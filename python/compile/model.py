"""Layer 2 — the JAX network-evaluation model.

``make_eval(n, num_apps, kchain)`` builds the full per-iteration evaluation
of the paper's objective for a *padded* network of ``n`` nodes and
``num_apps`` applications, each a chain of ``kchain`` tasks (stage layout is
app-major: stage id s = a·(kchain+1) + k):

1. forward sweep — the traffic fixed point t_i(a,k) (Section II recursions),
   chain level by chain level, each level running ``n`` propagation hops
   (exact for any loop-free φ, since stage DAG paths have < n hops);
2. flow accounting — link bit-rates F_ij, workloads G_i, and the aggregate
   cost D(φ) with the same saturated M/M/1 extension as the Rust side;
3. reverse sweep — ∂D/∂t_i(a,k) by eq. (4), final stages first;
4. δ-marginals (eq. 7) for every direction including the CPU column.

The inner hops and the δ epilogue call the Layer-1 Pallas kernels
(``use_pallas=True``) or their jnp oracles — both lower to identical HLO on
CPU (interpret mode). Everything is f64 so the Rust cross-check holds to
~1e-12.

Cost-function params are passed per link/node as three dense arrays
(is-queue flag, linear slope, queue capacity), so one artifact serves any
Linear/Queue mix.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import delta as delta_mod
from .kernels import propagate as prop_mod
from .kernels import ref

#: Saturation knee fraction — MUST match rust/src/cost/mod.rs::SAT_FRAC.
SAT_FRAC = 0.99

INF_MARGINAL = ref.INF_MARGINAL


def queue_cost_and_deriv(x, cap):
    """M/M/1 cost x/(cap-x) with the quadratic extension beyond SAT_FRAC·cap.

    Bit-compatible with CostFn::Queue in rust/src/cost/mod.rs.
    """
    knee = SAT_FRAC * cap
    inside = x < knee
    safe_den = jnp.where(inside, cap - x, 1.0)
    exact = x / safe_den
    d_exact = cap / (safe_den * safe_den)
    v = knee / (cap - knee)
    s = cap / ((cap - knee) * (cap - knee))
    c2 = 2.0 * cap / ((cap - knee) ** 3)
    dx = x - knee
    ext = v + s * dx + 0.5 * c2 * dx * dx
    d_ext = s + c2 * dx
    return jnp.where(inside, exact, ext), jnp.where(inside, d_exact, d_ext)


def cost_and_deriv(x, isq, lin, cap):
    """Linear or saturated-queue cost, selected elementwise by ``isq``."""
    qc, qd = queue_cost_and_deriv(x, cap)
    lc, ld = lin * x, jnp.broadcast_to(lin, x.shape)
    return jnp.where(isq > 0, qc, lc), jnp.where(isq > 0, qd, ld)


def make_eval(n, num_apps, kchain, use_pallas=True, interpret=True):
    """Build the evaluation function for a fixed padded size.

    Returns ``eval_network`` mapping 12 input arrays to a 7-tuple:
    (total_cost, t, F, G, d_dt, delta_link, delta_cpu).
    """
    k1 = kchain + 1
    num_stages = num_apps * k1

    if use_pallas:
        def prop(phi, t, inj):
            return prop_mod.propagate(phi, t, inj, interpret=interpret)

        def backp(phi, x, own):
            return prop_mod.backprop(phi, x, own, interpret=interpret)

        def delt(dprime, ddt, packet, adj):
            return delta_mod.delta(dprime, ddt, packet, adj, interpret=interpret)
    else:
        prop, backp, delt = ref.ref_propagate, ref.ref_backprop, ref.ref_delta

    def eval_network(
        phi_link,  # (S, N, N) forwarding fractions
        phi_cpu,  # (S, N) CPU fractions
        exo,  # (A, N) exogenous input rates (stage 0 of each app)
        adj,  # (N, N) 0/1 adjacency
        link_isq,  # (N, N) 1.0 where the link cost is Queue
        link_lin,  # (N, N) linear slope d_ij (0 where queue)
        link_cap,  # (N, N) queue capacity (1 where linear; never 0)
        comp_isq,  # (N,)
        comp_lin,  # (N,)
        comp_cap,  # (N,)
        packet,  # (S,) packet sizes L_(a,k)
        weight,  # (S, N) computation weights w_i(a,k)
    ):
        phi_l = phi_link.reshape(num_apps, k1, n, n)
        phi_c = phi_cpu.reshape(num_apps, k1, n)
        w_lvl = weight.reshape(num_apps, k1, n)

        # ---- 1. forward sweep: chain level by chain level ------------------
        t_levels = []
        g_levels = []
        inj = exo  # level-0 injection
        for k in range(k1):
            phi_k = phi_l[:, k]

            def body(_m, t, phi_k=phi_k, inj=inj):
                return prop(phi_k, t, inj)

            t_k = jax.lax.fori_loop(0, n, body, inj)
            g_k = t_k * phi_c[:, k]
            t_levels.append(t_k)
            g_levels.append(g_k)
            inj = g_k  # next level's injection (1:1 packet conversion)

        t = jnp.stack(t_levels, axis=1).reshape(num_stages, n)
        g = jnp.stack(g_levels, axis=1).reshape(num_stages, n)

        # ---- 2. flows and aggregate cost -----------------------------------
        f = t[:, :, None] * phi_link  # (S, N, N) packet rates
        flow = jnp.einsum("s,sij->ij", packet, f) * adj  # F_ij bits/sec
        work = jnp.einsum("si,si->i", weight, g)  # G_i

        link_c, link_d = cost_and_deriv(flow, link_isq, link_lin, link_cap)
        comp_c, comp_d = cost_and_deriv(work, comp_isq, comp_lin, comp_cap)
        total = jnp.sum(link_c * adj) + jnp.sum(comp_c)
        link_d = link_d * adj  # zero marginal on non-links (masked anyway)

        # ---- 3. reverse sweep ----------------------------------------------
        # static per-node part of eq. (4a): Σ_j φ_ij·L·D'_ij (+ CPU term)
        lw = packet[:, None, None] * link_d[None, :, :]  # (S, N, N)
        static_link = jnp.einsum("sij,sij->si", phi_link, lw).reshape(
            num_apps, k1, n
        )
        ddt_levels = [None] * k1
        ddt_next = jnp.zeros((num_apps, n), dtype=phi_link.dtype)
        for k in reversed(range(k1)):
            own = static_link[:, k]
            if k < kchain:
                own = own + phi_c[:, k] * (w_lvl[:, k] * comp_d[None, :] + ddt_next)
            phi_k = phi_l[:, k]

            def body(_m, x, phi_k=phi_k, own=own):
                return backp(phi_k, x, own)

            ddt_k = jax.lax.fori_loop(0, n, body, own)
            ddt_levels[k] = ddt_k
            ddt_next = ddt_k

        d_dt = jnp.stack(ddt_levels, axis=1).reshape(num_stages, n)

        # ---- 4. δ-marginals (eq. 7) ----------------------------------------
        delta_link = delt(link_d, d_dt, packet, adj)
        # CPU column: w·C' + ∂D/∂t(a,k+1); INF for final stages
        ddt_shift = jnp.stack(
            [
                ddt_levels[k + 1] if k < kchain else jnp.zeros((num_apps, n))
                for k in range(k1)
            ],
            axis=1,
        ).reshape(num_stages, n)
        final = jnp.tile(
            jnp.arange(k1) == kchain, (num_apps,)
        )  # (S,) final-stage mask
        delta_cpu = weight * comp_d[None, :] + ddt_shift
        delta_cpu = jnp.where(final[:, None], INF_MARGINAL, delta_cpu)

        return total, t, flow, work, d_dt, delta_link, delta_cpu

    return eval_network


def input_shapes(n, num_apps, kchain):
    """The 12 input (name, shape) pairs, in calling order — the artifact
    manifest the Rust runtime consumes."""
    s = num_apps * (kchain + 1)
    return [
        ("phi_link", (s, n, n)),
        ("phi_cpu", (s, n)),
        ("exo", (num_apps, n)),
        ("adj", (n, n)),
        ("link_isq", (n, n)),
        ("link_lin", (n, n)),
        ("link_cap", (n, n)),
        ("comp_isq", (n,)),
        ("comp_lin", (n,)),
        ("comp_cap", (n,)),
        ("packet", (s,)),
        ("weight", (s, n)),
    ]


def output_shapes(n, num_apps, kchain):
    """The 7 output (name, shape) pairs, in tuple order."""
    s = num_apps * (kchain + 1)
    return [
        ("total_cost", ()),
        ("traffic", (s, n)),
        ("link_flow", (n, n)),
        ("workload", (n,)),
        ("d_dt", (s, n)),
        ("delta_link", (s, n, n)),
        ("delta_cpu", (s, n)),
    ]
