"""AOT compile path: lower the L2 model to HLO *text* artifacts.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Each size bucket produces ``artifacts/eval_n{N}_a{A}_k{K}.hlo.txt`` plus a
single ``artifacts/manifest.json`` describing buckets, input order/shapes and
output order/shapes for the Rust runtime.

Usage: python -m compile.aot [--out-dir ../artifacts] [--no-pallas]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model

#: (n, num_apps, kchain) buckets. Small covers every Table-II scenario except
#: SW (n=100, |A|=30); large covers SW.
BUCKETS = [
    (32, 12, 2),
    (128, 32, 2),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n, num_apps, kchain, use_pallas=True):
    fn = model.make_eval(n, num_apps, kchain, use_pallas=use_pallas)
    specs = [
        jax.ShapeDtypeStruct(shape, jnp.float64)
        for _name, shape in model.input_shapes(n, num_apps, kchain)
    ]
    return jax.jit(fn).lower(*specs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the jnp reference instead of the Pallas kernels",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"dtype": "f64", "buckets": []}
    for (n, a, k) in BUCKETS:
        lowered = lower_bucket(n, a, k, use_pallas=not args.no_pallas)
        text = to_hlo_text(lowered)
        name = f"eval_n{n}_a{a}_k{k}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["buckets"].append(
            {
                "file": name,
                "n": n,
                "num_apps": a,
                "kchain": k,
                "inputs": [
                    {"name": nm, "shape": list(sh)}
                    for nm, sh in model.input_shapes(n, a, k)
                ],
                "outputs": [
                    {"name": nm, "shape": list(sh)}
                    for nm, sh in model.output_shapes(n, a, k)
                ],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
