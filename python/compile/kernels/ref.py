"""Pure-jnp oracles for the Pallas kernels (the correctness reference).

Every Pallas kernel in this package has an exact ``ref_*`` twin here built
from plain ``jax.numpy`` ops. pytest (incl. hypothesis sweeps) asserts
allclose between kernel and oracle across shapes/dtypes; the AOT model can be
built against either implementation (``use_pallas`` flag) and must produce
identical HLO-level numerics.
"""

import jax.numpy as jnp

#: Marginal used for unavailable directions; matches Rust INF_MARGINAL.
INF_MARGINAL = 1e30


def ref_propagate(phi, t, inj):
    """One hop of the traffic fixed point: ``out[b,j] = inj[b,j] + sum_i
    t[b,i] * phi[b,i,j]`` for a batch of stages.

    Args:
      phi: (B, N, N) forwarding fractions (row i -> col j).
      t:   (B, N) current traffic iterate.
      inj: (B, N) injection (exogenous + previous-stage CPU output).
    Returns:
      (B, N) next traffic iterate.
    """
    return inj + jnp.einsum("bi,bij->bj", t, phi)


def ref_backprop(phi, x, own):
    """One hop of the reverse (marginal) sweep:

    ``out[b,i] = own[b,i] + sum_j phi[b,i,j] * x[b,j]``

    where ``own`` is the static part of eq. (4a) (Σ_j φ_ij·L·D'_ij +
    φ_cpu·(w·C' + ∂D/∂t_next)) and ``x`` the current downstream iterate.

    Args:
      phi: (B, N, N) forwarding fractions.
      x:   (B, N) current ∂D/∂t iterate.
      own: (B, N) static per-node part.
    Returns:
      (B, N) next ∂D/∂t iterate.
    """
    return own + jnp.einsum("bij,bj->bi", phi, x)


def ref_delta(dprime, ddt, packet, adj):
    """Modified marginals δ_ij (eq. 7), link part, for a batch of stages:

    ``delta[b,i,j] = packet[b] * dprime[i,j] + ddt[b,j]`` where ``adj[i,j]``,
    else INF_MARGINAL.

    Args:
      dprime: (N, N) link marginal costs D'_ij(F_ij).
      ddt:    (B, N) ∂D/∂t_j for the stage batch.
      packet: (B,) packet sizes L_(a,k).
      adj:    (N, N) 0/1 adjacency mask.
    Returns:
      (B, N, N) δ with INF at non-links.
    """
    d = packet[:, None, None] * dprime[None, :, :] + ddt[:, None, :]
    return jnp.where(adj[None, :, :] > 0, d, INF_MARGINAL)
