"""Pallas kernels for the network-evaluation hot path (Layer 1).

Two kernels, both batched over stages:

* :func:`propagate` — one hop of the traffic fixed point
  ``t'[b,j] = inj[b,j] + Σ_i t[b,i]·φ[b,i,j]`` (the body of the forward
  sweep; also reused, transposed, for the reverse sweep via
  :func:`backprop`).
* :func:`delta` (in ``delta.py``) — the δ-marginal combine of eq. (7).

Blocking: the grid runs over the *stage* axis in blocks of ``block_stages``.
Each program instance holds a (bs, N, N) φ slab plus (bs, N) vectors in VMEM
and performs a batched (bs,1,N)x(bs,N,N) contraction on the MXU.

* TPU: pick ``block_stages`` so the slab fits VMEM —
  bs·N²·8B ≤ ~12MB ⇒ bs ≤ 8 at N = 128 (see DESIGN.md §Perf).
* CPU interpret (this testbed): ``block_stages=None`` → one full-batch block.
  Per-block grid steps in interpret mode execute as separate HLO
  dynamic-slice loop iterations, so fewer/larger blocks are strictly faster
  here (§Perf log: 17.5s → ~0.1s per SW evaluation for the n=128 bucket).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _propagate_kernel(phi_ref, t_ref, inj_ref, out_ref):
    # block shapes: phi (bs, N, N), t/inj/out (bs, N)
    phi = phi_ref[...]
    t = t_ref[...]
    # (bs, 1, N) @ (bs, N, N) -> (bs, 1, N): batched MXU matmul
    acc = jax.lax.dot_general(
        t[:, None, :],
        phi,
        (((2,), (1,)), ((0,), (0,))),
        precision=jax.lax.Precision.HIGHEST,
    )
    out_ref[...] = inj_ref[...] + acc[:, 0, :]


@functools.partial(jax.jit, static_argnames=("interpret", "block_stages"))
def propagate(phi, t, inj, *, interpret=True, block_stages=None):
    """One traffic-propagation hop for a batch of stages.

    Args:
      phi: (B, N, N) float array, forwarding fractions.
      t:   (B, N) current traffic.
      inj: (B, N) injection.
      interpret: lower in interpret mode (required on CPU PJRT).
      block_stages: stages per grid step (None = whole batch in one block).
    Returns:
      (B, N) next iterate, ``inj + t @ phi`` per stage.
    """
    b, n, _ = phi.shape
    bs = b if block_stages is None else min(block_stages, b)
    grid = ((b + bs - 1) // bs,)
    return pl.pallas_call(
        _propagate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), phi.dtype),
        interpret=interpret,
    )(phi, t, inj)


def _backprop_kernel(phi_ref, x_ref, own_ref, out_ref):
    phi = phi_ref[...]  # (bs, N, N)
    x = x_ref[...]  # (bs, N)
    # (bs, N, N) @ (bs, N, 1) -> (bs, N, 1)
    acc = jax.lax.dot_general(
        phi,
        x[:, :, None],
        (((2,), (1,)), ((0,), (0,))),
        precision=jax.lax.Precision.HIGHEST,
    )
    out_ref[...] = own_ref[...] + acc[:, :, 0]


@functools.partial(jax.jit, static_argnames=("interpret", "block_stages"))
def backprop(phi, x, own, *, interpret=True, block_stages=None):
    """One reverse-sweep hop: ``own + phi @ x`` per stage.

    Args:
      phi: (B, N, N) forwarding fractions.
      x:   (B, N) downstream ∂D/∂t iterate.
      own: (B, N) static part of eq. (4a).
      block_stages: stages per grid step (None = whole batch).
    Returns:
      (B, N) next ∂D/∂t iterate.
    """
    b, n, _ = phi.shape
    bs = b if block_stages is None else min(block_stages, b)
    grid = ((b + bs - 1) // bs,)
    return pl.pallas_call(
        _backprop_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), phi.dtype),
        interpret=interpret,
    )(phi, x, own)
