"""Pallas kernel for the δ-marginal combine (eq. 7), Layer 1.

``delta[b,i,j] = L[b]·D'_ij + ∂D/∂t_j(b)`` on links, INF elsewhere — the
elementwise epilogue of every evaluation call, batched over stages. Pure
VPU-style elementwise work; `block_stages` controls the VMEM slab size as in
``propagate.py`` (None = whole batch, the right choice on CPU interpret).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import INF_MARGINAL


def _delta_kernel(dprime_ref, ddt_ref, packet_ref, adj_ref, out_ref):
    dprime = dprime_ref[...]  # (N, N) — shared across the batch
    ddt = ddt_ref[...]  # (bs, N)
    packet = packet_ref[...]  # (bs,)
    adj = adj_ref[...]  # (N, N)
    d = packet[:, None, None] * dprime[None, :, :] + ddt[:, None, :]
    out_ref[...] = jnp.where(adj[None, :, :] > 0, d, INF_MARGINAL)


@functools.partial(jax.jit, static_argnames=("interpret", "block_stages"))
def delta(dprime, ddt, packet, adj, *, interpret=True, block_stages=None):
    """Batched link-δ computation.

    Args:
      dprime: (N, N) link marginals D'_ij(F_ij).
      ddt:    (B, N) ∂D/∂t_j per stage.
      packet: (B,) packet sizes.
      adj:    (N, N) 0/1 adjacency.
      block_stages: stages per grid step (None = whole batch).
    Returns:
      (B, N, N) δ with INF_MARGINAL at non-links.
    """
    b, n = ddt.shape
    bs = b if block_stages is None else min(block_stages, b)
    grid = ((b + bs - 1) // bs,)
    return pl.pallas_call(
        _delta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, n, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, n), dprime.dtype),
        interpret=interpret,
    )(dprime, ddt, packet, adj)
