"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and values; fixed cases pin the exact semantics.
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import delta as delta_mod
from compile.kernels import propagate as prop_mod
from compile.kernels import ref


def _rand(rng, shape, dtype):
    return rng.random(shape).astype(dtype)


# ---------------------------------------------------------------------------
# fixed-case pins
# ---------------------------------------------------------------------------


def test_propagate_identity_phi_zero():
    t = jnp.ones((2, 4))
    inj = jnp.arange(8.0).reshape(2, 4)
    phi = jnp.zeros((2, 4, 4))
    out = prop_mod.propagate(phi, t, inj)
    np.testing.assert_allclose(np.asarray(out), np.asarray(inj))


def test_propagate_single_link():
    # stage 0: all of node 0's unit traffic goes to node 2
    phi = np.zeros((1, 3, 3))
    phi[0, 0, 2] = 1.0
    t = np.array([[1.0, 0.0, 0.0]])
    inj = np.zeros((1, 3))
    out = prop_mod.propagate(jnp.asarray(phi), jnp.asarray(t), jnp.asarray(inj))
    np.testing.assert_allclose(np.asarray(out), [[0.0, 0.0, 1.0]])


def test_backprop_transposes_propagate():
    rng = np.random.default_rng(1)
    phi = rng.random((3, 5, 5))
    x = rng.random((3, 5))
    own = rng.random((3, 5))
    out = prop_mod.backprop(jnp.asarray(phi), jnp.asarray(x), jnp.asarray(own))
    want = own + np.einsum("bij,bj->bi", phi, x)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-12)


def test_delta_inf_off_links():
    dprime = np.full((3, 3), 2.0)
    ddt = np.zeros((1, 3))
    packet = np.array([5.0])
    adj = np.zeros((3, 3))
    adj[0, 1] = 1.0
    out = delta_mod.delta(
        jnp.asarray(dprime), jnp.asarray(ddt), jnp.asarray(packet), jnp.asarray(adj)
    )
    out = np.asarray(out)
    assert out[0, 0, 1] == pytest.approx(10.0)
    assert out[0, 1, 0] == ref.INF_MARGINAL
    assert out[0, 2, 2] == ref.INF_MARGINAL


# ---------------------------------------------------------------------------
# hypothesis sweeps: kernel == oracle across shapes and dtypes
# ---------------------------------------------------------------------------

shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=6),  # batch (stages)
    st.integers(min_value=1, max_value=16),  # nodes
)


@settings(max_examples=30, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([np.float32, np.float64]))
def test_propagate_matches_ref(shape, seed, dtype):
    b, n = shape
    rng = np.random.default_rng(seed)
    phi = _rand(rng, (b, n, n), dtype)
    t = _rand(rng, (b, n), dtype)
    inj = _rand(rng, (b, n), dtype)
    out = prop_mod.propagate(jnp.asarray(phi), jnp.asarray(t), jnp.asarray(inj))
    want = ref.ref_propagate(jnp.asarray(phi), jnp.asarray(t), jnp.asarray(inj))
    rtol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=rtol)


@settings(max_examples=30, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([np.float32, np.float64]))
def test_backprop_matches_ref(shape, seed, dtype):
    b, n = shape
    rng = np.random.default_rng(seed)
    phi = _rand(rng, (b, n, n), dtype)
    x = _rand(rng, (b, n), dtype)
    own = _rand(rng, (b, n), dtype)
    out = prop_mod.backprop(jnp.asarray(phi), jnp.asarray(x), jnp.asarray(own))
    want = ref.ref_backprop(jnp.asarray(phi), jnp.asarray(x), jnp.asarray(own))
    rtol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=rtol)


@settings(max_examples=30, deadline=None)
@given(shape=shape_strategy, seed=st.integers(0, 2**31 - 1),
       dtype=st.sampled_from([np.float32, np.float64]))
def test_delta_matches_ref(shape, seed, dtype):
    b, n = shape
    rng = np.random.default_rng(seed)
    dprime = _rand(rng, (n, n), dtype)
    ddt = _rand(rng, (b, n), dtype)
    packet = _rand(rng, (b,), dtype) + 1.0
    adj = (rng.random((n, n)) > 0.5).astype(dtype)
    out = delta_mod.delta(
        jnp.asarray(dprime), jnp.asarray(ddt), jnp.asarray(packet), jnp.asarray(adj)
    )
    want = ref.ref_delta(
        jnp.asarray(dprime), jnp.asarray(ddt), jnp.asarray(packet), jnp.asarray(adj)
    )
    # f32 differs in the last ulp (fma contraction inside the kernel)
    rtol = 1e-6 if dtype == np.float32 else 1e-14
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=rtol)
