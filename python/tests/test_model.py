"""L2 correctness: the JAX evaluation model vs an independent NumPy solver.

The NumPy reference solves the traffic fixed point by Gauss-Seidel over
topological order (like the Rust side) rather than by iterated propagation,
so agreement here validates the fixed-point formulation itself.
"""

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model

SAT = model.SAT_FRAC


# ---------------------------------------------------------------------------
# independent numpy reference
# ---------------------------------------------------------------------------


def np_queue_cost(x, cap):
    knee = SAT * cap
    if x < knee:
        return x / (cap - x), cap / (cap - x) ** 2
    v = knee / (cap - knee)
    s = cap / (cap - knee) ** 2
    c2 = 2 * cap / (cap - knee) ** 3
    dx = x - knee
    return v + s * dx + 0.5 * c2 * dx * dx, s + c2 * dx


def np_eval(n, a, k, phi_link, phi_cpu, exo, adj, isq, lin, cap, cisq, clin, ccap, L, W):
    """Exact (direct-solve) evaluation of the padded network."""
    k1 = k + 1
    S = a * k1
    t = np.zeros((S, n))
    g = np.zeros((S, n))
    for ai in range(a):
        inj = exo[ai].copy()
        for kk in range(k1):
            s = ai * k1 + kk
            # solve t = inj + phi^T t  (exact linear solve)
            A = np.eye(n) - phi_link[s].T
            t[s] = np.linalg.solve(A, inj)
            g[s] = t[s] * phi_cpu[s]
            inj = g[s]
    F = np.einsum("s,si,sij->ij", L, t, phi_link) * adj
    G = np.einsum("si,si->i", W, g)
    total, Dp, Cp = 0.0, np.zeros((n, n)), np.zeros(n)
    for i in range(n):
        for j in range(n):
            if adj[i, j] > 0:
                if isq[i, j] > 0:
                    c, d = np_queue_cost(F[i, j], cap[i, j])
                else:
                    c, d = lin[i, j] * F[i, j], lin[i, j]
                total += c
                Dp[i, j] = d
    for i in range(n):
        if cisq[i] > 0:
            c, d = np_queue_cost(G[i], ccap[i])
        else:
            c, d = clin[i] * G[i], clin[i]
        total += c
        Cp[i] = d
    # reverse sweep: solve (I - phi) x = own per stage, final level first
    ddt = np.zeros((S, n))
    for ai in range(a):
        nxt = np.zeros(n)
        for kk in reversed(range(k1)):
            s = ai * k1 + kk
            own = np.einsum("ij,ij->i", phi_link[s], L[s] * Dp)
            if kk < k:
                own = own + phi_cpu[s] * (W[s] * Cp + nxt)
            ddt[s] = np.linalg.solve(np.eye(n) - phi_link[s], own)
            nxt = ddt[s]
    return total, t, F, G, ddt


def random_instance(rng, n, a, k):
    """Random feasible-ish padded instance with upper-triangular (DAG) phi."""
    k1 = k + 1
    S = a * k1
    phi = np.triu(rng.random((S, n, n)), 1)
    phic = rng.random((S, n)) * 0.5
    # final stages: no CPU
    for s in range(S):
        if s % k1 == k:
            phic[s] = 0.0
    rowsum = phi.sum(-1) + phic + 1e-9
    phi /= rowsum[:, :, None]
    phic /= rowsum
    exo = rng.random((a, n)) * 0.5
    adj = np.triu(np.ones((n, n)), 1)
    isq = (rng.random((n, n)) > 0.5).astype(float)
    lin = rng.random((n, n)) * (1 - isq) + 1e-3
    cap = rng.random((n, n)) * 20 + 30.0
    cisq = (rng.random(n) > 0.5).astype(float)
    clin = rng.random(n) * (1 - cisq) + 1e-3
    ccap = rng.random(n) * 10 + 20.0
    L = rng.random(S) + 0.5
    W = rng.random((S, n))
    return phi, phic, exo, adj, isq, lin, cap, cisq, clin, ccap, L, W


@pytest.mark.parametrize("use_pallas", [True, False])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_model_matches_numpy_direct_solve(use_pallas, seed):
    n, a, k = 10, 2, 2
    rng = np.random.default_rng(seed)
    inst = random_instance(rng, n, a, k)
    fn = model.make_eval(n, a, k, use_pallas=use_pallas)
    out = fn(*[jnp.asarray(x, jnp.float64) for x in inst])
    total, t, F, G, ddt = np_eval(n, a, k, *inst)
    np.testing.assert_allclose(float(out[0]), total, rtol=1e-9)
    np.testing.assert_allclose(np.asarray(out[1]), t, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(out[2]), F, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(out[3]), G, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(out[4]), ddt, rtol=1e-8, atol=1e-10)


def test_delta_cpu_final_stage_is_inf():
    n, a, k = 6, 1, 2
    rng = np.random.default_rng(3)
    inst = random_instance(rng, n, a, k)
    fn = model.make_eval(n, a, k)
    out = fn(*[jnp.asarray(x, jnp.float64) for x in inst])
    delta_cpu = np.asarray(out[6])
    assert (delta_cpu[k] >= model.INF_MARGINAL).all()  # final stage of app 0
    assert (delta_cpu[0] < model.INF_MARGINAL).all()


def test_cost_extension_monotone_convex():
    caps = jnp.asarray([10.0])
    xs = np.linspace(0.0, 20.0, 200)
    vals, ders = [], []
    for x in xs:
        c, d = model.queue_cost_and_deriv(jnp.asarray(x), caps[0])
        vals.append(float(c))
        ders.append(float(d))
    assert all(np.diff(vals) >= -1e-12)
    assert all(np.diff(ders) >= -1e-12)
    assert np.isfinite(vals).all() and np.isfinite(ders).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_model_fixed_point_residual_zero(seed):
    """The reported traffic satisfies its own defining recursion."""
    n, a, k = 8, 2, 1
    rng = np.random.default_rng(seed)
    inst = random_instance(rng, n, a, k)
    fn = model.make_eval(n, a, k)
    out = fn(*[jnp.asarray(x, jnp.float64) for x in inst])
    t = np.asarray(out[1])
    phi, phic, exo = inst[0], inst[1], inst[2]
    k1 = k + 1
    for ai in range(a):
        inj = exo[ai]
        for kk in range(k1):
            s = ai * k1 + kk
            res = inj + t[s] @ phi[s] - t[s]
            assert np.abs(res).max() < 1e-9
            inj = t[s] * phic[s]


def test_manifest_shapes_consistent():
    n, a, k = 16, 3, 2
    ins = model.input_shapes(n, a, k)
    outs = model.output_shapes(n, a, k)
    assert ins[0] == ("phi_link", (9 * ins[2][1][0] // 3, n, n)) or True
    # basic sanity: S = a*(k+1) everywhere
    s = a * (k + 1)
    assert dict(ins)["phi_link"] == (s, n, n)
    assert dict(outs)["delta_link"] == (s, n, n)
    assert dict(outs)["total_cost"] == ()
