//! Online adaptation: input-rate shifts and link failures mid-run.
//!
//! The paper (Section IV) claims Algorithm 1 is adaptive: it needs no prior
//! knowledge of r_i(a), tracks changes in them, and handles topology changes
//! by blocked-set edits. This example exercises all three on GEANT.
//!
//! ```bash
//! cargo run --release --example online_adaptation
//! ```

use scfo::config::Scenario;
use scfo::prelude::*;

fn main() -> anyhow::Result<()> {
    let sc = Scenario::table2("geant")?;
    let mut rng = Rng::new(sc.seed);
    let mut net = sc.build(&mut rng)?;
    let mut gp = GradientProjection::new(&net, GpOptions::default());

    println!("phase 1: converge on the initial demand");
    let rep = gp.run(&net, 600);
    println!("  cost {:.4} (converged={})", rep.final_cost, rep.converged);

    println!("phase 2: demand shock — app 0's main source rate x4");
    let src = net.apps[0]
        .input_rates
        .iter()
        .position(|&r| r > 0.0)
        .unwrap();
    net.apps[0].input_rates[src] *= 4.0;
    let shocked = gp.cost(&net);
    let rep = gp.run(&net, 600);
    println!(
        "  cost {:.4} right after shock -> {:.4} after re-optimizing",
        shocked, rep.final_cost
    );
    assert!(rep.final_cost <= shocked + 1e-9);

    println!("phase 3: link failure on a loaded link");
    // find the most loaded link and kill it
    let fs = FlowState::solve(&net, &gp.phi)?;
    let (emax, _) = fs
        .link_flow
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    let (i, j) = net.graph.edge(emax);
    println!("  removing link ({i},{j}) carrying F={:.3}", fs.link_flow[emax]);
    gp.on_link_removed(&net, i, j);
    gp.phi.validate(&net)?; // still feasible, loop-free
    let degraded = gp.cost(&net);
    let rep = gp.run(&net, 800);
    println!(
        "  cost {:.4} right after failure -> {:.4} after re-routing",
        degraded, rep.final_cost
    );

    println!("phase 4: link restored");
    gp.on_link_added(&net, i, j);
    let rep = gp.run(&net, 800);
    println!("  cost {:.4} after re-admitting the link", rep.final_cost);
    Ok(())
}
