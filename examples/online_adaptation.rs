//! Online adaptation under nonstationary traffic, on the workload subsystem.
//!
//! The paper (Section IV) claims Algorithm 1 is adaptive: it needs no prior
//! knowledge of r_i(a) and tracks changes in them online. This example
//! exercises that claim end to end on GEANT: a diurnal (sinusoidal) rate
//! pattern with a flash-crowd override on one source, served by the online
//! loop with the adaptation controller attached — change points are
//! detected from the EWMA innovations, the optimizer is re-triggered, and
//! per-slot regret is measured against a clairvoyant GP oracle.
//!
//! ```bash
//! cargo run --release --example online_adaptation
//! ```

use scfo::config::Scenario;
use scfo::prelude::*;
use scfo::serving::{
    AdaptationController, ControllerOptions, OnlineServer, ReconvergePolicy, ServerOptions,
};
use scfo::workload::StreamOverride;

fn main() -> anyhow::Result<()> {
    let sc = Scenario::table2("geant")?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;

    // diurnal demand everywhere; app 0's first source additionally erupts
    // into a flash crowd at t = 60
    let mut wspec = WorkloadSpec::named("diurnal")?;
    let hot_node = net.apps[0]
        .input_rates
        .iter()
        .position(|&r| r > 0.0)
        .expect("app 0 has a source");
    wspec.overrides.push(StreamOverride {
        app: 0,
        node: hot_node,
        model: ModelSpec::FlashCrowd {
            peak: 8.0,
            start: 60.0,
            ramp: 5.0,
            hold: 30.0,
            decay: 20.0,
        },
    });
    println!(
        "GEANT, {} apps; diurnal workload + flash crowd on (app 0, node {hot_node})",
        net.apps.len()
    );

    let workload = Workload::from_spec(&wspec, &net, 1.0, sc.seed)?;
    let gp = GradientProjection::new(&net, GpOptions::default());
    let mut srv = OnlineServer::with_workload(net, gp, workload, ServerOptions::default());
    srv.attach_controller(AdaptationController::new(ControllerOptions {
        policy: ReconvergePolicy::WarmStart,
        ..ControllerOptions::default()
    }));

    let metrics = srv.run(200)?;
    for m in &metrics {
        if m.detection {
            println!(
                "slot {:>3}: CHANGE POINT detected (served cost {:.3}, oracle {:.3})",
                m.slot,
                m.cost,
                m.oracle_cost.unwrap()
            );
        }
    }
    let s = srv.controller.as_ref().unwrap().summary();
    println!(
        "\n{} slots served; {} detections; reconvergence mean {:.1} / max {} slots",
        s.slots, s.detections, s.reconverge_mean, s.reconverge_max
    );
    println!(
        "regret vs clairvoyant GP: total {:.3}, per-slot mean {:.4}",
        s.regret_total, s.regret_mean
    );
    println!("delay histogram: {}", srv.delay_hist.summary());
    anyhow::ensure!(s.detections >= 1, "the flash crowd must be detected");
    Ok(())
}
