//! Quickstart: build a Table-II scenario, run GP to the global optimum,
//! compare against every baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use scfo::algo::Algorithm;
use scfo::config::Scenario;
use scfo::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. a scenario straight out of the paper's Table II
    let scenario = Scenario::table2("abilene")?;
    let mut rng = Rng::new(scenario.seed);
    let net = scenario.build(&mut rng)?;
    println!(
        "Abilene: {} nodes, {} directed links, {} apps x {} tasks",
        net.n(),
        net.m(),
        net.apps.len(),
        net.apps[0].num_tasks
    );

    // 2. run the paper's Gradient Projection to the sufficiency condition
    let mut gp = GradientProjection::new(&net, GpOptions::default());
    let report = gp.run(&net, 2000);
    println!(
        "GP: cost {:.4} after {} iterations (converged to condition (6): {})",
        report.final_cost, report.iters, report.converged
    );

    // 3. the aggregate cost IS the expected delay (Little's law): report it
    let fs = FlowState::solve(&net, &gp.phi)?;
    let lambda: f64 = net.apps.iter().map(|a| a.total_input()).sum();
    println!(
        "expected packets in system {:.4}  |  expected per-packet delay {:.4}s",
        fs.total_cost,
        fs.total_cost / lambda
    );

    // 4. baselines for context
    for alg in [Algorithm::Spoc, Algorithm::Lcof, Algorithm::LprSc] {
        let cost = alg.solve(&net, 800)?;
        println!(
            "{:<7} cost {:.4}  ({:.1}% above GP)",
            alg.name(),
            cost,
            100.0 * (cost / report.final_cost - 1.0)
        );
    }
    Ok(())
}
