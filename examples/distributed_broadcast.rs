//! The asynchronous sharded distributed runtime, for real: node actors
//! sharded across worker threads, exchanging versioned marginal broadcasts
//! through a virtual-time transport, with no global round barrier — and a
//! deterministic chaos run on top.
//!
//! ```bash
//! cargo run --release --example distributed_broadcast
//! ```

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::config::Scenario;
use scfo::distributed::{AsyncRuntime, FaultSpec, RuntimeOptions};
use scfo::prelude::*;

fn main() -> anyhow::Result<()> {
    let sc = Scenario::table2("abilene")?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let phi0 = Strategy::shortest_path_to_dest(&net);

    println!("== in-mem fabric: async runtime vs centralized GP ==");
    let mut rt = AsyncRuntime::in_mem(
        net.clone(),
        phi0.clone(),
        RuntimeOptions {
            shards: 4,
            ..RuntimeOptions::default()
        },
    );
    let rep = rt.run_until_quiescent();
    let mut gp = GradientProjection::new(&net, GpOptions::default());
    let central = gp.run(&net, 4000).final_cost;
    println!(
        "  quiesced after {} rounds ({} ticks): cost {:.6} vs centralized {:.6}",
        rep.epochs, rep.ticks, rep.final_cost, central
    );
    println!(
        "  {} peer msgs ({} bytes), max queue depth {}, {} control msgs",
        rep.stats.transport.sent,
        rep.stats.transport.bytes_sent,
        rep.stats.transport.max_queue_depth,
        rep.stats.control_messages,
    );

    println!("\n== sim-net fabric: seeded chaos (lossy preset) ==");
    let faults = FaultSpec::lossy(42);
    let mut chaos = AsyncRuntime::sim_net(
        net.clone(),
        phi0,
        faults,
        RuntimeOptions {
            shards: 4,
            ..RuntimeOptions::default()
        },
    );
    let crep = chaos.run_until_quiescent();
    chaos.strategy().validate(&net)?;
    assert!(!chaos.strategy().has_loop());
    let t = &crep.stats.transport;
    println!(
        "  quiesced after {} rounds: cost {:.6} (gap to centralized {:.2e})",
        crep.epochs,
        crep.final_cost,
        (crep.final_cost - central).abs() / (1.0 + central)
    );
    println!(
        "  sent {} / delivered {} / dropped {} (fault {}, overflow {}), duplicated {}, stale reads {}",
        t.sent,
        t.delivered,
        t.dropped_total(),
        t.dropped_fault,
        t.dropped_overflow,
        t.duplicated,
        crep.stats.stale_reads,
    );
    println!("  rerun with the same (seed, fault-spec) is bit-identical — see rust/tests/chaos.rs");
    Ok(())
}
