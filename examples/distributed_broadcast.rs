//! The Section-IV distributed runtime, for real: one thread per network
//! node, marginal-cost broadcast over channels, per-node GP updates — plus
//! fault injection on the peer message plane.
//!
//! ```bash
//! cargo run --release --example distributed_broadcast
//! ```

use std::time::Duration;

use scfo::config::Scenario;
use scfo::distributed::{Cluster, ClusterOptions, LossyConfig};
use scfo::prelude::*;

fn main() -> anyhow::Result<()> {
    let sc = Scenario::table2("abilene")?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let phi0 = Strategy::shortest_path_to_dest(&net);

    println!("== reliable fabric: distributed == centralized ==");
    let mut cluster = Cluster::spawn(
        net.clone(),
        phi0.clone(),
        ClusterOptions {
            alpha: 0.1,
            adaptive: false, // bit-parity with the non-backtracking optimizer
            ..Default::default()
        },
    );
    let mut gp = GradientProjection::with_strategy(
        &net,
        phi0.clone(),
        GpOptions {
            alpha: 0.1,
            backtrack: false,
            ..Default::default()
        },
    );
    for slot in 0..40 {
        let out = cluster.run_slot();
        gp.step(&net);
        let diff = cluster.phi.max_diff(&gp.phi);
        if slot % 10 == 0 {
            println!(
                "  slot {slot:>3}: cost {:.4}  |distributed - centralized|_inf = {diff:.2e}",
                out.cost
            );
        }
        assert!(diff < 1e-9, "slot {slot} diverged by {diff}");
    }
    println!("  final cost {:.4}", cluster.cost());
    let converged = cluster.phi.clone();
    cluster.shutdown();

    println!("== lossy fabric (2% peer-message drop): slots abort, never corrupt ==");
    let mut cluster = Cluster::spawn(
        net.clone(),
        converged,
        ClusterOptions {
            alpha: 0.1,
            slot_timeout: Duration::from_millis(250),
            lossy: Some(LossyConfig {
                drop_prob: 0.02,
                seed: 11,
            }),
            adaptive: true,
        },
    );
    let mut applied = 0;
    let mut skipped = 0;
    for _ in 0..30 {
        let out = cluster.run_slot();
        if out.applied {
            applied += 1;
        } else {
            skipped += 1;
        }
        cluster.phi.validate(&net)?;
        assert!(!cluster.phi.has_loop());
    }
    println!(
        "  30 slots: {applied} applied, {skipped} skipped, {} peer msgs dropped, final cost {:.4}",
        cluster.dropped_messages(),
        cluster.cost()
    );
    cluster.shutdown();
    Ok(())
}
