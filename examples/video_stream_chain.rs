//! The paper's Fig. 1 motivating workload: a LAN video-stream client whose
//! input stream runs through a service chain (decode → detect → render)
//! from source `s` to display `d`, embedded in the fog topology.
//!
//! Demonstrates: multi-stage chains with shrinking intermediate results,
//! heterogeneous CPU speeds (edge servers fast, devices slow), and where GP
//! decides to place each task as load grows.
//!
//! ```bash
//! cargo run --release --example video_stream_chain
//! ```

use scfo::app::{Application, Network, StageRegistry};
use scfo::cost::CostFn;
use scfo::graph::topologies;
use scfo::prelude::*;

fn build(rate: f64) -> anyhow::Result<Network> {
    let g = topologies::fog(); // 0 = cloud, 1-3 edge servers, 4-18 devices
    let n = g.n();
    // one video stream entering at device 10, display at device 16
    let mut input_rates = vec![0.0; n];
    input_rates[10] = rate;
    let app = Application {
        dest: 16,
        num_tasks: 3, // decode -> detect -> render
        // raw 4K frames are big; detection output is a tiny box list; the
        // rendered overlay is mid-sized
        packet_sizes: vec![24.0, 12.0, 1.0, 4.0],
        input_rates,
    };
    let apps = vec![app];
    let stages = StageRegistry::new(&apps);
    // CPU weight: devices are ~8x slower than edge servers; cloud fastest
    let mut comp_weight = vec![vec![0.0; n]; stages.len()];
    for row in &mut comp_weight {
        for (i, w) in row.iter_mut().enumerate() {
            *w = match i {
                0 => 0.5,        // cloud
                1..=3 => 1.0,    // edge servers
                _ => 8.0,        // devices
            };
        }
    }
    let link_cost: Vec<CostFn> = (0..g.m())
        .map(|e| {
            let (i, j) = g.edge(e);
            // cloud uplinks are long/thin; LAN links fat
            let cap = if i == 0 || j == 0 { 40.0 } else { 120.0 };
            CostFn::Queue { cap }
        })
        .collect();
    let comp_cost: Vec<CostFn> = (0..n)
        .map(|i| CostFn::Queue {
            cap: match i {
                0 => 50.0,
                1..=3 => 25.0,
                _ => 8.0,
            },
        })
        .collect();
    Network::new(g, apps, link_cost, comp_cost, comp_weight)
}

fn placement(net: &Network, phi: &Strategy) -> Vec<String> {
    let fs = FlowState::solve(net, phi).unwrap();
    let names = ["decode", "detect", "render"];
    let mut out = Vec::new();
    for k in 0..3 {
        let s = net.stages.id(0, k);
        let mut sites: Vec<(usize, f64)> = (0..net.n())
            .map(|i| (i, fs.cpu_pkt[s][i]))
            .filter(|(_i, g)| *g > 1e-6)
            .collect();
        sites.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let desc = sites
            .iter()
            .map(|(i, g)| {
                let kind = match i {
                    0 => "cloud",
                    1..=3 => "edge",
                    _ => "device",
                };
                format!("{kind}#{i}({g:.2}pkt/s)")
            })
            .collect::<Vec<_>>()
            .join(", ");
        out.push(format!("{:<7} @ {desc}", names[k]));
    }
    out
}

fn main() -> anyhow::Result<()> {
    for rate in [1.0, 4.0, 10.0] {
        let net = build(rate)?;
        let mut gp = GradientProjection::new(&net, GpOptions::default());
        let rep = gp.run(&net, 1500);
        let fs = FlowState::solve(&net, &gp.phi)?;
        println!("== stream rate {rate} fps ==");
        println!(
            "  delay-cost {:.4} (per-frame delay {:.4}s), converged={}",
            rep.final_cost,
            fs.total_cost / rate,
            rep.converged
        );
        for line in placement(&net, &gp.phi) {
            println!("  {line}");
        }
    }
    println!("\nNote how tasks migrate off the slow source device toward edge");
    println!("servers (and stay near the display for the big render output)");
    println!("as the stream rate grows — the Fig. 1/Fig. 7 behaviour.");
    Ok(())
}
