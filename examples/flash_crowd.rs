//! Flash-crowd drill: detection, warm-start vs cold-restart policies, and
//! bit-identical trace replay.
//!
//! Serves an Abilene workload that erupts into a 6x flash crowd, twice —
//! once per reconvergence policy — then records the same workload to a
//! trace and replays it, demonstrating that the trace reproduces the
//! serving results exactly (the property CI gates on).
//!
//! ```bash
//! cargo run --release --example flash_crowd
//! ```

use scfo::config::Scenario;
use scfo::prelude::*;
use scfo::serving::{
    AdaptationController, ControllerOptions, OnlineServer, ReconvergePolicy, ServerOptions,
};
use scfo::workload::Trace;

const SLOTS: usize = 120;
const SEED: u64 = 11;

fn serve(
    net: &Network,
    workload: Workload,
    policy: ReconvergePolicy,
) -> anyhow::Result<(Vec<f64>, scfo::serving::AdaptationSummary)> {
    let gp = GradientProjection::new(net, GpOptions::default());
    let mut srv = OnlineServer::with_workload(net.clone(), gp, workload, ServerOptions::default());
    srv.attach_controller(AdaptationController::new(ControllerOptions {
        policy,
        ..ControllerOptions::default()
    }));
    let metrics = srv.run(SLOTS)?;
    let costs = metrics.iter().map(|m| m.cost).collect();
    let summary = srv.controller.as_ref().unwrap().summary();
    Ok((costs, summary))
}

fn main() -> anyhow::Result<()> {
    let sc = Scenario::table2("abilene")?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let wspec = WorkloadSpec::named("flash-crowd")?;
    println!(
        "Abilene flash crowd: every source ramps to 6x at t = 30 ({SLOTS} slots)\n"
    );

    for policy in [ReconvergePolicy::WarmStart, ReconvergePolicy::ColdRestart] {
        let wl = Workload::from_spec(&wspec, &net, 1.0, SEED)?;
        let (costs, s) = serve(&net, wl, policy)?;
        println!(
            "policy {:<12} detections {}; reconvergence mean {:.1} slots; regret total {:.3}; final cost {:.4}",
            policy.name(),
            s.detections,
            s.reconverge_mean,
            s.regret_total,
            costs.last().unwrap()
        );
    }

    // record → replay: the trace must reproduce the warm-start run exactly
    let mut rec = Workload::from_spec(&wspec, &net, 1.0, SEED)?;
    let trace = Trace::record(&mut rec, SLOTS, Some(&sc));
    let live = serve(
        &net,
        Workload::from_spec(&wspec, &net, 1.0, SEED)?,
        ReconvergePolicy::WarmStart,
    )?;
    let replayed = serve(&net, trace.workload(), ReconvergePolicy::WarmStart)?;
    anyhow::ensure!(
        live.0 == replayed.0,
        "trace replay diverged from the live model"
    );
    println!(
        "\ntrace replay: {} slots reproduced bit-identically ({} recorded arrivals)",
        SLOTS,
        trace.stats().iter().map(|s| s.arrivals).sum::<u64>()
    );
    Ok(())
}
