//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! * L1/L2 — the Pallas/JAX network evaluation, AOT-compiled to HLO
//!   (`make artifacts`), loaded and executed via PJRT from Rust;
//! * L3 — the Rust serving loop: Poisson request arrivals on GEANT, online
//!   rate estimation, GP slots driven by the XLA evaluator;
//! * validation — final strategy replayed through the packet-level DES to
//!   confirm the optimized cost is the delay users would see.
//!
//! Reports convergence, expected delay, serving throughput and the
//! L3-hot-path latency breakdown. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```

use scfo::config::Scenario;
use scfo::prelude::*;
use scfo::runtime::XlaGp;
use scfo::serving::{OnlineServer, Optimizer, ServerOptions};
use scfo::sim;
use scfo::util::stats;

fn main() -> anyhow::Result<()> {
    if !scfo::runtime::artifacts_available() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    // ---- workload: GEANT, Table-II parameters --------------------------
    let sc = Scenario::table2("geant")?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let lambda: f64 = net.apps.iter().map(|a| a.total_input()).sum();
    println!(
        "GEANT: {} nodes / {} links / {} apps ({} stages), offered load λ = {lambda:.2} req/s",
        net.n(),
        net.m(),
        net.apps.len(),
        net.num_stages()
    );

    // ---- L1/L2 artifacts through PJRT -----------------------------------
    let gp = XlaGp::new(&net, GpOptions::default())?;
    println!(
        "loaded artifact bucket n={} apps={} (platform: PJRT CPU)",
        gp_bucket_n(&gp),
        gp_bucket_apps(&gp)
    );

    // ---- serving loop ----------------------------------------------------
    let slots = 150;
    let mut srv = OnlineServer::new(net.clone(), gp, ServerOptions::default());
    let t0 = std::time::Instant::now();
    let metrics = srv.run(slots)?;
    let wall = t0.elapsed().as_secs_f64();

    let arrivals: usize = metrics.iter().map(|m| m.arrivals).sum();
    let lat: Vec<f64> = metrics.iter().map(|m| m.optimizer_latency).collect();
    let costs: Vec<f64> = metrics.iter().map(|m| m.cost).collect();
    println!("\n-- serving results ({slots} slots, {:.1}s wall) --", wall);
    println!(
        "requests ingested: {arrivals} ({:.1} req/s sustained)",
        arrivals as f64 / wall
    );
    println!(
        "cost trajectory: slot1 {:.3} -> slot10 {:.3} -> final {:.3}",
        costs[0],
        costs[9.min(costs.len() - 1)],
        costs.last().unwrap()
    );
    println!(
        "expected per-request delay (Little): {:.4}s",
        metrics.last().unwrap().expected_delay
    );
    println!(
        "L3 hot-path latency per slot (PJRT eval + GP update): mean {:.2}ms p50 {:.2}ms p95 {:.2}ms",
        stats::mean(&lat) * 1e3,
        stats::percentile(&lat, 50.0) * 1e3,
        stats::percentile(&lat, 95.0) * 1e3
    );
    println!("delay histogram: {}", srv.delay_hist.summary());

    // ---- validate with the packet-level DES ------------------------------
    let mut truth = net.clone();
    // serve loop learned estimates; evaluate final phi on the true rates
    let phi = srv.optimizer.strategy().clone();
    for (a, app) in net.apps.iter().enumerate() {
        truth.apps[a].input_rates.copy_from_slice(&app.input_rates);
    }
    let analytic = FlowState::solve(&truth, &phi)?.total_cost;
    let des = sim::simulate(&truth, &phi, 1500.0, 99)?;
    println!("\n-- packet-level validation (DES, 1500 sim-seconds) --");
    println!(
        "analytic cost {:.3} | measured occupancy {:.3} | λ·W = {:.3} ({} packets delivered)",
        analytic,
        des.avg_occupancy,
        des.lambda * des.mean_delay,
        des.delivered
    );
    let rel = (des.avg_occupancy - analytic).abs() / analytic;
    println!("relative gap DES vs analytic: {:.1}%", rel * 100.0);

    // ---- compare against the congestion-blind baseline --------------------
    let lpr = scfo::algo::lpr::run(&truth)?;
    println!(
        "\nLPR-SC (congestion-blind) on the same workload: cost {:.3} ({:.1}x GP)",
        lpr.final_cost,
        lpr.final_cost / analytic
    );
    Ok(())
}

fn gp_bucket_n(gp: &XlaGp) -> usize {
    gp.bucket_info().0
}
fn gp_bucket_apps(gp: &XlaGp) -> usize {
    gp.bucket_info().1
}
