//! Fig. 6 bench: total cost vs exogenous input rate (Abilene, queue costs).
//!
//! Paper's shape to reproduce: all algorithms' costs grow with load; GP's
//! advantage widens sharply as the network becomes congested (baselines
//! saturate queues and blow up first).
//!
//! ```bash
//! cargo bench --bench fig6
//! ```

use scfo::bench::print_table;
use scfo::config::Scenario;
use scfo::sim::rate_sweep;

fn main() -> anyhow::Result<()> {
    let sc = Scenario::table2("abilene")?;
    let scales = [0.4, 0.6, 0.8, 1.0, 1.2, 1.4, 1.6, 1.8];
    let sweep = rate_sweep(&sc, &scales, 500)?;

    let mut rows = Vec::new();
    let mut advantage_low = 0.0;
    let mut advantage_high = 0.0;
    for (scale, row) in &sweep {
        let gp = row.cost_of("GP").unwrap();
        let best_other = row
            .costs
            .iter()
            .filter(|(n, _)| *n != "GP")
            .map(|(_, c)| *c)
            .fold(f64::INFINITY, f64::min);
        if (*scale - scales[0]).abs() < 1e-9 {
            advantage_low = best_other / gp;
        }
        if (*scale - scales[scales.len() - 1]).abs() < 1e-9 {
            advantage_high = best_other / gp;
        }
        let mut cells = vec![format!("{scale:.1}")];
        cells.extend(row.costs.iter().map(|(_n, c)| format!("{c:.4}")));
        cells.push(format!("{:.2}x", best_other / gp));
        rows.push(cells);
    }
    print_table(
        "Fig. 6 — total cost vs input-rate scale (Abilene)",
        &["scale", "GP", "SPOC", "LCOF", "LPR-SC", "GP advantage"],
        &rows,
    );
    println!(
        "GP advantage grows with congestion: {advantage_low:.2}x at low load -> \
         {advantage_high:.2}x at high load ({})",
        if advantage_high > advantage_low {
            "matches the paper"
        } else {
            "UNEXPECTED — check scenario"
        }
    );
    Ok(())
}
