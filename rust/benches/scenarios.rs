//! Scenario-engine bench: run the full default matrix in parallel and print
//! the GP-vs-baselines summary table (the numbers future perf/scale PRs
//! report against).
//!
//! ```bash
//! cargo bench --bench scenarios
//! ```

use scfo::bench::{print_table, scenario_summary_rows, SCENARIO_SUMMARY_HEADER};
use scfo::scenarios::{run_batch, RunnerOptions, ScenarioSpec};
use scfo::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let specs = ScenarioSpec::matrix();
    let jobs = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    eprintln!("running {} scenarios on {jobs} workers", specs.len());
    let watch = Stopwatch::start();
    let reports = run_batch(
        &specs,
        &RunnerOptions {
            jobs,
            out_dir: Some(std::path::PathBuf::from("reports/scenarios")),
            quiet: false,
        },
    )?;
    print_table(
        "Scenario engine — GP vs baselines (ratios to GP)",
        &SCENARIO_SUMMARY_HEADER,
        &scenario_summary_rows(&reports),
    );
    let wins = reports.iter().filter(|r| r.gp_within_baselines).count();
    println!(
        "GP within every baseline: {wins}/{} scenarios; wall {:.1}s; reports in reports/scenarios",
        reports.len(),
        watch.elapsed_secs()
    );
    Ok(())
}
