//! Fig. 5 bench: normalized total cost of GP vs SPOC/LCOF/LPR-SC across all
//! Table-II scenarios plus SW-linear and SW-queue.
//!
//! Paper's shape to reproduce: GP lowest everywhere (it is the global
//! optimum); gaps are larger in queue-cost (congestible) scenarios than in
//! the linear SW variant.
//!
//! ```bash
//! cargo bench --bench fig5
//! ```

use scfo::bench::print_table;
use scfo::config::Scenario;
use scfo::graph::topologies::SCENARIO_NAMES;
use scfo::sim::compare_algorithms;

fn main() -> anyhow::Result<()> {
    let mut scenarios: Vec<(Scenario, usize)> = SCENARIO_NAMES
        .iter()
        .map(|n| {
            let iters = if *n == "sw" { 300 } else { 1500 };
            (Scenario::table2(n).unwrap(), iters)
        })
        .collect();
    // the SW row with queue costs is named sw-queue in the figure
    for (sc, _) in scenarios.iter_mut() {
        if sc.name == "sw" {
            sc.name = "sw-queue".into();
        }
    }
    scenarios.push((Scenario::sw_linear(), 150));

    let mut rows = Vec::new();
    let mut gp_wins = true;
    for (sc, iters) in &scenarios {
        let row = compare_algorithms(sc, *iters, 1)?;
        let gp = row.cost_of("GP").unwrap();
        let mut cells = vec![sc.name.clone(), format!("{gp:.3}")];
        for (name, c) in &row.costs {
            if *name == "GP" {
                continue;
            }
            if gp > c + 1e-9 {
                gp_wins = false;
                eprintln!("!! GP lost to {name} on {}", sc.name);
            }
            // ratios far beyond the M/M/1 knee mean the baseline exceeded
            // capacity somewhere: report as saturated (infeasible in the
            // exact queue model — infinite delay)
            let ratio = c / gp;
            cells.push(if ratio > 50.0 {
                "sat(∞)".to_string()
            } else {
                format!("{ratio:.2}x")
            });
        }
        rows.push(cells);
    }
    print_table(
        "Fig. 5 — total cost relative to GP (sat(∞) = exceeds capacity)",
        &["scenario", "GP abs", "SPOC", "LCOF", "LPR-SC"],
        &rows,
    );
    println!("GP best in every scenario: {gp_wins}");
    Ok(())
}
