//! Perf bench: micro-timings of every hot-path component, for the §Perf
//! optimization log in EXPERIMENTS.md.
//!
//! ```bash
//! cargo bench --bench perf_hotpath
//! ```

use scfo::algo::blocked::BlockedSets;
use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::bench::Bench;
use scfo::broadcast::run_broadcast;
use scfo::config::Scenario;
use scfo::flow::FlowState;
use scfo::marginals::Marginals;
use scfo::prelude::*;

fn main() -> anyhow::Result<()> {
    let bench = Bench {
        warmup_iters: 2,
        iters: 10,
    };

    for name in ["abilene", "geant", "sw"] {
        let sc = Scenario::table2(name)?;
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng)?;
        let phi = Strategy::shortest_path_to_dest(&net);
        let fs = FlowState::solve(&net, &phi).unwrap();
        let mg = Marginals::compute(&net, &phi, &fs);

        println!(
            "\n--- {name}: |V|={} |E|={} |S|={} ---",
            net.n(),
            net.m(),
            net.num_stages()
        );
        bench.run(&format!("{name}/flow-solve"), || {
            FlowState::solve(&net, &phi).unwrap().total_cost
        });
        bench.run(&format!("{name}/marginals"), || {
            Marginals::compute(&net, &phi, &fs).d_dt[0][0]
        });
        bench.run(&format!("{name}/blocked-sets"), || {
            BlockedSets::compute(&net, &phi, &mg).is_blocked(0, 0, 0)
        });
        bench.run(&format!("{name}/broadcast-protocol"), || {
            run_broadcast(&net, &phi, &fs).messages
        });
        bench.run(&format!("{name}/gp-full-iteration"), || {
            let mut gp = GradientProjection::with_strategy(
                &net,
                phi.clone(),
                GpOptions {
                    backtrack: false,
                    ..Default::default()
                },
            );
            gp.step(&net).cost
        });
    }

    // PJRT-backed evaluation, if artifacts are present
    if scfo::runtime::artifacts_available() {
        println!("\n--- PJRT (XLA) evaluation path ---");
        for name in ["abilene", "geant", "sw"] {
            let sc = Scenario::table2(name)?;
            let mut rng = Rng::new(sc.seed);
            let net = sc.build(&mut rng)?;
            let rt = scfo::runtime::EvalRuntime::load_for(&net)?;
            let phi = Strategy::shortest_path_to_dest(&net);
            bench.run(
                &format!("{name}/xla-eval (bucket n={})", rt.bucket().n),
                || rt.eval(&net, &phi).unwrap().total_cost,
            );
        }
    } else {
        println!("(artifacts not built; skipping XLA timings)");
    }
    Ok(())
}
