//! Ablation bench — the design choices DESIGN.md calls out:
//!
//! 1. **Step scaling**: paper-exact fixed α vs the diagonally-scaled
//!    (quasi-Newton, after [5]) drain step — slots to reach a cost target.
//! 2. **Zero-traffic snap**: disabling it reproduces the Fig. 4 / Prop. 1
//!    degenerate stall — the condition-(6) residual plateaus.
//! 3. **Blocked node sets**: disabling them forces the loop-safety net to
//!    fire (reverted stages > 0), demonstrating why the protocol needs them.
//!
//! ```bash
//! cargo bench --bench ablation
//! ```

use scfo::algo::gp::{GpOptions, GradientProjection, StepScaling};
use scfo::bench::print_table;
use scfo::config::Scenario;
use scfo::prelude::*;

/// Slots needed to bring the cost within 1% of `target` (cap at `max`).
fn slots_to_target(
    net: &scfo::app::Network,
    opts: GpOptions,
    target: f64,
    max: usize,
) -> usize {
    let mut gp = GradientProjection::new(net, opts);
    for it in 0..max {
        let st = gp.step(net);
        if st.cost <= target * 1.01 {
            return it + 1;
        }
    }
    max
}

fn main() -> anyhow::Result<()> {
    // ---- 1. fixed vs diagonally-scaled steps ------------------------------
    let mut rows = Vec::new();
    for name in ["abilene", "geant", "connected-er"] {
        let sc = Scenario::table2(name)?;
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng)?;
        // reference optimum
        let mut gp = GradientProjection::new(&net, GpOptions::default());
        let opt = gp.run(&net, 4000).final_cost;
        let fixed = slots_to_target(&net, GpOptions::default(), opt, 4000);
        let scaled = slots_to_target(
            &net,
            GpOptions {
                scaling: StepScaling::Diagonal,
                alpha: 0.3,
                ..Default::default()
            },
            opt,
            4000,
        );
        rows.push(vec![
            name.to_string(),
            format!("{opt:.4}"),
            fixed.to_string(),
            scaled.to_string(),
            format!("{:.1}x", fixed as f64 / scaled.max(1) as f64),
        ]);
    }
    print_table(
        "Ablation 1 — slots to reach within 1% of the optimum",
        &["scenario", "optimum", "fixed α (paper)", "diagonal (after [5])", "speedup"],
        &rows,
    );

    // ---- 2. zero-traffic snap off: the Fig. 4 degenerate case -------------
    // Paper's construction: path 0-1-2-3 (cost ρ total) + expensive direct
    // link 0->3 (cost 1); all traffic starts on the direct link, so nodes
    // 1 and 2 carry ZERO traffic. The argmin snap fixes their rows in one
    // slot; without it they drain at rate α·e (the KKT-style update), which
    // may take orders of magnitude longer.
    let fig4 = fig4_net(0.05)?;
    let phi0 = fig4_degenerate_phi(&fig4);
    let target = 0.05; // the optimum ≈ ρ
    let slots_with = {
        let mut gp = GradientProjection::with_strategy(
            &fig4,
            phi0.clone(),
            GpOptions {
                alpha: 0.3,
                ..Default::default()
            },
        );
        let mut slots = 8000;
        for it in 0..8000 {
            if gp.step(&fig4).cost <= target * 1.05 {
                slots = it + 1;
                break;
            }
        }
        slots
    };
    let slots_without = {
        let mut gp = GradientProjection::with_strategy(
            &fig4,
            phi0,
            GpOptions {
                alpha: 0.3,
                ablate_zero_snap: true,
                ..Default::default()
            },
        );
        let mut slots = 8000;
        for it in 0..8000 {
            if gp.step(&fig4).cost <= target * 1.05 {
                slots = it + 1;
                break;
            }
        }
        slots
    };
    print_table(
        "Ablation 2 — zero-traffic argmin snap on the Fig. 4 instance",
        &["variant", "slots to escape the degenerate KKT point (cap 8000)"],
        &[
            vec!["with snap (condition-6 update)".into(), slots_with.to_string()],
            vec!["without snap (KKT-style drain)".into(), slots_without.to_string()],
        ],
    );

    // ---- 3. blocked sets off across scenarios, random starts --------------
    let mut rows3 = Vec::new();
    for name in ["abilene", "geant", "connected-er", "lhc"] {
        let sc = Scenario::table2(name)?;
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng)?;
        let count = |ablate: bool| -> usize {
            let mut total = 0;
            for seed in 0..4u64 {
                let mut r2 = Rng::new(seed);
                let phi0 = Strategy::random_dag(&net, &mut r2);
                let mut gp = GradientProjection::with_strategy(
                    &net,
                    phi0,
                    GpOptions {
                        ablate_blocking: ablate,
                        backtrack: false,
                        ..Default::default()
                    },
                );
                for _ in 0..150 {
                    total += gp.step(&net).reverted_stages;
                }
            }
            total
        };
        rows3.push(vec![
            name.to_string(),
            count(false).to_string(),
            count(true).to_string(),
        ]);
    }
    print_table(
        "Ablation 3 — loop-revert events (600 slots, 4 random starts)",
        &["scenario", "with blocked sets", "without blocked sets"],
        &rows3,
    );
    Ok(())
}

/// The paper's Fig. 4 network with path cost ρ.
fn fig4_net(rho: f64) -> anyhow::Result<scfo::app::Network> {
    use scfo::app::{Application, Network, StageRegistry};
    use scfo::cost::CostFn;
    use scfo::graph::Graph;
    let g = Graph::new(
        4,
        &[(0, 1), (1, 2), (2, 3), (0, 3), (1, 0), (2, 1), (3, 2), (3, 0)],
    )?;
    let apps = vec![Application {
        dest: 3,
        num_tasks: 1,
        packet_sizes: vec![1.0, 1.0],
        input_rates: vec![1.0, 0.0, 0.0, 0.0],
    }];
    let stages = StageRegistry::new(&apps);
    let mut cw = vec![vec![1000.0; 4]; stages.len()];
    for row in &mut cw {
        row[3] = 0.0; // CPU effectively only at the destination
    }
    let mut link_cost = Vec::new();
    for e in 0..g.m() {
        let (i, j) = g.edge(e);
        let d = if (i, j) == (0, 3) { 1.0 } else { rho / 3.0 };
        link_cost.push(CostFn::Linear { d });
    }
    Network::new(g, apps, link_cost, vec![CostFn::Linear { d: 1.0 }; 4], cw)
}

/// The degenerate strategy of Fig. 4: all traffic on the expensive direct
/// link, and the (zero-traffic) intermediate nodes pointing *backwards* so
/// the cheap path looks unattractive through their marginals — a KKT point.
fn fig4_degenerate_phi(net: &scfo::app::Network) -> Strategy {
    let mut phi = Strategy::zeros(&net.graph, 2);
    for s in 0..2 {
        phi.set(s, 0, 3, 1.0);
        phi.set(s, 1, 0, 1.0); // backward
        phi.set(s, 2, 1, 1.0); // backward
    }
    phi.set(0, 3, phi.cpu(), 1.0);
    phi.validate(net).unwrap();
    phi
}
