//! Fig. 7 bench: average hops travelled by data packets (stage 0) and result
//! packets (final stage) under GP, as input packet size L_(a,0) varies.
//!
//! Paper's shape to reproduce: when L_(a,0) is small (inputs cheap to move),
//! data travels far — computation happens near the destination. As L_(a,0)
//! grows, GP offloads closer to the requester: data hops shrink.
//!
//! ```bash
//! cargo bench --bench fig7
//! ```

use scfo::bench::print_table;
use scfo::config::Scenario;
use scfo::sim::packet_size_sweep;

fn main() -> anyhow::Result<()> {
    let sc = Scenario::table2("abilene")?;
    let l0s = [1.0, 2.0, 4.0, 6.0, 8.0, 10.0, 14.0, 20.0];
    let rows_data = packet_size_sweep(&sc, &l0s, 600)?;
    let rows: Vec<Vec<String>> = rows_data
        .iter()
        .map(|r| {
            vec![
                format!("{:.0}", r.l0),
                format!("{:.3}", r.data_hops),
                format!("{:.3}", r.result_hops),
                format!("{:.3}", r.data_hops / r.result_hops.max(1e-9)),
            ]
        })
        .collect();
    print_table(
        "Fig. 7 — avg hop count vs input packet size (GP, Abilene)",
        &["L(a,0)", "data hops", "result hops", "data/result"],
        &rows,
    );
    let first = &rows_data[0];
    let last = &rows_data[rows_data.len() - 1];
    println!(
        "data-hop trend as L(a,0) grows: {:.2} -> {:.2} ({})",
        first.data_hops,
        last.data_hops,
        if last.data_hops < first.data_hops {
            "offloading moves toward the requester — matches the paper"
        } else {
            "UNEXPECTED — check scenario"
        }
    );
    Ok(())
}
