//! Table II bench: builds every scenario, verifies its inventory against the
//! paper's row, and times + reports the GP solve on each.
//!
//! ```bash
//! cargo bench --bench table2
//! ```

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::bench::{print_table, Bench};
use scfo::config::Scenario;
use scfo::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let paper_rows = [
        // name, |V|, undirected |E|, |A|, R
        ("connected-er", 20, 40, 5, 3),
        ("balanced-tree", 15, 14, 5, 3),
        ("fog", 19, 30, 5, 3),
        ("abilene", 11, 14, 3, 3),
        ("lhc", 16, 31, 8, 3),
        ("geant", 22, 33, 10, 5),
        ("sw", 100, 320, 30, 8),
    ];
    let bench = Bench {
        warmup_iters: 0,
        iters: 3,
    };
    let mut rows = Vec::new();
    for (name, v, e, a, r) in paper_rows {
        let sc = Scenario::table2(name)?;
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng)?;
        assert_eq!(net.n(), v, "{name} |V|");
        assert_eq!(net.m(), 2 * e, "{name} |E|");
        assert_eq!(net.apps.len(), a, "{name} |A|");
        let iters = if name == "sw" { 150 } else { 400 };
        let mut final_cost = 0.0;
        let summary = bench.run(&format!("gp-solve/{name}"), || {
            let mut gp = GradientProjection::new(&net, GpOptions::default());
            let rep = gp.run(&net, iters);
            final_cost = rep.final_cost;
            rep.final_cost
        });
        rows.push(vec![
            name.to_string(),
            format!("{v}"),
            format!("{e}"),
            format!("{a}"),
            format!("{r}"),
            format!("{:.4}", final_cost),
            format!("{:.1}ms", summary.mean_s * 1e3),
        ]);
    }
    print_table(
        "Table II scenarios — inventory check + GP solve",
        &["topology", "|V|", "|E|", "|A|", "R", "GP cost", "solve time"],
        &rows,
    );
    Ok(())
}
