//! Detection-parity suite: the column-scan [`AdaptationController`] must be
//! indistinguishable from the per-stream reference formulation of the
//! detector — same detections at the same slots, same z statistics bit for
//! bit, same policy actions — on every cell of the `dynamic` tier, and
//! silent (zero spurious detections) under stationary Poisson traffic at
//! 100k streams.
//!
//! The reference implementation below is an independent vec-of-structs
//! transcription of the detector's published semantics (slow-EWMA anchors
//! with cold start, aggregate gap/variance accumulation, per-stream max
//! |z|, CUSUM on the aggregate, cooldown, re-anchor on fire, warm-start
//! boost scheduling). It shares no code with `serving::adapt`; any drift
//! between the SoA scan and these semantics fails the suite.
//!
//! Each dynamic cell prints one
//! `parity-digest <cell> <z-bits> detections=<k>` line under
//! `SCFO_PARITY_SEED`; the CI `chaos-and-golden` job replays the suite
//! twice per seed and diffs the output (the flakiness gate — see
//! docs/TESTING.md).

use scfo::scenarios::ScenarioSpec;
use scfo::serving::{
    AdaptationController, ControllerOptions, OnlineServer, PolicyAction, ReconvergePolicy,
    ServerOptions, StreamEstimator,
};
use scfo::util::rng::Rng;
use scfo::workload::{Workload, WorkloadSpec};

fn parity_seed() -> u64 {
    std::env::var("SCFO_PARITY_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
}

/// Per-stream detector state, reference (array-of-structs) formulation.
#[derive(Clone, Copy)]
struct RefStream {
    slow: f64,
    seen: bool,
}

/// Independent scalar reimplementation of the detection semantics.
struct RefDetector {
    opts: ControllerOptions,
    fast_ewma: f64,
    slot_secs: f64,
    streams: Vec<RefStream>,
    cusum: f64,
    cooldown_left: usize,
    boost_left: usize,
    slot: usize,
    last_z: f64,
    /// 1-based slots at which a detection fired.
    fire_slots: Vec<usize>,
}

impl RefDetector {
    fn new(opts: ControllerOptions) -> RefDetector {
        RefDetector {
            opts,
            fast_ewma: 0.3,
            slot_secs: 1.0,
            streams: Vec::new(),
            cusum: 0.0,
            cooldown_left: 0,
            boost_left: 0,
            slot: 0,
            last_z: 0.0,
            fire_slots: Vec::new(),
        }
    }

    fn observe(&mut self, observed: &[f64], fast: &[f64]) -> PolicyAction {
        self.slot += 1;
        let n = observed.len();
        if n > self.streams.len() {
            self.streams.resize(
                n,
                RefStream {
                    slow: 0.0,
                    seen: false,
                },
            );
        } else if n < self.streams.len() {
            self.streams.truncate(n);
        }
        let ws = self.opts.slow_ewma;
        let wf = self.fast_ewma;
        let vfactor = wf / (2.0 - wf) + ws / (2.0 - ws);
        let mut gap = 0.0;
        let mut var = 0.0;
        let mut stream_z = 0.0f64;
        for (s, st) in self.streams.iter_mut().enumerate() {
            let obs = observed[s];
            if !st.seen {
                st.slow = obs;
                st.seen = true;
            } else {
                st.slow = (1.0 - ws) * st.slow + ws * obs;
            }
            let g = fast[s] - st.slow;
            let v = vfactor * st.slow.max(1e-9) / self.slot_secs;
            gap += g;
            var += v;
            stream_z = stream_z.max(g.abs() / v.sqrt());
        }
        self.last_z = if var > 0.0 { gap / var.sqrt() } else { 0.0 };
        self.cusum = (self.cusum + self.last_z.abs() - self.opts.cusum_k).max(0.0);
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
        }
        let fired = self.cooldown_left == 0
            && (self.last_z.abs() > self.opts.threshold
                || stream_z > self.opts.threshold
                || self.cusum > self.opts.cusum_h);
        if fired {
            for (st, &f) in self.streams.iter_mut().zip(fast) {
                st.slow = f;
            }
            self.cusum = 0.0;
            self.cooldown_left = self.opts.cooldown;
            self.fire_slots.push(self.slot);
            return match self.opts.policy {
                ReconvergePolicy::ColdRestart => PolicyAction::Restart,
                ReconvergePolicy::WarmStart => {
                    let act = if self.boost_left == 0 {
                        PolicyAction::ScaleStep(self.opts.alpha_boost)
                    } else {
                        PolicyAction::None
                    };
                    self.boost_left = self.opts.boost_slots;
                    act
                }
            };
        }
        if self.boost_left > 0 {
            self.boost_left -= 1;
            if self.boost_left == 0 {
                return PolicyAction::ScaleStep(1.0 / self.opts.alpha_boost);
            }
        }
        PolicyAction::None
    }
}

/// Drive both detectors over `slots` batched serving slots of `wl`,
/// asserting per-slot action and z-bit parity; returns the FNV-1a fold of
/// the z series plus the detection count (for the digest line).
fn run_parity(cell: &str, wl: &mut Workload, slots: usize) -> (u64, usize) {
    let mut est = StreamEstimator::new(1.0, 0.3);
    let mut ctrl = AdaptationController::new(ControllerOptions::default());
    let mut refd = RefDetector::new(ControllerOptions::default());
    let mut acc: u64 = 0xcbf29ce484222325;
    for slot in 0..slots {
        wl.sample_slot();
        let (obs, fast) = est.update(wl);
        let a = ctrl.observe(obs, fast);
        let b = refd.observe(obs, fast);
        assert_eq!(a, b, "{cell}: action diverges at slot {slot}");
        assert_eq!(
            ctrl.last_z.to_bits(),
            refd.last_z.to_bits(),
            "{cell}: z statistic diverges at slot {slot} ({} vs {})",
            ctrl.last_z,
            refd.last_z
        );
        acc = (acc ^ ctrl.last_z.to_bits()).wrapping_mul(0x100000001b3);
    }
    let fired: Vec<usize> = ctrl.events().iter().map(|e| e.slot).collect();
    assert_eq!(
        fired, refd.fire_slots,
        "{cell}: detection slots diverge from the reference detector"
    );
    (acc, fired.len())
}

/// Every dynamic-tier cell: column scan == per-stream reference, slot for
/// slot, bit for bit. At least one cell must actually detect something, so
/// the parity claim is not vacuously true.
#[test]
fn column_scan_matches_reference_on_full_dynamic_tier() {
    let seed = parity_seed();
    let mut total_detections = 0usize;
    for spec in ScenarioSpec::dynamic_matrix_sized(60) {
        let sc = spec.effective_base();
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng).unwrap();
        let wspec = spec.workload.as_ref().expect("dynamic cells carry workloads");
        let mut wl =
            Workload::from_spec(wspec, &net, 1.0, sc.seed.wrapping_add(seed)).unwrap();
        assert!(wl.enable_batching(), "{}: dynamic workloads batch", spec.name());
        let (digest, detections) = run_parity(spec.name(), &mut wl, spec.slots);
        total_detections += detections;
        println!("parity-digest {} {digest:016x} detections={detections}", spec.name());
    }
    assert!(
        total_detections >= 1,
        "no dynamic cell fired — parity test is vacuous"
    );
}

/// Stationary null at massive scale: 100,000 Poisson streams on the
/// massive tier's er-1000-4000 network, batched, must produce zero
/// spurious detections — and the column scan must still match the
/// reference exactly at this width.
#[test]
fn stationary_null_is_silent_at_100k_streams() {
    let spec = ScenarioSpec::massive_matrix_sized(100, 1000, 30)
        .pop()
        .expect("massive matrix has one spec");
    let sc = spec.effective_base();
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng).unwrap();
    let mut wl =
        Workload::from_spec(&WorkloadSpec::named("poisson").unwrap(), &net, 1.0, sc.seed)
            .unwrap();
    assert_eq!(wl.streams.len(), 100_000, "100 apps x 1000 sources");
    assert!(wl.enable_batching());
    let (digest, detections) = run_parity("stationary-null-100k", &mut wl, 30);
    assert_eq!(
        detections, 0,
        "controller fired under stationary Poisson traffic at 100k streams"
    );
    println!("parity-digest stationary-null-100k {digest:016x} detections=0");
}

/// The full serving loop with the column controller attached is bit
/// deterministic — identical detection slots and an identical per-slot
/// regret series across independent runs — and its detections agree with
/// the reference detector fed by a twin workload + estimator pipeline.
#[test]
fn serving_regret_series_is_bit_deterministic_and_reference_consistent() {
    let spec = ScenarioSpec::dynamic_matrix_sized(60)
        .into_iter()
        .find(|s| s.name() == "abilene-flash-crowd")
        .expect("dynamic tier has the abilene flash-crowd cell");
    let sc = spec.effective_base();
    let wspec = spec.workload.as_ref().unwrap();
    let serve = || -> (Vec<usize>, Vec<u64>) {
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng).unwrap();
        let mut wl = Workload::from_spec(wspec, &net, 1.0, sc.seed).unwrap();
        assert!(wl.enable_batching());
        let gp = scfo::algo::gp::GradientProjection::new(&net, scfo::algo::gp::GpOptions::default());
        let mut srv = OnlineServer::with_workload(net, gp, wl, ServerOptions::default());
        srv.attach_controller(AdaptationController::new(ControllerOptions::default()));
        srv.run(spec.slots).unwrap();
        let ctrl = srv.controller.as_ref().unwrap();
        (
            ctrl.events().iter().map(|e| e.slot).collect(),
            ctrl.regrets().iter().map(|r| r.to_bits()).collect(),
        )
    };
    let (events_a, regrets_a) = serve();
    let (events_b, regrets_b) = serve();
    assert_eq!(events_a, events_b, "detection slots must be run-to-run identical");
    assert_eq!(regrets_a, regrets_b, "regret series must be bit-identical across runs");
    assert_eq!(regrets_a.len(), spec.slots, "one regret sample per served slot");

    // twin pipeline: same seed, estimator + reference detector only — the
    // serving loop's detections must be exactly these
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng).unwrap();
    let mut wl = Workload::from_spec(wspec, &net, 1.0, sc.seed).unwrap();
    assert!(wl.enable_batching());
    let mut est = StreamEstimator::new(1.0, 0.3);
    let mut refd = RefDetector::new(ControllerOptions::default());
    for _ in 0..spec.slots {
        wl.sample_slot();
        let (obs, fast) = est.update(&wl);
        let _ = refd.observe(obs, fast);
    }
    assert_eq!(
        events_a, refd.fire_slots,
        "served detections must match the offline reference detector"
    );
}
