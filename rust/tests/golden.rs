//! Golden-file regression tests: fixed-seed scenario reports, one per tier
//! (default, large, dynamic, distributed, churn, topo-churn, massive, ha,
//! dnn), compared
//! against the committed files under `rust/tests/golden/` with a
//! tolerance-aware JSON comparator.
//!
//! * `SCFO_BLESS=1 cargo test --test golden` regenerates the files;
//! * an existing golden is compared strictly — any drift fails;
//! * a missing golden is NOT silently bootstrapped: under
//!   `SCFO_GOLDEN_REQUIRE=1` (CI's strict pass, run after its bless pass)
//!   the test fails, otherwise it warns and passes so a fresh checkout
//!   stays green until the blessed fixtures are committed;
//! * numbers compare with relative tolerance 1e-9; volatile keys
//!   (wall-clock timings, cache bits, RSS) are skipped.
//!
//! CI runs bless → strict (`SCFO_GOLDEN_REQUIRE=1`) → `git status` on
//! `rust/tests/golden/`, so both nondeterminism between the two runs and
//! drift against the committed fixtures gate the build. Policy and
//! blessing workflow: `docs/TESTING.md`.

use scfo::prelude::*;
use scfo::scenarios::{runner, DistributedSpec};
use scfo::util::json::Json;

/// Keys whose values are wall-clock / environment dependent.
const VOLATILE_KEYS: [&str; 19] = [
    "solve_secs",
    "cache_hit",
    "build_secs",
    "iter_secs",
    "iter_secs_samples",
    "peak_rss_bytes",
    "convergence_secs",
    "admission_latency_secs_mean",
    "admission_latency_secs_p95",
    "rebind_secs_mean",
    "slot_wall_ms_mean",
    "slot_wall_ms_max",
    "streams_per_sec",
    "phase_sample_ms_mean",
    "phase_estimate_ms_mean",
    "phase_detect_ms_mean",
    "election_secs",
    "failover_secs",
    "commands_per_sec",
];

const REL_TOL: f64 = 1e-9;

/// Structural JSON comparison with numeric tolerance; returns the list of
/// mismatches as `path: detail` lines.
fn diff_json(path: &str, want: &Json, got: &Json, out: &mut Vec<String>) {
    match (want, got) {
        (Json::Num(a), Json::Num(b)) => {
            let tol = REL_TOL * (1.0 + a.abs());
            if (a - b).abs() > tol && !(a.is_nan() && b.is_nan()) {
                out.push(format!("{path}: {a} != {b} (tol {tol:.1e})"));
            }
        }
        (Json::Obj(a), Json::Obj(b)) => {
            for (k, va) in a {
                if VOLATILE_KEYS.contains(&k.as_str()) {
                    continue;
                }
                match b.get(k) {
                    Some(vb) => diff_json(&format!("{path}.{k}"), va, vb, out),
                    None => out.push(format!("{path}.{k}: missing in new report")),
                }
            }
            for k in b.keys() {
                if !a.contains_key(k) && !VOLATILE_KEYS.contains(&k.as_str()) {
                    out.push(format!("{path}.{k}: new key not in golden"));
                }
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                out.push(format!("{path}: length {} != {}", a.len(), b.len()));
                return;
            }
            for (i, (va, vb)) in a.iter().zip(b).enumerate() {
                diff_json(&format!("{path}[{i}]"), va, vb, out);
            }
        }
        (a, b) => {
            if a != b {
                out.push(format!("{path}: {a:?} != {b:?}"));
            }
        }
    }
}

fn golden_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// Zero out volatile values before writing a fixture, so blessed goldens
/// are byte-stable across machines and reruns — the CI drift gate
/// re-blesses into the checkout and then `git status`es the golden dir,
/// which only works if nothing wall-clock-dependent reaches the file.
fn normalize(v: &Json) -> Json {
    match v {
        Json::Obj(map) => Json::Obj(
            map.iter()
                .map(|(k, val)| {
                    let nv = if VOLATILE_KEYS.contains(&k.as_str()) {
                        Json::Num(0.0)
                    } else {
                        normalize(val)
                    };
                    (k.clone(), nv)
                })
                .collect(),
        ),
        Json::Arr(items) => Json::Arr(items.iter().map(normalize).collect()),
        other => other.clone(),
    }
}

/// Compare `actual` against `tests/golden/<name>.json`.
///
/// `SCFO_BLESS=1` rewrites the file. An existing file is compared
/// strictly. A missing file is never written implicitly (no bootstrap
/// fallback): it fails under `SCFO_GOLDEN_REQUIRE=1` and warns otherwise.
fn check_golden(name: &str, actual: &Json) {
    let path = golden_dir().join(format!("{name}.json"));
    let bless = std::env::var("SCFO_BLESS").map(|v| v == "1").unwrap_or(false);
    if bless {
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, normalize(actual).to_string_pretty()).unwrap();
        eprintln!("golden '{name}': blessed {}", path.display());
        return;
    }
    if !path.exists() {
        let require = std::env::var("SCFO_GOLDEN_REQUIRE")
            .map(|v| v == "1")
            .unwrap_or(false);
        assert!(
            !require,
            "golden '{name}' missing at {} — run `SCFO_BLESS=1 cargo test --test golden` \
             and commit the file",
            path.display()
        );
        eprintln!(
            "golden '{name}': missing — passing with a warning (SCFO_GOLDEN_REQUIRE=1 \
             enforces, SCFO_BLESS=1 generates)"
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let want = Json::parse(&text).unwrap_or_else(|e| panic!("unparseable golden {name}: {e}"));
    let mut diffs = Vec::new();
    diff_json(name, &want, actual, &mut diffs);
    assert!(
        diffs.is_empty(),
        "golden '{name}' mismatch ({} diffs) — intentional change? rerun with SCFO_BLESS=1 \
         and commit the updated golden:\n{}",
        diffs.len(),
        diffs.join("\n")
    );
}

// ---- one scenario per tier ------------------------------------------------

/// Default tier: abilene at light congestion with the standard (shrunk)
/// event schedule.
#[test]
fn golden_default_tier_abilene() {
    let mut spec = ScenarioSpec::named("abilene", Congestion::Light).unwrap();
    spec.iters = 120;
    spec.events = vec![
        DynamicEvent::RateScale {
            factor: 1.3,
            iters: 80,
        },
        DynamicEvent::LinkDown { iters: 80 },
        DynamicEvent::LinkUp { iters: 80 },
    ];
    let rep = runner::run_one(&spec, &runner::ScenarioCache::new()).unwrap();
    check_golden("default-abilene-light", &rep.to_json());
}

/// Large tier: the er-1000-4000 GP hot path (bench form — cost trajectory,
/// arena shape; timings are volatile and skipped).
#[test]
fn golden_large_tier_er_1000_4000() {
    let res = scfo::bench::bench_gp_scenario("er-1000-4000", 10).unwrap();
    check_golden("large-er-1000-4000", &res.to_json());
}

/// Dynamic tier: abilene under the flash-crowd workload with the adaptation
/// controller (regret/reconvergence columns).
#[test]
fn golden_dynamic_tier_flash_crowd() {
    let mut spec = ScenarioSpec::named("abilene", Congestion::Nominal).unwrap();
    spec.base.name = "abilene-flash-crowd".to_string();
    spec.events.clear();
    spec.iters = 150;
    spec.slots = 60;
    spec.workload = Some(WorkloadSpec::named("flash-crowd").unwrap());
    let rep = runner::run_one(&spec, &runner::ScenarioCache::new()).unwrap();
    check_golden("dynamic-abilene-flash-crowd", &rep.to_json());
}

/// Distributed tier: abilene through the async runtime under the lossy
/// fault spec (rounds/messages/bytes/stale-reads columns).
#[test]
fn golden_distributed_tier_abilene_lossy() {
    let mut spec = ScenarioSpec::named("abilene", Congestion::Nominal).unwrap();
    spec.base.name = "abilene-dist-lossy".to_string();
    spec.events.clear();
    spec.iters = 800;
    spec.distributed = Some(DistributedSpec {
        shards: 2,
        faults: scfo::distributed::FaultSpec::lossy(spec.base.seed),
        max_epochs: 4000,
    });
    let rep = runner::run_one(&spec, &runner::ScenarioCache::new()).unwrap();
    check_golden("distributed-abilene-lossy", &rep.to_json());
}

/// Churn (control-plane) tier: abilene at light congestion serving the
/// default scripted app arrival/departure schedule; pins admission
/// outcomes, epoch count and the reconvergence spans.
#[test]
fn golden_churn_tier_abilene() {
    let mut spec = scfo::scenarios::ScenarioSpec::churn_matrix_sized(80)
        .into_iter()
        .find(|s| s.base.topology == "abilene")
        .expect("churn matrix covers abilene");
    spec.iters = 120;
    let rep = runner::run_one(&spec, &runner::ScenarioCache::new()).unwrap();
    check_golden("churn-abilene-light", &rep.to_json());
}

/// Topology-churn tier: er-20-40 under the default flap schedule; pins the
/// epoch-rebuild count, removed-pair totals, the warm/cold reconvergence
/// spans and the retained-optimality columns (rebind wall time is
/// volatile and skipped).
#[test]
fn golden_topo_churn_tier_er_20_40() {
    let mut spec = ScenarioSpec::named("er-20-40", Congestion::Nominal).unwrap();
    spec.base.name = "er-20-40-topo-churn".to_string();
    spec.events.clear();
    spec.iters = 150;
    spec.slots = 60;
    spec.topo_churn = Some(scfo::topo::TopoChurnSpec::default_schedule(60));
    let rep = runner::run_one(&spec, &runner::ScenarioCache::new()).unwrap();
    check_golden("topo-churn-er-20-40", &rep.to_json());
}

/// Massive tier: a sized-down stream table (same er-1000-4000 family and
/// batched SoA hot loop as the million-stream run) pinning stream count,
/// arrivals, detections and offered load; the slot wall-time and
/// streams/sec columns are volatile and skipped.
#[test]
fn golden_massive_tier_er_1000_4000() {
    let spec = ScenarioSpec::massive_matrix_sized(8, 100, 15)
        .pop()
        .expect("massive matrix has one spec");
    let rep = runner::run_one(&spec, &runner::ScenarioCache::new()).unwrap();
    check_golden("massive-er-1000-4000", &rep.to_json());
}

/// HA (replicated control plane) tier: the abilene clean-fabric cell —
/// elect, register burst, leader kill, failover — pinning commit indices,
/// tick counts, fabric counters and the survivor's catalog/epoch state;
/// election/failover wall times and commands/sec are volatile and skipped.
#[test]
fn golden_ha_tier_abilene_clean() {
    let mut spec = ScenarioSpec::ha_matrix_sized(20, 3)
        .into_iter()
        .find(|s| s.name().ends_with("clean"))
        .expect("ha matrix covers the clean preset");
    spec.iters = 120;
    let rep = runner::run_one(&spec, &runner::ScenarioCache::new()).unwrap();
    check_golden("ha-abilene-clean", &rep.to_json());
}

/// DNN (generalized chain) tier: the abilene/vgg16 heavy-congestion cell —
/// per-stage data inflation plus the result-return flow served under a
/// flash-crowd workload — pinning the served GP cost trajectory and the
/// baseline comparison on the generalized cost (wall time is volatile and
/// skipped).
#[test]
fn golden_dnn_tier_abilene_vgg16_heavy() {
    let spec = ScenarioSpec::dnn_matrix_sized(20, 60)
        .into_iter()
        .find(|s| s.name() == "abilene-dnn-vgg16-heavy")
        .expect("dnn matrix covers the abilene vgg16 heavy cell");
    let rep = runner::run_one(&spec, &runner::ScenarioCache::new()).unwrap();
    check_golden("dnn-abilene-vgg16-heavy", &rep.to_json());
}

// ---- comparator self-tests ------------------------------------------------

#[test]
fn comparator_tolerates_jitter_and_flags_real_diffs() {
    let want = Json::parse(r#"{"a": 1.0, "b": [1.0, 2.0], "solve_secs": 9.0, "s": "x"}"#).unwrap();
    let close = Json::parse(r#"{"a": 1.0000000000001, "b": [1.0, 2.0], "solve_secs": 1.0, "s": "x"}"#)
        .unwrap();
    let mut diffs = Vec::new();
    diff_json("t", &want, &close, &mut diffs);
    assert!(diffs.is_empty(), "{diffs:?}");

    let wrong = Json::parse(r#"{"a": 1.1, "b": [1.0], "solve_secs": 9.0, "s": "y"}"#).unwrap();
    let mut diffs = Vec::new();
    diff_json("t", &want, &wrong, &mut diffs);
    assert_eq!(diffs.len(), 3, "{diffs:?}"); // a off, b length, s string
}

#[test]
fn normalize_zeroes_volatile_keys_only() {
    let v = Json::parse(
        r#"{"a": 1.5, "solve_secs": 3.25, "nest": {"iter_secs": {"mean": 2.0}, "b": 7.0}}"#,
    )
    .unwrap();
    let n = normalize(&v);
    assert_eq!(n.get("a").unwrap().as_f64(), Some(1.5));
    assert_eq!(n.get("solve_secs").unwrap().as_f64(), Some(0.0));
    let nest = n.get("nest").unwrap();
    assert_eq!(nest.get("iter_secs").unwrap().as_f64(), Some(0.0));
    assert_eq!(nest.get("b").unwrap().as_f64(), Some(7.0));
}

#[test]
fn comparator_reports_missing_and_extra_keys() {
    let want = Json::parse(r#"{"a": 1.0, "b": 2.0}"#).unwrap();
    let got = Json::parse(r#"{"a": 1.0, "c": 3.0}"#).unwrap();
    let mut diffs = Vec::new();
    diff_json("t", &want, &got, &mut diffs);
    assert_eq!(diffs.len(), 2, "{diffs:?}");
    assert!(diffs.iter().any(|d| d.contains("t.b")));
    assert!(diffs.iter().any(|d| d.contains("t.c")));
}
