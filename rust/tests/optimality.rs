//! Theorem-level integration tests: Theorem 1 (sufficiency of condition 6),
//! Theorem 2 (convergence), Proposition 1 / Fig. 4 (KKT insufficiency), and
//! global-optimality cross-checks against exhaustive search on tiny nets.

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::app::{Application, Network, StageRegistry};
use scfo::cost::CostFn;
use scfo::flow::FlowState;
use scfo::graph::Graph;
use scfo::prelude::*;
use scfo::util::rng::Rng;

/// Tiny diamond network where the optimum can be found by brute force over a
/// fine grid of the only two free variables: split at node 0 between the two
/// paths, and offload location.
fn diamond_net() -> Network {
    let g = Graph::bidirected(4, &[(0, 1), (1, 3), (0, 2), (2, 3)]).unwrap();
    let apps = vec![Application {
        dest: 3,
        num_tasks: 1,
        packet_sizes: vec![4.0, 1.0],
        input_rates: vec![2.0, 0.0, 0.0, 0.0],
    }];
    let stages = StageRegistry::new(&apps);
    let cw = vec![vec![1.0; 4]; stages.len()];
    Network::new(
        g.clone(),
        apps,
        vec![CostFn::Queue { cap: 12.0 }; g.m()],
        vec![CostFn::Queue { cap: 6.0 }; 4],
        cw,
    )
    .unwrap()
}

/// Brute force: data splits x to path 0-1-3 and 1-x to 0-2-3; each unit is
/// computed at the middle node of its path (1 or 2) with fraction y_i, or at
/// dest 3. Exhaustive over a grid, exploiting symmetry of the diamond.
fn diamond_brute_force() -> f64 {
    let net = diamond_net();
    let mut best = f64::INFINITY;
    let steps = 60;
    for xi in 0..=steps {
        let x = xi as f64 / steps as f64;
        for y1i in 0..=steps {
            let y1 = y1i as f64 / steps as f64;
            for y2i in 0..=steps {
                let y2 = y2i as f64 / steps as f64;
                let mut phi = Strategy::zeros(&net.graph, 2);
                // stage 0
                phi.set(0, 0, 1, x);
                phi.set(0, 0, 2, 1.0 - x);
                phi.set(0, 1, phi.cpu(), y1);
                phi.set(0, 1, 3, 1.0 - y1);
                phi.set(0, 2, phi.cpu(), y2);
                phi.set(0, 2, 3, 1.0 - y2);
                phi.set(0, 3, phi.cpu(), 1.0);
                // stage 1: forward results to dest
                phi.set(1, 0, 1, 1.0); // unused (no stage-1 traffic at 0)
                phi.set(1, 1, 3, 1.0);
                phi.set(1, 2, 3, 1.0);
                if phi.validate(&net).is_err() {
                    continue;
                }
                if let Ok(fs) = FlowState::solve(&net, &phi) {
                    best = best.min(fs.total_cost);
                }
            }
        }
    }
    best
}

#[test]
fn gp_matches_brute_force_on_diamond() {
    let net = diamond_net();
    let mut gp = GradientProjection::new(&net, GpOptions::default());
    let rep = gp.run(&net, 5000);
    let brute = diamond_brute_force();
    // GP searches the full space (incl. computing at node 0 and mixed
    // paths), so it may only be BETTER than the restricted brute force.
    assert!(
        rep.final_cost <= brute + 2e-3,
        "GP {} worse than brute-force {brute}",
        rep.final_cost
    );
}

#[test]
fn theorem2_convergence_from_many_starts() {
    // Theorem 2: from any feasible loop-free start, Algorithm 1 converges;
    // Theorem 1: the limit is globally optimal — so all limits must agree.
    let net = diamond_net();
    let mut costs = Vec::new();
    for seed in 0..8 {
        let mut rng = Rng::new(seed);
        let phi0 = Strategy::random_dag(&net, &mut rng);
        let mut gp = GradientProjection::with_strategy(&net, phi0, GpOptions::default());
        costs.push(gp.run(&net, 5000).final_cost);
    }
    let lo = costs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = costs.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        (hi - lo) / lo < 1e-4,
        "limits disagree across starts: {costs:?}"
    );
}

#[test]
fn proposition1_kkt_point_is_arbitrarily_suboptimal() {
    // Fig. 4 construction: for rho -> 0 the degenerate KKT point has cost 1
    // while the optimum has cost rho. Verify the ratio is unbounded by
    // checking two rho values, and that GP escapes to the optimum.
    for rho in [0.1, 0.001] {
        let g = Graph::new(
            4,
            &[(0, 1), (1, 2), (2, 3), (0, 3), (1, 0), (2, 1), (3, 2), (3, 0)],
        )
        .unwrap();
        let apps = vec![Application {
            dest: 3,
            num_tasks: 1,
            packet_sizes: vec![1.0, 1.0],
            input_rates: vec![1.0, 0.0, 0.0, 0.0],
        }];
        let stages = StageRegistry::new(&apps);
        let mut cw = vec![vec![1000.0; 4]; stages.len()];
        for row in &mut cw {
            row[3] = 0.0;
        }
        let mut link_cost = Vec::new();
        for e in 0..g.m() {
            let (i, j) = g.edge(e);
            let d = if (i, j) == (0, 3) { 1.0 } else { rho / 3.0 };
            link_cost.push(CostFn::Linear { d });
        }
        let net = Network::new(
            g,
            apps,
            link_cost,
            vec![CostFn::Linear { d: 1.0 }; 4],
            cw,
        )
        .unwrap();

        // The degenerate strategy (all on the direct link) costs 1:
        let mut phi_kkt = Strategy::zeros(&net.graph, 2);
        for s in 0..2 {
            phi_kkt.set(s, 0, 3, 1.0);
            phi_kkt.set(s, 1, 2, 1.0);
            phi_kkt.set(s, 2, 3, 1.0);
        }
        phi_kkt.set(0, 3, phi_kkt.cpu(), 1.0);
        phi_kkt.set(1, 1, 2, 1.0);
        let kkt_cost = FlowState::solve(&net, &phi_kkt).unwrap().total_cost;
        assert!((kkt_cost - 1.0).abs() < 1e-9);

        // GP from that degenerate point reaches ~rho:
        let mut gp = GradientProjection::with_strategy(
            &net,
            phi_kkt,
            GpOptions {
                alpha: 0.3,
                ..Default::default()
            },
        );
        let rep = gp.run(&net, 8000);
        assert!(
            rep.final_cost < rho * 1.05 + 1e-6,
            "rho={rho}: GP stuck at {} (optimum {rho})",
            rep.final_cost
        );
        // ratio D(phi*)/D(phi_kkt) = rho -> unbounded suboptimality
    }
}

#[test]
fn sufficiency_condition_implies_no_better_neighbor() {
    // At the GP limit, perturbing any single row toward any direction must
    // not reduce cost (local check of global optimality).
    let net = diamond_net();
    let mut gp = GradientProjection::new(&net, GpOptions::default());
    let rep = gp.run(&net, 5000);
    assert!(rep.converged);
    let base = rep.final_cost;
    let n = net.n();
    for s in 0..net.num_stages() {
        for i in 0..n {
            let row_sum: f64 = gp.phi.row(s, i).iter().sum();
            if row_sum < 0.5 {
                continue; // exit row
            }
            for j in 0..=n {
                // shift 1% of the row mass onto direction j
                let mut cand = gp.phi.clone();
                let eps = 0.01;
                let ok = j == n || net.graph.has_edge(i, j);
                if !ok || (j == n && net.is_final_stage(s)) {
                    continue;
                }
                for v in cand.row_mut(s, i).iter_mut() {
                    *v *= 1.0 - eps;
                }
                cand.set(s, i, j, cand.get(s, i, j) + eps);
                if cand.has_loop() {
                    continue;
                }
                let c = FlowState::solve(&net, &cand).unwrap().total_cost;
                assert!(
                    c >= base - 1e-7,
                    "perturbation (s={s},i={i},j={j}) improved {base} -> {c}"
                );
            }
        }
    }
}
