//! Observability-surface acceptance tests: a short serve loop behind the
//! HTTP ops API, with `/metrics` checked by an in-test Prometheus
//! exposition-format validator (family grouping, `# HELP`/`# TYPE`
//! headers, gauge + bucketed-histogram families) and `/profile` checked as
//! well-formed Chrome trace-event JSON (monotone timestamps, matched `B`/`E`
//! pairs per thread lane).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;

use scfo::control::{AppSpec, AppStatus, ControlOptions, ControlPlane, OpsServer};
use scfo::scenarios::{Congestion, ScenarioSpec};
use scfo::util::json::Json;

fn light_plane() -> ControlPlane {
    let spec = ScenarioSpec::named("abilene", Congestion::Light).unwrap();
    ControlPlane::new(spec.effective_base(), ControlOptions::default()).unwrap()
}

/// Issue one HTTP request from a helper thread while the main thread polls
/// the ops server (the production single-threaded poll loop).
fn http_request(
    srv: &OpsServer,
    plane: &mut ControlPlane,
    method: &str,
    path: &str,
) -> (u16, String) {
    let addr = srv.local_addr();
    let request =
        format!("{method} {path} HTTP/1.1\r\nHost: scfo\r\nContent-Length: 0\r\n\r\n");
    let handle = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("connect ops API");
        stream.write_all(request.as_bytes()).unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        response
    });
    let response = loop {
        srv.poll(plane, None::<&std::path::Path>);
        if handle.is_finished() {
            break handle.join().unwrap();
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    };
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let body = response
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

/// Family of a sample line's metric name: strip labels, then the
/// histogram-series suffixes.
fn family_of_sample(name: &str) -> String {
    let bare = name.split('{').next().unwrap_or(name);
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(stripped) = bare.strip_suffix(suffix) {
            return stripped.to_string();
        }
    }
    bare.to_string()
}

#[derive(Debug, Default)]
struct Exposition {
    /// family → declared kind (counter|gauge|histogram)
    types: BTreeMap<String, String>,
    /// family → number of `# TYPE` lines seen (strict scrapers want 1)
    type_lines: BTreeMap<String, usize>,
    /// families with a `# HELP` line
    helps: BTreeMap<String, usize>,
    /// sample name (with labels) → value
    samples: Vec<(String, f64)>,
}

/// Parse a Prometheus text-exposition document, panicking on any
/// malformed line; mirrors what a strict scraper would enforce.
fn parse_exposition(text: &str) -> Exposition {
    let mut e = Exposition::default();
    let mut current_family: Option<String> = None;
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.splitn(2, ' ');
            let family = it.next().expect("TYPE family").to_string();
            let kind = it.next().expect("TYPE kind").to_string();
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind.as_str()),
                "unknown kind in {line:?}"
            );
            if let Some(prev) = e.types.insert(family.clone(), kind.clone()) {
                assert_eq!(prev, kind, "family {family} re-declared with a new kind");
            }
            *e.type_lines.entry(family.clone()).or_default() += 1;
            current_family = Some(family);
        } else if let Some(rest) = line.strip_prefix("# HELP ") {
            let family = rest.split(' ').next().expect("HELP family").to_string();
            assert!(
                rest.len() > family.len() + 1,
                "HELP without text: {line:?}"
            );
            *e.helps.entry(family).or_default() += 1;
        } else if let Some(rest) = line.strip_prefix('#') {
            panic!("unexpected comment line: #{rest}");
        } else {
            let (name, value) = line.rsplit_once(' ').expect("sample line");
            let value: f64 = value.parse().unwrap_or_else(|_| {
                panic!("unparseable sample value in {line:?}")
            });
            let family = family_of_sample(name);
            assert_eq!(
                current_family.as_deref(),
                Some(family.as_str()),
                "sample {name} not grouped under its family header \
                 (current: {current_family:?})"
            );
            // label syntax sanity: balanced braces, quoted values
            if let Some(idx) = name.find('{') {
                assert!(name.ends_with('}'), "unterminated labels in {name}");
                let body = &name[idx + 1..name.len() - 1];
                for pair in body.split("\",") {
                    let pair = pair.trim_end_matches('"');
                    let (k, v) = pair.split_once("=\"").unwrap_or_else(|| {
                        panic!("malformed label pair {pair:?} in {name}")
                    });
                    assert!(!k.is_empty() && !v.contains('\n'), "bad label {k}={v}");
                }
            }
            e.samples.push((name.to_string(), value));
        }
    }
    e
}

impl Exposition {
    fn sample(&self, name: &str) -> Option<f64> {
        self.samples
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
    }

    /// Validate one bucketed histogram family end to end: cumulative
    /// monotone `_bucket` series ending at `+Inf`, with `_sum` and a
    /// `_count` equal to the `+Inf` bucket. `label` selects one series
    /// (e.g. `phase="sample",` or "" for unlabeled).
    fn check_histogram(&self, family: &str, label: &str) {
        assert_eq!(
            self.types.get(family).map(String::as_str),
            Some("histogram"),
            "{family} must be declared a histogram"
        );
        let prefix = format!("{family}_bucket{{{label}le=\"");
        let buckets: Vec<f64> = self
            .samples
            .iter()
            .filter(|(n, _)| n.starts_with(&prefix))
            .map(|(_, v)| *v)
            .collect();
        assert!(
            buckets.len() >= 2,
            "{family}{{{label}}} needs buckets, found {}",
            buckets.len()
        );
        assert!(
            buckets.windows(2).all(|w| w[0] <= w[1]),
            "{family}{{{label}}} buckets are not cumulative: {buckets:?}"
        );
        let inf = self
            .sample(&format!("{family}_bucket{{{label}le=\"+Inf\"}}"))
            .expect("+Inf bucket");
        let count_name = if label.is_empty() {
            format!("{family}_count")
        } else {
            format!("{family}_count{{{}}}", label.trim_end_matches(','))
        };
        let sum_name = if label.is_empty() {
            format!("{family}_sum")
        } else {
            format!("{family}_sum{{{}}}", label.trim_end_matches(','))
        };
        let count = self.sample(&count_name).expect("histogram _count");
        assert_eq!(count, inf, "{family}: _count != +Inf bucket");
        assert!(
            self.sample(&sum_name).is_some(),
            "{family}: missing {sum_name}"
        );
    }
}

#[test]
fn metrics_surface_passes_exposition_validation() {
    let mut plane = light_plane();
    // a short serve loop + one admission so every surface has data
    for _ in 0..5 {
        plane.run_slot().unwrap();
    }
    let app = AppSpec {
        id: "obs-app".into(),
        dest: 4,
        num_tasks: 2,
        packet_sizes: vec![10.0, 5.0, 1.0],
        rates: vec![(0, 0.2)],
        status: AppStatus::Active,
    };
    assert!(plane.register(app).unwrap().accepted());
    plane.run_slot().unwrap();

    let srv = OpsServer::bind("127.0.0.1:0").unwrap();
    let (code, body) = http_request(&srv, &mut plane, "GET", "/metrics");
    assert_eq!(code, 200);
    let e = parse_exposition(&body);

    // strict grouping: exactly one TYPE header per family, HELP for each
    for (family, n) in &e.type_lines {
        assert_eq!(*n, 1, "family {family} re-emits its # TYPE header");
        assert!(
            e.helps.get(family).is_some(),
            "family {family} has no # HELP line"
        );
    }

    // ≥ 1 gauge family with a live sample
    let gauges: Vec<&String> = e
        .types
        .iter()
        .filter(|(_, k)| k.as_str() == "gauge")
        .map(|(f, _)| f)
        .collect();
    assert!(!gauges.is_empty(), "no gauge families in:\n{body}");
    assert_eq!(e.sample("scfo_epoch"), Some(1.0), "one admission commit");
    assert_eq!(
        e.sample("scfo_apps_total"),
        Some(plane.catalog.len() as f64)
    );

    // ≥ 2 bucketed histogram families, each fully formed
    let histograms: Vec<&String> = e
        .types
        .iter()
        .filter(|(_, k)| k.as_str() == "histogram")
        .map(|(f, _)| f)
        .collect();
    assert!(
        histograms.len() >= 2,
        "need >= 2 histogram families, got {histograms:?}"
    );
    e.check_histogram("scfo_admission_latency_seconds", "");
    e.check_histogram("scfo_rebind_latency_seconds", "");
    for phase in ["sample", "observe", "optimize", "measure"] {
        e.check_histogram("scfo_slot_phase_seconds", &format!("phase=\"{phase}\","));
    }
    // the per-phase series carry the six served slots
    assert_eq!(
        e.sample("scfo_slot_phase_seconds_count{phase=\"optimize\"}"),
        Some(6.0)
    );
    // counters that the control-smoke CI check greps for stay present
    assert_eq!(e.sample("scfo_admission_accepted_total"), Some(1.0));
    assert_eq!(e.sample("scfo_slots_served_total"), Some(6.0));
}

#[test]
fn profile_endpoint_serves_wellformed_chrome_trace() {
    // /profile with tracing disabled: valid, empty trace array
    let mut plane = light_plane();
    let srv = OpsServer::bind("127.0.0.1:0").unwrap();
    let (code, body) = http_request(&srv, &mut plane, "GET", "/profile");
    assert_eq!(code, 200);
    assert!(Json::parse(&body).unwrap().as_arr().is_some());

    // enabled: serve a few slots, then validate the snapshot. Capacity is
    // large enough that no span is dropped mid-test even if another test
    // thread in this binary records concurrently.
    scfo::obs::enable(scfo::obs::DEFAULT_CAPACITY);
    for _ in 0..3 {
        plane.run_slot().unwrap();
    }
    let (code, body) = http_request(&srv, &mut plane, "GET", "/profile");
    scfo::obs::clear();
    assert_eq!(code, 200);
    let doc = Json::parse(&body).unwrap();
    let events = doc.as_arr().expect("trace document is a JSON array");
    assert!(
        events.len() >= 2,
        "serving slots must have recorded spans, got {}",
        events.len()
    );

    let mut last_ts = f64::NEG_INFINITY;
    let mut stacks: BTreeMap<(u64, u64), Vec<String>> = BTreeMap::new();
    let mut names: Vec<String> = Vec::new();
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).expect("ph");
        assert!(
            ["B", "E", "X"].contains(&ph),
            "unexpected event phase {ph:?}"
        );
        let ts = ev.get("ts").and_then(Json::as_f64).expect("ts");
        assert!(ts >= last_ts, "timestamps must be monotone");
        last_ts = ts;
        let pid = ev.get("pid").and_then(Json::as_f64).expect("pid") as u64;
        let tid = ev.get("tid").and_then(Json::as_f64).expect("tid") as u64;
        let name = ev.get("name").and_then(Json::as_str).expect("name").to_string();
        match ph {
            "B" => {
                // virtual coordinates ride on every begin event
                let args = ev.get("args").expect("B event args");
                for coord in ["slot", "gp_iter", "control_epoch", "topo_epoch"] {
                    assert!(
                        args.get(coord).and_then(Json::as_f64).is_some(),
                        "missing {coord} in args"
                    );
                }
                stacks.entry((pid, tid)).or_default().push(name.clone());
                names.push(name);
            }
            "E" => {
                let top = stacks.entry((pid, tid)).or_default().pop();
                assert_eq!(
                    top.as_deref(),
                    Some(name.as_str()),
                    "E event does not match the innermost open B in lane ({pid},{tid})"
                );
            }
            // complete (X) events are self-contained — no stack entry
            _ => names.push(name),
        }
    }
    for ((pid, tid), stack) in &stacks {
        assert!(
            stack.is_empty(),
            "unmatched B events in lane ({pid},{tid}): {stack:?}"
        );
    }
    // the serving instrumentation is present in the snapshot
    for expected in ["slot", "sample", "optimize", "step", "flow-solve"] {
        assert!(
            names.iter().any(|n| n == expected),
            "span {expected:?} missing from trace (got {names:?})"
        );
    }
}
