//! Control-plane acceptance tests: admission safety (property-based),
//! checkpoint/restore bit-identical resume (including a checkpoint taken
//! **mid-flap**, with a topology repair still pending), warm-vs-cold
//! reconvergence after an app arrival, the end-to-end churn demo, the
//! HTTP ops API over a real loopback socket, and the replicated
//! per-replica checkpoint/restore path (fresh-term rebootstrap, forged
//! consensus-sender rejection).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;

use scfo::control::{
    iters_to_reach, snapshot, AppSpec, AppStatus, ControlOptions, ControlPlane, LiveReplica,
    OpsServer,
};
use scfo::flow::FlowState;
use scfo::prelude::*;
use scfo::scenarios::{Congestion, ScenarioSpec};
use scfo::topo::TopoAction;
use scfo::util::json::Json;
use scfo::util::prop::forall;
use scfo::workload::WorkloadSpec;

fn light_plane(opts: ControlOptions) -> ControlPlane {
    let spec = ScenarioSpec::named("abilene", Congestion::Light).unwrap();
    ControlPlane::new(spec.effective_base(), opts).unwrap()
}

fn small_app(id: &str, dest: usize, rates: Vec<(usize, f64)>) -> AppSpec {
    AppSpec {
        id: id.into(),
        dest,
        num_tasks: 2,
        packet_sizes: vec![10.0, 5.0, 1.0],
        rates,
        status: AppStatus::Active,
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("scfo-control-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

// ---- admission safety -------------------------------------------------------

/// Property: an accepted app never drives any link/CPU utilization up to
/// the capacity headroom — at the committed (admission-probed) operating
/// point under the true rates.
#[test]
fn prop_accepted_apps_respect_headroom() {
    forall("admission keeps headroom", 12, |g| {
        let mut plane = light_plane(ControlOptions::default());
        let n = plane.graph().n();
        let rng = g.rng();
        let dest = rng.usize(n);
        let num_sources = 1 + rng.usize(2);
        let sources = rng.choose_distinct(n, num_sources);
        let rates: Vec<(usize, f64)> = sources
            .into_iter()
            .map(|i| (i, rng.range(0.05, 2.5)))
            .collect();
        let app = small_app("prop-app", dest, rates);
        let accepted = match plane.register(app) {
            Ok(d) => d.accepted(),
            Err(e) => {
                g.fail(format!("register errored: {e}"));
                return false;
            }
        };
        if !accepted {
            // rejected candidates must leave the fleet untouched
            if plane.epoch() != 0 || plane.catalog.get("prop-app").is_some() {
                g.fail("rejected register mutated the fleet".into());
                return false;
            }
            return true; // vacuous case (rejection is the gate working)
        }
        let mut truth = plane.server.net.clone();
        plane.server.workload.apply_true_rates(&mut truth);
        let fs = match FlowState::solve(&truth, plane.server.optimizer.strategy()) {
            Ok(fs) => fs,
            Err(e) => {
                g.fail(format!("committed strategy unsolvable: {e}"));
                return false;
            }
        };
        let headroom = plane.admission.opts.headroom;
        for e in 0..truth.m() {
            if let Some(cap) = truth.link_cost[e].capacity() {
                let util = fs.link_flow[e] / cap;
                if util >= headroom {
                    g.fail(format!(
                        "link {e} utilization {util:.3} >= headroom {headroom}"
                    ));
                    return false;
                }
            }
        }
        for i in 0..truth.n() {
            if let Some(cap) = truth.comp_cost[i].capacity() {
                let util = fs.workload[i] / cap;
                if util >= headroom {
                    g.fail(format!(
                        "cpu {i} utilization {util:.3} >= headroom {headroom}"
                    ));
                    return false;
                }
            }
        }
        true
    });
}

/// At the capacity boundary the admission gate must reject: an app whose
/// demand alone saturates the narrowest link cannot be routed under
/// headroom no matter what the optimizer does.
#[test]
fn admission_rejects_at_the_capacity_boundary() {
    let mut plane = light_plane(ControlOptions::default());
    // abilene link caps are 15 bits/s; stage-0 packets are 10 bits, so a
    // 10 pkt/s single-source app offers 100 bits/s on its access links
    let monster = small_app("boundary", 9, vec![(0, 10.0)]);
    let d = plane.register(monster).unwrap();
    assert!(!d.accepted(), "boundary app must be rejected: {d:?}");
    match d {
        scfo::control::AdmissionDecision::Rejected { reason } => {
            assert!(reason.contains("utilization"), "{reason}");
        }
        _ => unreachable!(),
    }
    assert_eq!(plane.epoch(), 0);
    // a scaled-down version of the same app is admissible
    let ok = small_app("boundary-ok", 9, vec![(0, 0.1)]);
    assert!(plane.register(ok).unwrap().accepted());
    assert_eq!(plane.epoch(), 1);
}

// ---- warm-start reconvergence ----------------------------------------------

/// Acceptance: warm-start reconvergence after an app arrival takes
/// measurably fewer optimizer iterations than a cold restart.
#[test]
fn warm_start_beats_cold_restart_after_arrival() {
    let mut plane = light_plane(ControlOptions::default());
    // converge the initial fleet
    for _ in 0..60 {
        plane.run_slot().unwrap();
    }
    let d = plane
        .register(small_app("arrival", 10, vec![(0, 0.5), (4, 0.4)]))
        .unwrap();
    assert!(d.accepted(), "{d:?}");

    let mut truth = plane.server.net.clone();
    plane.server.workload.apply_true_rates(&mut truth);
    let warm_phi = plane.server.optimizer.strategy().clone();
    let cold_phi = Strategy::shortest_path_to_dest(&truth);
    let mut reference =
        GradientProjection::with_strategy(&truth, cold_phi.clone(), GpOptions::default());
    let target = reference.run(&truth, 4000).final_cost;

    let warm = iters_to_reach(&truth, &warm_phi, target, 0.02, 4000);
    let cold = iters_to_reach(&truth, &cold_phi, target, 0.02, 4000);
    assert!(
        warm < cold,
        "warm start must reconverge in fewer iterations: warm {warm} vs cold {cold}"
    );
}

// ---- checkpoint / restore ---------------------------------------------------

/// Acceptance: snapshot → kill → restore resumes the serving loop
/// bit-identically vs an uninterrupted run — same seed, same slots,
/// including MMPP workload state and controller (EWMA/CUSUM/oracle) state.
#[test]
fn checkpoint_restore_resumes_bit_identically() {
    let opts = ControlOptions {
        adapt: true,
        workload: Some(WorkloadSpec::named("mmpp").unwrap()),
        ..ControlOptions::default()
    };
    let mut a = light_plane(opts.clone());
    // churn before the checkpoint so the snapshot carries a non-trivial
    // catalog + epoch history
    for _ in 0..10 {
        a.run_slot().unwrap();
    }
    assert!(a
        .register(small_app("svc-a", 7, vec![(2, 0.3)]))
        .unwrap()
        .accepted());
    for _ in 0..10 {
        a.run_slot().unwrap();
    }
    a.drain("svc-a").unwrap();
    for _ in 0..10 {
        a.run_slot().unwrap();
    }

    let dir = tmp_dir("restore");
    a.checkpoint(&dir).unwrap();
    let mut b = ControlPlane::restore(&dir, opts).unwrap();
    assert_eq!(b.epoch(), a.epoch());
    assert_eq!(b.slots_served(), a.slots_served());
    assert_eq!(b.catalog.len(), a.catalog.len());
    assert_eq!(
        b.catalog.get("svc-a").unwrap().status,
        AppStatus::Draining,
        "lifecycle state survives the snapshot"
    );

    // the uninterrupted plane and the restored plane must now serve
    // bit-identical slots
    for slot in 0..30 {
        let ma = a.run_slot().unwrap();
        let mb = b.run_slot().unwrap();
        assert_eq!(ma.arrivals, mb.arrivals, "slot {slot} arrivals differ");
        assert_eq!(
            ma.cost.to_bits(),
            mb.cost.to_bits(),
            "slot {slot} cost differs: {} vs {}",
            ma.cost,
            mb.cost
        );
        assert_eq!(
            ma.expected_delay.to_bits(),
            mb.expected_delay.to_bits(),
            "slot {slot} delay differs"
        );
        assert_eq!(ma.detection, mb.detection, "slot {slot} detection differs");
        match (ma.regret, mb.regret) {
            (Some(ra), Some(rb)) => assert_eq!(ra.to_bits(), rb.to_bits(), "slot {slot} regret"),
            (None, None) => {}
            other => panic!("controller presence diverged: {other:?}"),
        }
    }
    let sa = a.server.controller.as_ref().unwrap().summary();
    let sb = b.server.controller.as_ref().unwrap().summary();
    assert_eq!(sa.detections, sb.detections);
    assert_eq!(sa.regret_total.to_bits(), sb.regret_total.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: the end-to-end churn demo — register 3 apps while serving,
/// drain 1, checkpoint, restart with restore — and the final aggregate
/// cost matches an uninterrupted run within 1e-9 relative.
#[test]
fn churn_with_restore_matches_uninterrupted_run() {
    let run_prefix = |plane: &mut ControlPlane| {
        for _ in 0..8 {
            plane.run_slot().unwrap();
        }
        assert!(plane
            .register(small_app("churn-1", 10, vec![(0, 0.3)]))
            .unwrap()
            .accepted());
        for _ in 0..8 {
            plane.run_slot().unwrap();
        }
        assert!(plane
            .register(small_app("churn-2", 5, vec![(3, 0.25)]))
            .unwrap()
            .accepted());
        for _ in 0..8 {
            plane.run_slot().unwrap();
        }
        assert!(plane
            .register(small_app("churn-3", 1, vec![(8, 0.2)]))
            .unwrap()
            .accepted());
        for _ in 0..8 {
            plane.run_slot().unwrap();
        }
        plane.drain("churn-2").unwrap();
        for _ in 0..8 {
            plane.run_slot().unwrap();
        }
    };
    // uninterrupted reference
    let mut reference = light_plane(ControlOptions::default());
    run_prefix(&mut reference);
    let mut final_ref = f64::NAN;
    for _ in 0..20 {
        final_ref = reference.run_slot().unwrap().cost;
    }

    // interrupted run: same prefix, checkpoint, "kill" (drop), restore
    let mut interrupted = light_plane(ControlOptions::default());
    run_prefix(&mut interrupted);
    let dir = tmp_dir("churn");
    interrupted.checkpoint(&dir).unwrap();
    drop(interrupted);
    let mut restored = ControlPlane::restore(&dir, ControlOptions::default()).unwrap();
    assert_eq!(restored.catalog.len(), reference.catalog.len());
    let mut final_restored = f64::NAN;
    for _ in 0..20 {
        final_restored = restored.run_slot().unwrap().cost;
    }

    let rel = (final_ref - final_restored).abs() / (1.0 + final_ref.abs());
    assert!(
        rel <= 1e-9,
        "final cost after restore diverged: {final_ref} vs {final_restored} (rel {rel:.3e})"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: checkpoint **mid-flap** — links removed, their repair still
/// pending — kill, restore, and the resumed run matches an uninterrupted
/// one within 1e-9, including the pending repair firing on its original
/// schedule.
#[test]
fn snapshot_mid_flap_restores_pending_repair_schedule() {
    // Serve, register an app, then flap two links due for repair 10 slots
    // later; stop mid-degradation with the repair still pending.
    let run_prefix = |plane: &mut ControlPlane| {
        for _ in 0..8 {
            plane.run_slot().unwrap();
        }
        assert!(plane
            .register(small_app("flap-app", 6, vec![(1, 0.25)]))
            .unwrap()
            .accepted());
        for _ in 0..4 {
            plane.run_slot().unwrap();
        }
        let mut churn_rng = Rng::new(0x70D0_CAFE);
        let picked = plane
            .apply_topo_event(
                &TopoAction::LinkFlap {
                    links: 2,
                    repair_after: 10,
                },
                &mut churn_rng,
            )
            .unwrap();
        assert!(!picked.is_empty(), "scripted flap removed nothing");
        assert!(plane.topology().is_degraded());
        for _ in 0..4 {
            plane.run_slot().unwrap();
        }
    };
    // Serve past the repair-due slot, draining due repairs exactly as the
    // production serving loop does.
    let run_suffix = |plane: &mut ControlPlane| -> f64 {
        let mut last = f64::NAN;
        for _ in 0..14 {
            let slot = plane.slots_served();
            plane.apply_due_repairs(slot).unwrap();
            last = plane.run_slot().unwrap().cost;
        }
        assert!(
            !plane.topology().is_degraded(),
            "pending repair never fired after restore"
        );
        last
    };

    // uninterrupted reference
    let mut reference = light_plane(ControlOptions::default());
    run_prefix(&mut reference);
    let final_ref = run_suffix(&mut reference);

    // interrupted run: same prefix, checkpoint mid-flap, "kill", restore
    let mut interrupted = light_plane(ControlOptions::default());
    run_prefix(&mut interrupted);
    let dir = tmp_dir("mid-flap");
    interrupted.checkpoint(&dir).unwrap();
    let expected_removed = interrupted.topology().removed_pairs();
    let expected_pending = interrupted.topology().pending_repairs();
    let expected_epoch = interrupted.topology().epoch();
    drop(interrupted);

    let mut restored = ControlPlane::restore(&dir, ControlOptions::default()).unwrap();
    assert!(
        restored.topology().is_degraded(),
        "degradation lost in the snapshot"
    );
    assert_eq!(restored.topology().removed_pairs(), expected_removed);
    assert_eq!(
        restored.topology().pending_repairs(),
        expected_pending,
        "pending repair schedule lost in the snapshot"
    );
    assert_eq!(restored.topology().epoch(), expected_epoch);
    let final_restored = run_suffix(&mut restored);

    let rel = (final_ref - final_restored).abs() / (1.0 + final_ref.abs());
    assert!(
        rel <= 1e-9,
        "mid-flap restore diverged: {final_ref} vs {final_restored} (rel {rel:.3e})"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- HTTP ops API -----------------------------------------------------------

/// Issue one HTTP request against `addr` from a helper thread while the
/// main thread polls the ops server; returns (status, body).
fn http_request(
    srv: &OpsServer,
    plane: &mut ControlPlane,
    checkpoint: Option<&PathBuf>,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, String) {
    let checkpoint = checkpoint.map(PathBuf::as_path);
    let addr = srv.local_addr();
    let request = format!(
        "{method} {path} HTTP/1.1\r\nHost: scfo\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let handle = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("connect ops API");
        stream.write_all(request.as_bytes()).unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        response
    });
    // serve the request from the main thread (the production poll loop)
    let response = loop {
        srv.poll(plane, checkpoint);
        if handle.is_finished() {
            break handle.join().unwrap();
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    };
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let body = response
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

#[test]
fn http_ops_api_end_to_end() {
    let mut plane = light_plane(ControlOptions::default());
    plane.run_slot().unwrap();
    let srv = OpsServer::bind("127.0.0.1:0").unwrap();
    let dir = tmp_dir("http");

    // healthz
    let (code, body) = http_request(&srv, &mut plane, Some(&dir), "GET", "/healthz", "");
    assert_eq!(code, 200, "{body}");
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("epoch").unwrap().as_usize(), Some(0));

    // register an app over HTTP
    let spec = r#"{"id": "web", "dest": 4, "num_tasks": 2, "rates": [[0, 0.3]]}"#;
    let (code, body) = http_request(&srv, &mut plane, Some(&dir), "POST", "/apps", spec);
    assert_eq!(code, 200, "{body}");
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("accepted").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("epoch").unwrap().as_usize(), Some(1));
    assert!(plane.catalog.get("web").is_some());

    // status lists the new app
    let (code, body) = http_request(&srv, &mut plane, Some(&dir), "GET", "/status", "");
    assert_eq!(code, 200);
    let v = Json::parse(&body).unwrap();
    let apps = v.get("apps").unwrap().as_arr().unwrap();
    assert!(apps
        .iter()
        .any(|a| a.get("id").and_then(Json::as_str) == Some("web")));
    assert!(v.get("utilization").unwrap().get("link_max").is_some());

    // an oversized app is rejected with 409 + reason
    let monster = r#"{"id": "monster", "dest": 9, "rates": [[0, 50.0]]}"#;
    let (code, body) = http_request(&srv, &mut plane, Some(&dir), "POST", "/apps", monster);
    assert_eq!(code, 409, "{body}");
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("accepted").unwrap().as_bool(), Some(false));
    assert!(v.get("reason").unwrap().as_str().unwrap().contains("utilization"));

    // DELETE drains, second DELETE removes
    let (code, body) = http_request(&srv, &mut plane, Some(&dir), "DELETE", "/apps/web", "");
    assert_eq!(code, 200, "{body}");
    assert_eq!(
        plane.catalog.get("web").unwrap().status,
        AppStatus::Draining
    );
    let (code, _) = http_request(&srv, &mut plane, Some(&dir), "DELETE", "/apps/web", "");
    assert_eq!(code, 200);
    assert!(plane.catalog.get("web").is_none());
    let (code, _) = http_request(&srv, &mut plane, Some(&dir), "DELETE", "/apps/web", "");
    assert_eq!(code, 404);

    // metrics render in Prometheus text format
    let (code, body) = http_request(&srv, &mut plane, Some(&dir), "GET", "/metrics", "");
    assert_eq!(code, 200);
    assert!(body.contains("# TYPE scfo_epoch gauge"), "{body}");
    assert!(body.contains("scfo_admission_accepted_total 1"), "{body}");
    assert!(body.contains("scfo_http_requests_total"), "{body}");

    // checkpoint over HTTP, then restore from it
    let (code, body) = http_request(&srv, &mut plane, Some(&dir), "POST", "/checkpoint", "");
    assert_eq!(code, 200, "{body}");
    let restored = ControlPlane::restore(&dir, ControlOptions::default()).unwrap();
    assert_eq!(restored.epoch(), plane.epoch());
    assert_eq!(restored.slots_served(), plane.slots_served());

    // unknown routes 404
    let (code, _) = http_request(&srv, &mut plane, Some(&dir), "GET", "/nope", "");
    assert_eq!(code, 404);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- replicated checkpoint / restore ----------------------------------------

fn loopback_peers() -> Vec<String> {
    ["127.0.0.1:1", "127.0.0.1:2", "127.0.0.1:3"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

/// A replica checkpoints into its private `replica-I/` subdirectory of the
/// shared dir with its consensus state embedded, and a restarted process
/// resumes from that document: the plane restores exactly and the replica
/// re-asserts leadership in a term strictly above the persisted one, so
/// its first appends truncate stale same-term follower suffixes instead of
/// silently diverging — the serve-path restart flow, via the public API.
#[test]
fn replicated_checkpoint_restore_resumes_in_fresh_term() {
    let mut plane = light_plane(ControlOptions::default());
    for _ in 0..3 {
        plane.run_slot().unwrap();
    }
    let repl = LiveReplica::new(0, loopback_peers(), plane.scenario.seed).unwrap();
    assert!(repl.is_leader());
    assert_eq!(repl.term(), 1);

    let dir = tmp_dir("repl-ckpt");
    let path = plane.checkpoint_replicated(&dir, &repl).unwrap();
    assert!(path.starts_with(snapshot::replica_dir(&dir, 0)));
    // the shared base dir itself holds no snapshot.json: co-located
    // replicas write to private subdirectories, never clobbering each other
    assert!(!snapshot::snapshot_path(&dir).exists());

    // "restart": load the per-replica document, rebuild plane + replica
    let doc = snapshot::load(&snapshot::replica_dir(&dir, 0)).unwrap();
    let restored = ControlPlane::restore_from_doc(&doc, ControlOptions::default()).unwrap();
    assert_eq!(restored.slots_served(), plane.slots_served());
    assert_eq!(restored.epoch(), plane.epoch());

    let mut back = LiveReplica::new(0, loopback_peers(), restored.scenario.seed).unwrap();
    back.load_persistent(doc.get("replication").unwrap()).unwrap();
    back.rebootstrap();
    assert!(back.is_leader());
    assert_eq!(back.term(), 2, "restart must lead in a fresh term");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Replicated-mode variant of [`http_request`]: polls with the replica
/// attached so the consensus routes are live.
fn http_request_repl(
    srv: &OpsServer,
    plane: &mut ControlPlane,
    repl: &mut LiveReplica,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, String) {
    let addr = srv.local_addr();
    let request = format!(
        "{method} {path} HTTP/1.1\r\nHost: scfo\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let handle = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("connect ops API");
        stream.write_all(request.as_bytes()).unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        response
    });
    let response = loop {
        srv.poll_repl(plane, None, Some(&mut *repl));
        if handle.is_finished() {
            break handle.join().unwrap();
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    };
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let body = response
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

/// A consensus message whose sender id is outside the replica group must
/// be rejected with a 400 — not panic the single serving thread by
/// indexing the per-replica vote/match tables (a trivial remote DoS).
#[test]
fn raftish_msg_rejects_out_of_range_sender() {
    let mut plane = light_plane(ControlOptions::default());
    let srv = OpsServer::bind("127.0.0.1:0").unwrap();
    let mut repl = LiveReplica::new(0, loopback_peers(), plane.scenario.seed).unwrap();

    let forged = r#"{"kind":"append-ack","term":1,"from":999,"ok":true,"match_index":1}"#;
    let (code, body) =
        http_request_repl(&srv, &mut plane, &mut repl, "POST", "/raftish/msg", forged);
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("out of range"), "{body}");

    // the server survived and still answers consensus routes
    let (code, body) = http_request_repl(&srv, &mut plane, &mut repl, "GET", "/raftish", "");
    assert_eq!(code, 200, "{body}");
    let v = Json::parse(&body).unwrap();
    assert_eq!(v.get("term").and_then(Json::as_usize), Some(1));
}
