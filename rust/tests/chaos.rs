//! Chaos property suite for the asynchronous distributed runtime.
//!
//! For seeded fault specs (drop ≤ 20%, reorder/delay jitter, one heal-able
//! partition) the async runtime must
//!
//! 1. converge to the centralized `GradientProjection` final cost within
//!    1e-6 (relative) on the default-matrix families, and
//! 2. be **bit-reproducible**: a rerun with the same `(seed, fault-spec)`
//!    yields the identical strategy, cost bits and transport counters.
//!
//! The fault seed honors `SCFO_CHAOS_SEED` so CI can sweep seeds; every run
//! prints one `chaos-digest <scenario> <spec> <cost-bits>` line, and the CI
//! `chaos-and-golden` job runs the whole suite twice per seed and fails on
//! any run-to-run output diff (the flakiness gate — see docs/TESTING.md).
//!
//! A stationary-null case closes the loop with the serving layer: under
//! stationary Poisson traffic the `AdaptationController` must fire zero
//! spurious restarts while driving the distributed optimizer.
//!
//! The flap-under-faults cases compose topology churn with the transport
//! fault specs: a scripted link flap at er-200-800 (remove → warm
//! [`Strategy::rebind_topology`] remap → [`AsyncRuntime::rebind`] →
//! repair), run under the `lossy` and `partition` presets. Each phase must
//! re-quiesce within 1e-6 of centralized GP on the post-churn graph, be
//! bit-identical across reruns per (seed, spec), and be invisible to the
//! shard count.

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::distributed::{
    AsyncRuntime, DistributedOptimizer, FaultSpec, Partition, RunReport, RuntimeOptions,
};
use scfo::prelude::*;
use scfo::serving::{
    AdaptationController, ControllerOptions, OnlineServer, ServerOptions,
};
use scfo::topo::{TopoAction, TopologyState};
use scfo::workload::Workload;

/// Fault seed: `SCFO_CHAOS_SEED` (CI sweeps it), default 7.
fn chaos_seed() -> u64 {
    std::env::var("SCFO_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
}

/// The chaos fault specs from the issue: drop ≤ 20%, reorder/delay, one
/// heal-able partition.
fn fault_specs(seed: u64) -> Vec<FaultSpec> {
    vec![
        FaultSpec {
            name: "drop20".to_string(),
            seed,
            drop: 0.2,
            dup: 0.0,
            min_delay: 1,
            max_delay: 1,
            partitions: Vec::new(),
        },
        FaultSpec {
            name: "reorder".to_string(),
            seed,
            drop: 0.02,
            dup: 0.05,
            min_delay: 1,
            max_delay: 6,
            partitions: Vec::new(),
        },
        FaultSpec {
            name: "partition".to_string(),
            seed,
            drop: 0.05,
            dup: 0.0,
            min_delay: 1,
            max_delay: 3,
            partitions: vec![Partition {
                start: 30,
                end: 150,
                group: Vec::new(),
            }],
        },
    ]
}

/// Nominal-congestion cells of the default matrix (the families chaos runs
/// against; the remaining two default families are covered by the cheaper
/// clean-transport sweep below).
const CHAOS_FAMILIES: [&str; 3] = ["abilene", "er-20-40", "grid-4x5"];
const CLEAN_FAMILIES: [&str; 5] = ["abilene", "er-20-40", "grid-4x5", "fat-tree-4", "geant"];

fn build_network(family: &str) -> Network {
    let spec = ScenarioSpec::named(family, Congestion::Nominal).unwrap();
    let sc = spec.effective_base();
    let mut rng = Rng::new(sc.seed);
    sc.build(&mut rng).unwrap()
}

fn centralized_final_cost(net: &Network) -> f64 {
    let mut gp = GradientProjection::new(
        net,
        GpOptions {
            residual_tol: 1e-9,
            ..GpOptions::default()
        },
    );
    gp.run(net, 8000).final_cost
}

fn run_async(net: &Network, faults: Option<FaultSpec>, shards: usize) -> RunReport {
    let phi0 = Strategy::shortest_path_to_dest(net);
    let opts = RuntimeOptions {
        shards,
        max_epochs: 12_000,
        ..RuntimeOptions::default()
    };
    let mut rt = match faults {
        Some(f) => AsyncRuntime::sim_net(net.clone(), phi0, f, opts),
        None => AsyncRuntime::in_mem(net.clone(), phi0, opts),
    };
    rt.run_until_quiescent()
}

fn digest(family: &str, spec: &str, rep: &RunReport) {
    println!(
        "chaos-digest {family} {spec} {:016x} epochs={} msgs={} dropped={}",
        rep.final_cost.to_bits(),
        rep.epochs,
        rep.stats.transport.sent,
        rep.stats.transport.dropped_total(),
    );
}

#[test]
fn clean_transport_matches_centralized_on_all_default_families() {
    for family in CLEAN_FAMILIES {
        let net = build_network(family);
        let rep = run_async(&net, None, 4);
        digest(family, "clean", &rep);
        assert!(rep.converged, "{family}: no quiescence in {} epochs", rep.epochs);
        let central = centralized_final_cost(&net);
        let rel = (rep.final_cost - central).abs() / (1.0 + central);
        assert!(
            rel < 1e-6,
            "{family}: async {} vs centralized {central} (rel {rel:.2e})",
            rep.final_cost
        );
    }
}

#[test]
fn chaos_final_cost_matches_centralized_within_1e6() {
    let seed = chaos_seed();
    for family in CHAOS_FAMILIES {
        let net = build_network(family);
        let central = centralized_final_cost(&net);
        for faults in fault_specs(seed) {
            let name = faults.name.clone();
            let rep = run_async(&net, Some(faults), 4);
            digest(family, &name, &rep);
            assert!(
                rep.converged,
                "{family}/{name}: no quiescence in {} epochs",
                rep.epochs
            );
            let rel = (rep.final_cost - central).abs() / (1.0 + central);
            assert!(
                rel < 1e-6,
                "{family}/{name}: async {} vs centralized {central} (rel {rel:.2e})",
                rep.final_cost
            );
        }
    }
}

#[test]
fn chaos_runs_are_bit_identical_per_seed_and_spec() {
    let seed = chaos_seed();
    let net = build_network("er-20-40");
    for faults in fault_specs(seed) {
        let name = faults.name.clone();
        let a = run_async(&net, Some(faults.clone()), 4);
        let b = run_async(&net, Some(faults), 4);
        assert_eq!(
            a.final_cost.to_bits(),
            b.final_cost.to_bits(),
            "{name}: cost bits differ across reruns"
        );
        assert_eq!(a.epochs, b.epochs, "{name}");
        assert_eq!(a.stats, b.stats, "{name}: transport counters differ");
        assert_eq!(
            a.cost_trace.len(),
            b.cost_trace.len(),
            "{name}: trace length differs"
        );
        for (x, y) in a.cost_trace.iter().zip(&b.cost_trace) {
            assert_eq!(x.to_bits(), y.to_bits(), "{name}: trace diverged");
        }
    }
}

#[test]
fn shard_count_is_not_observable() {
    let seed = chaos_seed();
    let net = build_network("grid-4x5");
    let specs = fault_specs(seed);
    let faults = &specs[1]; // reorder/delay spec
    let a = run_async(&net, Some(faults.clone()), 1);
    let b = run_async(&net, Some(faults.clone()), 4);
    let c = run_async(&net, Some(faults.clone()), 7);
    assert_eq!(a.final_cost.to_bits(), b.final_cost.to_bits());
    assert_eq!(b.final_cost.to_bits(), c.final_cost.to_bits());
    assert_eq!(a.stats.transport, b.stats.transport);
    assert_eq!(b.stats.transport, c.stats.transport);
}

#[test]
fn chaos_actually_injected_faults() {
    let seed = chaos_seed();
    let net = build_network("abilene");
    let specs = fault_specs(seed);
    let drop = run_async(&net, Some(specs[0].clone()), 2);
    assert!(
        drop.stats.transport.dropped_fault > 0,
        "drop20 spec dropped nothing"
    );
    let reorder = run_async(&net, Some(specs[1].clone()), 2);
    assert!(
        reorder.stats.transport.duplicated > 0,
        "reorder spec duplicated nothing"
    );
    let partition = run_async(&net, Some(specs[2].clone()), 2);
    assert!(
        partition.stats.transport.dropped_partition > 0,
        "partition spec cut nothing"
    );
    assert!(
        partition.ticks > specs[2].last_partition_end(),
        "quiesced inside the partition window"
    );
}

/// Stationary-null: serving a stationary Poisson workload through the
/// distributed optimizer with the adaptation controller attached must
/// produce ZERO spurious change-point detections (hence zero restarts /
/// step boosts).
#[test]
fn stationary_null_no_spurious_restarts_distributed() {
    let net = build_network("abilene");
    let phi0 = Strategy::shortest_path_to_dest(&net);
    let rt = AsyncRuntime::in_mem(
        net.clone(),
        phi0,
        RuntimeOptions {
            shards: 2,
            ..RuntimeOptions::default()
        },
    );
    let opt = DistributedOptimizer::new(rt);
    let workload = Workload::stationary(&net, 1.0, 2024);
    let mut srv = OnlineServer::with_workload(net, opt, workload, ServerOptions::default());
    srv.attach_controller(AdaptationController::new(ControllerOptions::default()));
    let metrics = srv.run(120).unwrap();
    let summary = srv.controller.as_ref().unwrap().summary();
    assert_eq!(
        summary.detections, 0,
        "spurious detections under stationary traffic"
    );
    assert!(metrics.iter().all(|m| !m.detection));
    assert!(metrics.iter().all(|m| m.cost.is_finite()));
}

// ---------------------------------------------------------------------------
// Flap under faults: scripted topology churn × transport fault specs.
// ---------------------------------------------------------------------------

/// Churn RNG salt shared with the `topo-churn` scenario tier, so the chaos
/// flap and `scfo scenarios run --tier topo-churn` pick from the same
/// deterministic stream family.
const CHURN_RNG_SALT: u64 = 0x70D0_CAFE;

/// The scale-tier family the flap cases run at (same workload overrides as
/// the `distributed` tier uses for ≥200-node cells).
const FLAP_FAMILY: &str = "er-200-800";

fn build_scaled_network(family: &str) -> Network {
    let mut spec = ScenarioSpec::named(family, Congestion::Nominal).unwrap();
    spec.apply_scale_overrides();
    let sc = spec.effective_base();
    let mut rng = Rng::new(sc.seed);
    sc.build(&mut rng).unwrap()
}

/// Script one link flap on `base`: remove two link pairs at slot 0 (due
/// for repair at slot 1), then restore them. Returns the degraded and
/// repaired networks. Deterministic in `seed` alone — every fault spec and
/// shard count sees the identical churn — and exercises the epoch/pending
/// bookkeeping of [`TopologyState`] on the way.
fn flap_nets(base: &Network, seed: u64) -> (Network, Network) {
    let mut topo = TopologyState::new(base.clone());
    let mut churn_rng = Rng::new(seed ^ CHURN_RNG_SALT);
    let flap = TopoAction::LinkFlap {
        links: 2,
        repair_after: 1,
    };
    let picked = topo.apply_event(0, &flap, &mut churn_rng);
    assert!(!picked.is_empty(), "scripted flap removed no link pair");
    assert_eq!(topo.epoch(), 1);
    let degraded = topo.current_network();
    assert!(topo.is_degraded());

    let restored = topo.due_repairs(1);
    assert_eq!(restored, picked, "repair schedule lost a pair");
    assert_eq!(topo.epoch(), 2);
    assert!(!topo.is_degraded());
    let repaired = topo.current_network();
    assert_eq!(
        repaired.graph.edges(),
        base.graph.edges(),
        "full repair must restore the exact base edge set"
    );
    (degraded, repaired)
}

/// One flap chain: quiesce on `base` under `faults`, warm-remap the
/// converged strategy onto the degraded arena ([`Strategy::rebind_topology`]
/// + [`AsyncRuntime::rebind`]), re-quiesce, repair, re-quiesce again.
/// Returns the per-phase reports (pre-flap, degraded, repaired).
fn run_flap_chain(
    base: &Network,
    degraded_net: &Network,
    repaired_net: &Network,
    faults: FaultSpec,
    shards: usize,
) -> (RunReport, RunReport, RunReport) {
    let name = faults.name.clone();
    let phi0 = Strategy::shortest_path_to_dest(base);
    let opts = RuntimeOptions {
        shards,
        max_epochs: 12_000,
        ..RuntimeOptions::default()
    };
    let mut rt = AsyncRuntime::sim_net(base.clone(), phi0, faults, opts);
    let pre = rt.run_until_quiescent();
    assert!(pre.converged, "{name}: pre-flap run did not quiesce");

    let phi_warm = rt.strategy().rebind_topology(degraded_net);
    rt.rebind(degraded_net.clone(), phi_warm);
    let degraded = rt.run_until_quiescent();
    assert!(degraded.converged, "{name}: degraded run did not quiesce");

    let phi_back = rt.strategy().rebind_topology(repaired_net);
    rt.rebind(repaired_net.clone(), phi_back);
    let repaired = rt.run_until_quiescent();
    assert!(repaired.converged, "{name}: repaired run did not quiesce");
    (pre, degraded, repaired)
}

/// Flap under `lossy` and `partition`: after every phase of the chain the
/// runtime must land within 1e-6 (relative) of centralized GP **on the
/// graph of that phase** — the degraded arena mid-flap, the restored base
/// arena after repair.
#[test]
fn flap_under_faults_matches_centralized_on_post_churn_graph() {
    let seed = chaos_seed();
    let base = build_scaled_network(FLAP_FAMILY);
    let (degraded_net, repaired_net) = flap_nets(&base, seed);
    let central_degraded = centralized_final_cost(&degraded_net);
    let central_repaired = centralized_final_cost(&repaired_net);
    for preset in ["lossy", "partition"] {
        let faults = FaultSpec::preset(preset, seed).unwrap();
        let (pre, degraded, repaired) =
            run_flap_chain(&base, &degraded_net, &repaired_net, faults, 4);
        digest(FLAP_FAMILY, &format!("flap-{preset}-pre"), &pre);
        digest(FLAP_FAMILY, &format!("flap-{preset}-degraded"), &degraded);
        digest(FLAP_FAMILY, &format!("flap-{preset}-repaired"), &repaired);
        let rel = (degraded.final_cost - central_degraded).abs() / (1.0 + central_degraded);
        assert!(
            rel < 1e-6,
            "{preset}: degraded async {} vs centralized {central_degraded} (rel {rel:.2e})",
            degraded.final_cost
        );
        let rel = (repaired.final_cost - central_repaired).abs() / (1.0 + central_repaired);
        assert!(
            rel < 1e-6,
            "{preset}: repaired async {} vs centralized {central_repaired} (rel {rel:.2e})",
            repaired.final_cost
        );
    }
}

/// The whole flap chain is bit-reproducible per (seed, fault-spec): both
/// the mid-flap and post-repair phases rerun to identical cost bits,
/// epoch counts and transport counters.
#[test]
fn flap_chains_are_bit_identical_per_seed_and_spec() {
    let seed = chaos_seed();
    let base = build_scaled_network(FLAP_FAMILY);
    let (degraded_net, repaired_net) = flap_nets(&base, seed);
    for preset in ["lossy", "partition"] {
        let faults = FaultSpec::preset(preset, seed).unwrap();
        let a = run_flap_chain(&base, &degraded_net, &repaired_net, faults.clone(), 4);
        let b = run_flap_chain(&base, &degraded_net, &repaired_net, faults, 4);
        for (phase, (x, y)) in [
            ("pre", (&a.0, &b.0)),
            ("degraded", (&a.1, &b.1)),
            ("repaired", (&a.2, &b.2)),
        ] {
            assert_eq!(
                x.final_cost.to_bits(),
                y.final_cost.to_bits(),
                "{preset}/{phase}: cost bits differ across reruns"
            );
            assert_eq!(x.epochs, y.epochs, "{preset}/{phase}: epoch count differs");
            assert_eq!(
                x.stats, y.stats,
                "{preset}/{phase}: transport counters differ"
            );
        }
    }
}

/// Shard count stays unobservable through a flap: rebinding onto the
/// degraded and repaired arenas with 1, 4 and 7 shards yields identical
/// cost bits and transport counters in every phase.
#[test]
fn flap_shard_count_is_not_observable() {
    let seed = chaos_seed();
    let base = build_scaled_network(FLAP_FAMILY);
    let (degraded_net, repaired_net) = flap_nets(&base, seed);
    let faults = FaultSpec::lossy(seed);
    let a = run_flap_chain(&base, &degraded_net, &repaired_net, faults.clone(), 1);
    let b = run_flap_chain(&base, &degraded_net, &repaired_net, faults.clone(), 4);
    let c = run_flap_chain(&base, &degraded_net, &repaired_net, faults, 7);
    for (phase, (x, y, z)) in [
        ("pre", (&a.0, &b.0, &c.0)),
        ("degraded", (&a.1, &b.1, &c.1)),
        ("repaired", (&a.2, &b.2, &c.2)),
    ] {
        assert_eq!(
            x.final_cost.to_bits(),
            y.final_cost.to_bits(),
            "{phase}: 1 vs 4 shards"
        );
        assert_eq!(
            y.final_cost.to_bits(),
            z.final_cost.to_bits(),
            "{phase}: 4 vs 7 shards"
        );
        assert_eq!(x.stats.transport, y.stats.transport, "{phase}");
        assert_eq!(y.stats.transport, z.stats.transport, "{phase}");
    }
}
