//! Three-layer parity: the PJRT-executed artifact (L1 Pallas + L2 JAX) must
//! agree with the native Rust evaluation on every Table-II scenario, and the
//! XLA-driven GP must track the native GP trajectory.
//!
//! Skipped (with a message) when `make artifacts` has not been run.

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::config::Scenario;
use scfo::flow::FlowState;
use scfo::marginals::Marginals;
use scfo::prelude::*;
use scfo::runtime::{EvalRuntime, XlaGp};
use scfo::util::rng::Rng;

/// Self-skip guard. Rust's libtest has no runtime skip verdict, so a test
/// that cannot run still exits green — the explicit reason below is the
/// contract that makes those passes auditable: CI logs are grepped for
/// `skipped: missing XLA artifact` to distinguish "parity verified" from
/// "parity not exercised" (see docs/TESTING.md).
fn artifacts_or_skip() -> bool {
    if scfo::runtime::artifacts_available() {
        true
    } else {
        eprintln!(
            "skipped: missing XLA artifact — parity not exercised (build with `make artifacts`)"
        );
        false
    }
}

#[test]
fn xla_eval_matches_native_on_all_table2_scenarios() {
    if !artifacts_or_skip() {
        return;
    }
    for name in ["connected-er", "balanced-tree", "fog", "abilene", "lhc", "geant"] {
        let sc = Scenario::table2(name).unwrap();
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng).unwrap();
        let rt = EvalRuntime::load_for(&net).unwrap();
        // a random mixed strategy exercises split forwarding + offloading
        let phi = Strategy::random_dag(&net, &mut rng);
        let out = rt.eval(&net, &phi).unwrap();
        let fs = FlowState::solve(&net, &phi).unwrap();
        let mg = Marginals::compute(&net, &phi, &fs);
        assert!(
            (out.total_cost - fs.total_cost).abs() < 1e-8 * (1.0 + fs.total_cost.abs()),
            "{name}: cost xla {} native {}",
            out.total_cost,
            fs.total_cost
        );
        for s in 0..net.num_stages() {
            for i in 0..net.n() {
                assert!(
                    (out.d_dt[s][i] - mg.d_dt[s][i]).abs()
                        < 1e-7 * (1.0 + mg.d_dt[s][i].abs()),
                    "{name}: ddt[{s}][{i}] xla {} native {}",
                    out.d_dt[s][i],
                    mg.d_dt[s][i]
                );
            }
        }
    }
}

#[test]
fn xla_eval_matches_native_on_sw_large_bucket() {
    if !artifacts_or_skip() {
        return;
    }
    let sc = Scenario::table2("sw").unwrap();
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng).unwrap();
    let rt = EvalRuntime::load_for(&net).unwrap();
    assert_eq!(rt.bucket().n, 128, "SW must land in the large bucket");
    let phi = Strategy::shortest_path_to_dest(&net);
    let out = rt.eval(&net, &phi).unwrap();
    let fs = FlowState::solve(&net, &phi).unwrap();
    assert!(
        (out.total_cost - fs.total_cost).abs() < 1e-7 * (1.0 + fs.total_cost.abs()),
        "cost xla {} native {}",
        out.total_cost,
        fs.total_cost
    );
}

#[test]
fn xla_gp_trajectory_tracks_native() {
    if !artifacts_or_skip() {
        return;
    }
    let sc = Scenario::table2("abilene").unwrap();
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng).unwrap();
    let mut xgp = XlaGp::new(
        &net,
        GpOptions {
            backtrack: false, // strict trajectory parity
            ..Default::default()
        },
    )
    .unwrap();
    let mut gp = GradientProjection::with_strategy(
        &net,
        Strategy::shortest_path_to_dest(&net),
        GpOptions {
            backtrack: false,
            ..Default::default()
        },
    );
    for it in 0..40 {
        xgp.step(&net).unwrap();
        gp.step(&net);
        let diff = xgp.phi.max_diff(&gp.phi);
        assert!(
            diff < 1e-6,
            "iteration {it}: XLA and native phi diverged by {diff}"
        );
    }
}
