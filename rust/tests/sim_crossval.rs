//! Cross-validation of the two simulators: the packet-level DES
//! (`sim/des.rs`) and the analytic flow model (`sim/flowsim.rs`) must agree
//! on per-link utilization/occupancy and mean delay — this pins the M/M/1
//! cost semantics both sides assume (D_ij(F) = F/(d̄−F) as a mean queue
//! length, delay via Little's law).
//!
//! Bounds are statistical-CI-shaped: the DES is a stochastic system
//! measured over a finite horizon, so loaded links get a relative band and
//! lightly-loaded links an absolute one.

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::prelude::*;
use scfo::sim;

fn build(family: &str) -> Network {
    let spec = ScenarioSpec::named(family, Congestion::Nominal).unwrap();
    let sc = spec.effective_base();
    let mut rng = Rng::new(sc.seed);
    sc.build(&mut rng).unwrap()
}

fn crossval(family: &str, horizon: f64, seed: u64) {
    let net = build(family);
    let mut gp = GradientProjection::new(&net, GpOptions::default());
    gp.run(&net, 300);
    let phi = gp.phi.clone();

    let analytic = sim::analytic_link_profile(&net, &phi).unwrap();
    let analytic_delay = sim::analytic_mean_delay(&net, &phi).unwrap();
    let rep = sim::simulate(&net, &phi, horizon, seed).unwrap();
    assert_eq!(rep.link_occupancy.len(), net.m());
    assert!(rep.delivered > 1000, "{family}: too few packets delivered");

    // 1. per-link occupancy: loaded links within 35% relative or 0.08
    //    absolute; idle links essentially empty.
    let mut loaded = 0;
    let mut abs_err_sum = 0.0;
    for p in &analytic {
        let measured = rep.link_occupancy[p.edge];
        if p.utilization > 0.05 {
            loaded += 1;
            let err = (measured - p.occupancy).abs();
            let band = (0.35 * p.occupancy).max(0.08);
            assert!(
                err <= band,
                "{family}: link {} occupancy {measured:.4} vs analytic {:.4} \
                 (util {:.2}, band {band:.4})",
                p.edge,
                p.occupancy,
                p.utilization
            );
            abs_err_sum += err;
        } else {
            assert!(
                measured < 0.06 + 2.0 * p.occupancy,
                "{family}: near-idle link {} measured occupancy {measured:.4}",
                p.edge
            );
        }
    }
    assert!(loaded >= 3, "{family}: optimized flow uses too few links");
    // aggregate per-link error must be tighter than the per-link band
    assert!(
        abs_err_sum / loaded as f64 <= 0.06,
        "{family}: mean per-link occupancy error {:.4}",
        abs_err_sum / loaded as f64
    );

    // 2. mean delay: DES sojourn vs analytic D(φ)/λ̄ (Little).
    let rel = (rep.mean_delay - analytic_delay).abs() / analytic_delay;
    assert!(
        rel < 0.2,
        "{family}: DES delay {:.4}s vs analytic {:.4}s (rel {rel:.3})",
        rep.mean_delay,
        analytic_delay
    );

    // 3. total occupancy decomposition: links + CPUs ≈ D(φ).
    let total_links: f64 = rep.link_occupancy.iter().sum();
    let total_cpus: f64 = rep.cpu_occupancy.iter().sum();
    let rel_total = (total_links + total_cpus - rep.avg_occupancy).abs()
        / rep.avg_occupancy.max(1e-9);
    assert!(rel_total < 1e-9, "{family}: per-station sums disagree with total");
}

#[test]
fn des_matches_analytic_link_profile_on_abilene() {
    crossval("abilene", 6000.0, 42);
}

#[test]
fn des_matches_analytic_link_profile_on_grid_4x5() {
    crossval("grid-4x5", 6000.0, 17);
}

#[test]
fn analytic_profile_rejects_linear_costs() {
    let mut net = build("abilene");
    for c in &mut net.link_cost {
        *c = CostFn::Linear { d: 1.0 };
    }
    let phi = Strategy::shortest_path_to_dest(&net);
    assert!(sim::analytic_link_profile(&net, &phi).is_err());
}
