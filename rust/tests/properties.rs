//! Property-based integration tests over the coordinator invariants:
//! flow conservation, loop-freeness, monotone descent, optimality.

use scfo::algo::blocked::BlockedSets;
use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::app::{Application, Network, StageRegistry};
use scfo::cost::CostFn;
use scfo::flow::FlowState;
use scfo::graph::topologies;
use scfo::marginals::Marginals;
use scfo::prelude::*;
use scfo::util::prop::{forall, forall_cases, PropResult};
use scfo::util::rng::Rng;

/// Random network on a random Table-II-style topology with random apps.
fn random_network(rng: &mut Rng) -> Network {
    let topo = ["connected-er", "balanced-tree", "fog", "abilene", "lhc", "geant"]
        [rng.usize(6)];
    let g = topologies::by_name(topo, rng).unwrap();
    let n = g.n();
    let m = g.m();
    let num_apps = 1 + rng.usize(3);
    let mut apps = Vec::new();
    for _ in 0..num_apps {
        let dest = rng.usize(n);
        let num_tasks = 1 + rng.usize(2);
        let mut input_rates = vec![0.0; n];
        let nsrc = 1 + rng.usize(3);
        for s in rng.choose_distinct(n, nsrc) {
            input_rates[s] = rng.range(0.2, 1.0);
        }
        let packet_sizes = (0..=num_tasks)
            .map(|k| (8.0 - 3.0 * k as f64).max(1.0))
            .collect();
        apps.push(Application {
            dest,
            num_tasks,
            packet_sizes,
            input_rates,
        });
    }
    let stages = StageRegistry::new(&apps);
    let comp_weight = (0..stages.len())
        .map(|_| (0..n).map(|_| rng.range(0.5, 2.0)).collect())
        .collect();
    let link_cost = (0..m)
        .map(|_| CostFn::Queue {
            cap: rng.range(30.0, 60.0),
        })
        .collect();
    let comp_cost = (0..n)
        .map(|_| CostFn::Queue {
            cap: rng.range(10.0, 25.0),
        })
        .collect();
    Network::new(g, apps, link_cost, comp_cost, comp_weight).unwrap()
}

/// Single-app network on an arbitrary digraph (dest 0, source at the
/// highest node id); `None` when node 0 is not reachable from everywhere —
/// the property's precondition.
fn single_app_net(g: &Graph) -> Option<Network> {
    if !g.all_reach(0) {
        return None;
    }
    let n = g.n();
    let m = g.m();
    let mut input_rates = vec![0.0; n];
    input_rates[n - 1] = 1.0;
    let apps = vec![Application {
        dest: 0,
        num_tasks: 1,
        packet_sizes: vec![4.0, 1.0],
        input_rates,
    }];
    let stages = StageRegistry::new(&apps);
    let cw = vec![vec![1.0; n]; stages.len()];
    Network::new(
        g.clone(),
        apps,
        vec![CostFn::Linear { d: 1.0 }; m],
        vec![CostFn::Linear { d: 1.0 }; n],
        cw,
    )
    .ok()
}

/// Shrinking-enabled topology property: flow conservation holds on every
/// random digraph where the destination is reachable. A failure shrinks the
/// topology itself (edge deletions / node drops, discarding candidates that
/// break reachability) and reports the minimal counterexample graph.
#[test]
fn prop_conservation_on_random_digraphs_with_subgraph_shrinking() {
    forall_cases(
        "conservation on random digraphs",
        25,
        |g| {
            let rng = g.rng();
            let n = 6 + rng.usize(6);
            // bidirected ring guarantees connectivity, plus random chords
            let mut edges: Vec<(usize, usize)> = Vec::new();
            for i in 0..n {
                edges.push((i, (i + 1) % n));
                edges.push(((i + 1) % n, i));
            }
            for _ in 0..2 * n {
                let a = rng.usize(n);
                let b = rng.usize(n);
                if a != b && !edges.contains(&(a, b)) {
                    edges.push((a, b));
                }
            }
            Graph::new(n, &edges).unwrap()
        },
        |g: &Graph| {
            let Some(net) = single_app_net(g) else {
                return PropResult::Discard;
            };
            let phi = Strategy::shortest_path_to_dest(&net);
            let fs = match FlowState::solve(&net, &phi) {
                Ok(fs) => fs,
                Err(e) => return PropResult::Fail(format!("flow solve failed: {e}")),
            };
            let res = fs.conservation_residual(&net, &phi);
            if res < 1e-8 {
                PropResult::Pass
            } else {
                PropResult::Fail(format!("conservation residual {res}"))
            }
        },
    );
}

/// Shrinking-enabled topology-churn equivalence: after ANY sequence of
/// link remove/restore events, the incrementally rebound arena + remapped
/// φ ([`scfo::topo::TopologyState`] + `Strategy::rebind_topology` chained
/// through every intermediate epoch) is equivalent to a cold build on the
/// final graph — identical edge list, φ feasible and loop-free on the
/// cold arena, flow conservation exact, and bit-for-bit the same cost on
/// both builds (within 1e-9 relative). A failure shrinks both the
/// topology (subgraph shrinker) and the event sequence, replaying each
/// candidate greedily toward the minimal counterexample.
#[test]
fn prop_incremental_rebind_equals_cold_build_with_shrinking() {
    use scfo::topo::TopologyState;

    // toggle the t-th undirected base pair: remove if present (skipping
    // connectivity-filtered picks), restore if currently removed
    fn apply_toggles(
        topo: &mut TopologyState,
        phi: Strategy,
        toggles: &[usize],
    ) -> Strategy {
        let pairs: Vec<(usize, usize)> = topo
            .base()
            .graph
            .edges()
            .iter()
            .copied()
            .filter(|&(i, j)| i < j)
            .collect();
        let mut phi = phi;
        for &t in toggles {
            let (i, j) = pairs[t % pairs.len()];
            let changed = if topo.removed_pairs().contains(&(i, j)) {
                topo.restore_pair(i, j)
            } else {
                // never due: repairs are driven explicitly by the toggles
                topo.remove_pair(i, j, usize::MAX).is_ok()
            };
            if changed {
                phi = phi.rebind_topology(&topo.current_network());
            }
        }
        phi
    }

    forall_cases(
        "incremental rebind == cold build",
        20,
        |g| {
            let rng = g.rng();
            // bidirected ring (flaps remove undirected pairs) + chords
            let n = 6 + rng.usize(6);
            let mut und: Vec<(usize, usize)> = (0..n)
                .map(|i| {
                    let j = (i + 1) % n;
                    (i.min(j), i.max(j))
                })
                .collect();
            for _ in 0..n {
                let a = rng.usize(n);
                let b = rng.usize(n);
                let p = (a.min(b), a.max(b));
                if a != b && !und.contains(&p) {
                    und.push(p);
                }
            }
            let mut edges = Vec::with_capacity(2 * und.len());
            for &(i, j) in &und {
                edges.push((i, j));
                edges.push((j, i));
            }
            let graph = Graph::new(n, &edges).unwrap();
            let toggles: Vec<usize> = (0..rng.usize(9)).map(|_| rng.usize(64)).collect();
            (graph, toggles)
        },
        |(graph, toggles): &(Graph, Vec<usize>)| {
            let Some(net) = single_app_net(graph) else {
                return PropResult::Discard; // shrunk candidate broke reachability
            };
            if graph.edges().iter().all(|&(i, j)| i >= j) {
                return PropResult::Discard; // no undirected pair to toggle
            }
            let mut rng = Rng::new(0xF1A9);
            let phi0 = Strategy::random_dag(&net, &mut rng);
            let mut topo = TopologyState::new(net.clone());
            let phi = apply_toggles(&mut topo, phi0, toggles);
            let incr = topo.current_network();
            // cold build on the final edge set — an independent construction
            let removed = topo.removed_pairs();
            let final_edges: Vec<(usize, usize)> = graph
                .edges()
                .iter()
                .copied()
                .filter(|&(i, j)| !removed.contains(&(i.min(j), i.max(j))))
                .collect();
            let cold_graph = match Graph::new(graph.n(), &final_edges) {
                Ok(g) => g,
                Err(e) => return PropResult::Fail(format!("cold graph build: {e}")),
            };
            let Some(cold) = single_app_net(&cold_graph) else {
                return PropResult::Fail("cold build lost reachability".into());
            };
            if incr.graph.edges() != cold.graph.edges() {
                return PropResult::Fail(format!(
                    "arena edge lists diverged: incremental {} vs cold {} edges",
                    incr.m(),
                    cold.m()
                ));
            }
            if let Err(e) = phi.validate(&cold) {
                return PropResult::Fail(format!("remapped phi invalid on cold build: {e}"));
            }
            if phi.has_loop() {
                return PropResult::Fail("remapped phi has a loop".into());
            }
            let fs_incr = match FlowState::solve(&incr, &phi) {
                Ok(fs) => fs,
                Err(e) => return PropResult::Fail(format!("incremental solve: {e}")),
            };
            let fs_cold = match FlowState::solve(&cold, &phi) {
                Ok(fs) => fs,
                Err(e) => return PropResult::Fail(format!("cold solve: {e}")),
            };
            let res = fs_incr.conservation_residual(&incr, &phi);
            if res > 1e-9 {
                return PropResult::Fail(format!("conservation residual {res}"));
            }
            let (a, b) = (fs_incr.total_cost, fs_cold.total_cost);
            if (a - b).abs() > 1e-9 * (1.0 + b.abs()) {
                return PropResult::Fail(format!("cost diverged: incremental {a} vs cold {b}"));
            }
            PropResult::Pass
        },
    );
}

/// Acceptance gate: on every default-matrix family, a flap + rebind is
/// equivalent to a cold rebuild within 1e-9 — and after the repair the
/// rebound strategy returns to the full arena intact.
#[test]
fn rebind_matches_cold_rebuild_on_default_matrix_families() {
    use scfo::scenarios::{Congestion, ScenarioSpec};
    use scfo::topo::{TopoAction, TopologyState};

    for family in ["er-20-40", "grid-4x5", "fat-tree-4", "abilene", "geant"] {
        let spec = ScenarioSpec::named(family, Congestion::Light).unwrap();
        let sc = spec.effective_base();
        let mut rng = Rng::new(sc.seed);
        let graph = topologies::by_name(&sc.topology, &mut rng).unwrap();
        let base = sc.build_on(graph, &mut rng).unwrap();
        let mut gp = GradientProjection::new(&base, GpOptions::default());
        gp.run(&base, 200);

        let mut topo = TopologyState::new(base.clone());
        let mut churn_rng = Rng::new(sc.seed ^ 0x70D0_CAFE);
        let action = TopoAction::LinkFlap {
            links: 2,
            repair_after: 1,
        };
        let picked = topo.apply_event(0, &action, &mut churn_rng);
        assert!(!picked.is_empty(), "{family}: flap removed nothing");
        let pruned = topo.current_network();
        let warm = gp.phi.rebind_topology(&pruned);
        warm.validate(&pruned)
            .unwrap_or_else(|e| panic!("{family}: {e}"));

        // cold rebuild of the same pruned network, constructed independently
        let mut edges = Vec::new();
        let mut link_cost = Vec::new();
        for (id, &(i, j)) in base.graph.edges().iter().enumerate() {
            if !picked.contains(&(i.min(j), i.max(j))) {
                edges.push((i, j));
                link_cost.push(base.link_cost[id].clone());
            }
        }
        let cold = Network::new(
            Graph::new(base.n(), &edges).unwrap(),
            base.apps.clone(),
            link_cost,
            base.comp_cost.clone(),
            base.comp_weight.clone(),
        )
        .unwrap();
        assert_eq!(pruned.graph.edges(), cold.graph.edges(), "{family}");
        let ci = FlowState::solve(&pruned, &warm).unwrap().total_cost;
        let cc = FlowState::solve(&cold, &warm).unwrap().total_cost;
        assert!(
            (ci - cc).abs() <= 1e-9 * (1.0 + cc.abs()),
            "{family}: incremental {ci} vs cold {cc}"
        );

        // repair: back onto the full arena, strategy still valid
        assert_eq!(topo.due_repairs(1), picked, "{family}");
        let repaired = topo.current_network();
        assert_eq!(repaired.graph.edges(), base.graph.edges(), "{family}");
        let back = warm.rebind_topology(&repaired);
        back.validate(&repaired)
            .unwrap_or_else(|e| panic!("{family}: {e}"));
        assert!(!back.has_loop(), "{family}");
    }
}

#[test]
fn prop_flow_conservation_holds_for_random_strategies() {
    forall("flow conservation", 40, |g| {
        let mut rng = g.rng().fork();
        let net = random_network(&mut rng);
        let phi = Strategy::random_dag(&net, &mut rng);
        let fs = FlowState::solve(&net, &phi).unwrap();
        let res = fs.conservation_residual(&net, &phi);
        scfo::prop_assert!(g, res < 1e-8, "residual {res}");
        true
    });
}

#[test]
fn prop_gp_iterates_stay_feasible_and_loop_free() {
    forall("gp invariants", 15, |g| {
        let mut rng = g.rng().fork();
        let net = random_network(&mut rng);
        let phi0 = Strategy::random_dag(&net, &mut rng);
        let mut gp = GradientProjection::with_strategy(&net, phi0, GpOptions::default());
        for it in 0..25 {
            gp.step(&net);
            scfo::prop_assert!(
                g,
                gp.phi.validate(&net).is_ok(),
                "iterate {it} infeasible: {:?}",
                gp.phi.validate(&net).err()
            );
            scfo::prop_assert!(g, !gp.phi.has_loop(), "iterate {it} has a loop");
        }
        true
    });
}

#[test]
fn prop_gp_cost_never_increases() {
    forall("gp monotone descent", 15, |g| {
        let mut rng = g.rng().fork();
        let net = random_network(&mut rng);
        let phi0 = Strategy::random_dag(&net, &mut rng);
        let mut gp = GradientProjection::with_strategy(&net, phi0, GpOptions::default());
        let mut prev = f64::INFINITY;
        for it in 0..30 {
            let st = gp.step(&net);
            scfo::prop_assert!(
                g,
                st.cost <= prev + 1e-9,
                "iterate {it} increased cost {prev} -> {}",
                st.cost
            );
            prev = st.cost;
        }
        true
    });
}

#[test]
fn prop_marginals_match_finite_differences() {
    forall("marginal fd-check", 12, |g| {
        let mut rng = g.rng().fork();
        let net = random_network(&mut rng);
        let phi = Strategy::random_dag(&net, &mut rng);
        let fs = FlowState::solve(&net, &phi).unwrap();
        let mg = Marginals::compute(&net, &phi, &fs);
        // spot-check a few positive directions
        let mut checked = 0;
        'outer: for s in 0..net.num_stages() {
            for i in 0..net.n() {
                if fs.traffic[s][i] < 1e-3 {
                    continue;
                }
                for j in phi.positive_links(s, i).collect::<Vec<_>>() {
                    let analytic = mg.d_dphi(&fs, s, i, j);
                    let fd = Marginals::fd_check(&net, &phi, s, i, j, 1e-6).unwrap();
                    scfo::prop_assert!(
                        g,
                        (analytic - fd).abs() < 1e-3 * (1.0 + analytic.abs()),
                        "s={s} i={i} j={j} analytic {analytic} fd {fd}"
                    );
                    checked += 1;
                    if checked >= 8 {
                        break 'outer;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_sparse_marginals_match_dense_reference() {
    // The CSR core must be numerically equivalent to the textbook dense
    // recursion. Re-derive eq. (4)/(7) here with plain O(n²) loops over
    // node-id indices (no CSR machinery at all) and compare to 1e-12.
    use scfo::marginals::INF_MARGINAL;
    forall("sparse == dense marginals", 15, |g| {
        let mut rng = g.rng().fork();
        let net = random_network(&mut rng);
        let phi = Strategy::random_dag(&net, &mut rng);
        let fs = FlowState::solve(&net, &phi).unwrap();
        let mg = Marginals::compute(&net, &phi, &fs);

        let n = net.n();
        let cpu = net.n();
        let mut dense_ddt = vec![vec![0.0; n]; net.num_stages()];
        for (a, app) in net.apps.iter().enumerate() {
            for k in (0..app.num_stages()).rev() {
                let s = net.stages.id(a, k);
                let l = net.packet_size(s);
                let is_final = k == app.num_tasks;
                let order = phi.topo_order(s).unwrap();
                for &i in order.iter().rev() {
                    let mut acc = 0.0;
                    for j in 0..n {
                        let p = phi.get(s, i, j);
                        if p > 0.0 {
                            let e = net.graph.edge_id(i, j).unwrap();
                            acc += p * (l * fs.link_marginal[e] + dense_ddt[s][j]);
                        }
                    }
                    if !is_final {
                        let pc = phi.get(s, i, cpu);
                        if pc > 0.0 {
                            let next = net.stages.id(a, k + 1);
                            acc += pc
                                * (net.comp_weight[s][i] * fs.comp_marginal[i]
                                    + dense_ddt[next][i]);
                        }
                    }
                    dense_ddt[s][i] = acc;
                }
                // δ over the full dense (i, j) index space
                for i in 0..n {
                    for j in 0..=n {
                        let want = if j < n {
                            match net.graph.edge_id(i, j) {
                                Some(e) => Some(l * fs.link_marginal[e] + dense_ddt[s][j]),
                                None => None,
                            }
                        } else if !is_final {
                            let next = net.stages.id(a, k + 1);
                            Some(
                                net.comp_weight[s][i] * fs.comp_marginal[i]
                                    + dense_ddt[next][i],
                            )
                        } else {
                            None
                        };
                        let got = mg.delta_at(s, i, j);
                        match want {
                            Some(want) => scfo::prop_assert!(
                                g,
                                (got - want).abs() <= 1e-12 * (1.0 + want.abs()),
                                "delta[{s}][{i}][{j}]: sparse {got} dense {want}"
                            ),
                            None => scfo::prop_assert!(
                                g,
                                got >= INF_MARGINAL,
                                "delta[{s}][{i}][{j}]: sparse {got}, dense has no direction"
                            ),
                        }
                    }
                }
            }
        }
        for s in 0..net.num_stages() {
            for i in 0..n {
                let (a, b) = (mg.d_dt[s][i], dense_ddt[s][i]);
                scfo::prop_assert!(
                    g,
                    (a - b).abs() <= 1e-12 * (1.0 + b.abs()),
                    "d_dt[{s}][{i}]: sparse {a} dense {b}"
                );
            }
        }
        true
    });
}

#[test]
fn prop_blocked_sets_prevent_loop_formation() {
    forall("blocked sets vs loops", 15, |g| {
        let mut rng = g.rng().fork();
        let net = random_network(&mut rng);
        let phi = Strategy::random_dag(&net, &mut rng);
        let fs = FlowState::solve(&net, &phi).unwrap();
        let mg = Marginals::compute(&net, &phi, &fs);
        let bs = BlockedSets::compute(&net, &phi, &mg);
        // for every stage: adding ANY unblocked direction to phi must keep
        // the stage acyclic
        for s in 0..net.num_stages() {
            let mut test_phi = phi.clone();
            for i in 0..net.n() {
                for j in 0..net.n() {
                    if !bs.is_blocked(s, i, j) && test_phi.get(s, i, j) == 0.0 {
                        test_phi.set(s, i, j, 1e-6);
                    }
                }
            }
            scfo::prop_assert!(
                g,
                test_phi.topo_order(s).is_some(),
                "stage {s}: unioning all unblocked directions formed a loop"
            );
        }
        true
    });
}

#[test]
fn prop_converged_gp_satisfies_condition6() {
    forall("condition-6 at convergence", 6, |g| {
        let mut rng = g.rng().fork();
        let net = random_network(&mut rng);
        let mut gp = GradientProjection::new(&net, GpOptions::default());
        let rep = gp.run(&net, 4000);
        if !rep.converged {
            // extremely slow cases may need more iterations; only check
            // that the residual has become small
            let last = *rep.residual_trace.last().unwrap();
            scfo::prop_assert!(g, last < 0.2, "residual stuck at {last}");
            return true;
        }
        let fs = FlowState::solve(&net, &gp.phi).unwrap();
        let mg = Marginals::compute(&net, &gp.phi, &fs);
        let res = mg.condition6_residual(&net, &gp.phi);
        scfo::prop_assert!(g, res < 1e-6, "converged but residual {res}");
        true
    });
}

#[test]
fn prop_gp_beats_or_ties_every_baseline() {
    forall("gp is global optimum", 8, |g| {
        let mut rng = g.rng().fork();
        let net = random_network(&mut rng);
        let gp_cost = scfo::algo::Algorithm::Gp.solve(&net, 1500).unwrap();
        for alg in [
            scfo::algo::Algorithm::Spoc,
            scfo::algo::Algorithm::Lcof,
            scfo::algo::Algorithm::LprSc,
        ] {
            let c = alg.solve(&net, 800).unwrap();
            scfo::prop_assert!(
                g,
                gp_cost <= c * 1.005 + 1e-9,
                "GP {gp_cost} lost to {} {c}",
                alg.name()
            );
        }
        true
    });
}

#[test]
fn prop_broadcast_always_matches_centralized() {
    forall("broadcast == recursion", 20, |g| {
        let mut rng = g.rng().fork();
        let net = random_network(&mut rng);
        let phi = Strategy::random_dag(&net, &mut rng);
        let fs = FlowState::solve(&net, &phi).unwrap();
        let mg = Marginals::compute(&net, &phi, &fs);
        let out = scfo::broadcast::run_broadcast(&net, &phi, &fs);
        for s in 0..net.num_stages() {
            for i in 0..net.n() {
                let a = out.d_dt[s][i];
                let b = mg.d_dt[s][i];
                scfo::prop_assert!(
                    g,
                    (a - b).abs() < 1e-9 * (1.0 + b.abs()),
                    "s={s} i={i}: broadcast {a} vs centralized {b}"
                );
            }
        }
        scfo::prop_assert!(
            g,
            out.messages == net.num_stages() * net.m(),
            "messages {} != |S||E| {}",
            out.messages,
            net.num_stages() * net.m()
        );
        true
    });
}
