//! Scenario-engine integration tests: matrix shape, batch execution, and
//! the determinism contract — same seed + spec gives bit-identical results
//! across repeated runs and across serial vs parallel execution.

use scfo::scenarios::{
    run_batch, Congestion, DynamicEvent, RunnerOptions, ScenarioCache, ScenarioSpec,
};

/// A shrunk three-scenario batch (three distinct topology families) that
/// keeps debug-mode runtime small while still exercising the full path:
/// initial solve, demand step, link churn, baseline comparison.
fn small_batch() -> Vec<ScenarioSpec> {
    let cells = [
        ("abilene", Congestion::Nominal),
        ("grid-3x3", Congestion::Heavy),
        ("er-12-24", Congestion::Light),
    ];
    cells
        .iter()
        .map(|(family, congestion)| {
            let mut spec = ScenarioSpec::named(family, *congestion).unwrap();
            spec.iters = 250;
            spec.events = vec![
                DynamicEvent::RateScale {
                    factor: 1.3,
                    iters: 120,
                },
                DynamicEvent::LinkDown { iters: 120 },
                DynamicEvent::LinkUp { iters: 120 },
            ];
            spec
        })
        .collect()
}

fn quiet(jobs: usize) -> RunnerOptions {
    RunnerOptions {
        jobs,
        out_dir: None,
        quiet: true,
    }
}

#[test]
fn default_matrix_meets_acceptance_shape() {
    let matrix = ScenarioSpec::matrix();
    assert!(matrix.len() >= 12, "matrix too small: {}", matrix.len());
    let families: std::collections::BTreeSet<&str> =
        matrix.iter().map(|s| s.base.topology.as_str()).collect();
    assert!(families.len() >= 3, "need >= 3 topology families");
    let levels: std::collections::BTreeSet<&str> =
        matrix.iter().map(|s| s.congestion.name()).collect();
    assert_eq!(levels.len(), 3, "need all congestion levels");
    assert!(
        matrix.iter().all(|s| !s.events.is_empty()),
        "every cell needs a dynamic-event schedule"
    );
}

#[test]
fn large_tier_scenario_runs_end_to_end() {
    // er-1000-4000 was unrepresentable under the dense [stage][n×(n+1)]
    // layout (φ alone ~8 MB per stage, δ/blocked/support again each); under
    // the CSR core the arena is m+n ≈ 9000 entries per stage and the run
    // completes in-process even in debug builds. Budgets are shrunk hard —
    // this test checks end-to-end viability, not convergence quality.
    let mut spec = ScenarioSpec::named("er-1000-4000", Congestion::Nominal).unwrap();
    spec.base.num_apps = 1;
    spec.base.num_sources = 2;
    spec.base.link_param = 60.0;
    spec.base.comp_param = 40.0;
    spec.iters = 6;
    spec.events.clear();
    let rep = scfo::scenarios::runner::run_one(&spec, &ScenarioCache::new()).unwrap();
    assert!(rep.n >= 1000, "large tier must be ≥1000 nodes, got {}", rep.n);
    assert_eq!(rep.costs.len(), 4); // GP + three baselines still compared
    assert!(rep.gp_cost().is_finite() && rep.gp_cost() > 0.0);
}

#[test]
fn same_seed_and_spec_reproduce_identical_costs() {
    let spec = &small_batch()[0];
    let a = scfo::scenarios::runner::run_one(spec, &ScenarioCache::new()).unwrap();
    let b = scfo::scenarios::runner::run_one(spec, &ScenarioCache::new()).unwrap();
    assert_eq!(a.costs.len(), b.costs.len());
    for ((n1, c1), (n2, c2)) in a.costs.iter().zip(&b.costs) {
        assert_eq!(n1, n2);
        assert!(
            c1.to_bits() == c2.to_bits(),
            "{n1}: {c1} vs {c2} must be bit-identical"
        );
    }
    for (p1, p2) in a.phases.iter().zip(&b.phases) {
        assert_eq!(p1.label, p2.label);
        assert!(p1.gp_cost.to_bits() == p2.gp_cost.to_bits());
    }
}

#[test]
fn serial_and_parallel_execution_agree() {
    let specs = small_batch();
    let serial = run_batch(&specs, &quiet(1)).unwrap();
    let parallel = run_batch(&specs, &quiet(4)).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (s, p) in serial.iter().zip(&parallel) {
        assert_eq!(s.name, p.name, "report order must follow spec order");
        for ((n1, c1), (n2, c2)) in s.costs.iter().zip(&p.costs) {
            assert_eq!(n1, n2);
            assert!(
                c1.to_bits() == c2.to_bits(),
                "{}/{n1}: serial {c1} vs parallel {c2}",
                s.name
            );
        }
        for (p1, p2) in s.phases.iter().zip(&p.phases) {
            assert!(
                p1.gp_cost.to_bits() == p2.gp_cost.to_bits(),
                "{}/{}: serial {} vs parallel {}",
                s.name,
                p1.label,
                p1.gp_cost,
                p2.gp_cost
            );
        }
    }
}

#[test]
fn gp_beats_or_ties_baselines_across_small_batch() {
    let reports = run_batch(&small_batch(), &quiet(2)).unwrap();
    for rep in &reports {
        let gp = rep.gp_cost();
        for (name, cost) in rep.costs.iter().skip(1) {
            assert!(
                gp <= cost * (1.0 + 1e-6) + 1e-9,
                "{}: GP {gp} lost to {name} {cost}",
                rep.name
            );
        }
        assert!(rep.gp_within_baselines, "{}: flag disagrees", rep.name);
    }
}

#[test]
fn dynamic_events_drive_cost_trajectory() {
    let reports = run_batch(&small_batch(), &quiet(2)).unwrap();
    for rep in &reports {
        assert_eq!(rep.phases.len(), 4, "{}", rep.name);
        assert_eq!(rep.phases[0].label, "initial");
        // the 1.3x demand step strictly raises the settled optimum
        assert!(
            rep.phases[1].gp_cost > rep.phases[0].gp_cost,
            "{}: rate step had no effect ({} -> {})",
            rep.name,
            rep.phases[0].gp_cost,
            rep.phases[1].gp_cost
        );
        // all phases stay finite (smooth queue extension, no NaN)
        assert!(rep.phases.iter().all(|p| p.gp_cost.is_finite()));
    }
}
