//! Sampling-equivalence test layer for the SoA batched workload engine.
//!
//! The `massive` tier's throughput rests on one invariant: the batched
//! structure-of-arrays sampler is *bit-identical* to the boxed
//! `TrafficModel` reference path — same arrival offsets, same true rates,
//! same RNG consumption — for every model family, any seed, and across
//! epoch rebinds that grow or shrink the stream set. This suite pins that
//! invariant three ways:
//!
//! 1. a shrinking property test (`util::prop`) over (family, seed) pairs
//!    that reports a minimal (model, seed, slot) counterexample on failure,
//! 2. trace record → replay fidelity: traces recorded from the batched
//!    path round-trip through JSON and CSV files and replay byte-identical
//!    serving results through `OnlineServer`,
//! 3. a massive-scale (10k-stream) checkpoint/restore smoke with f64 bit
//!    equality over 30 post-restore slots.
//!
//! The `equiv_digest_is_stable` case prints one
//! `equiv-digest <family> <arrival-bits> arrivals=<n>` line per model
//! family under `SCFO_EQUIV_SEED`; the CI `chaos-and-golden` job runs the
//! suite twice per seed and fails on any run-to-run diff (the flakiness
//! gate — see docs/TESTING.md).

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::app::{Network, StageRegistry};
use scfo::config::Scenario;
use scfo::scenarios::ScenarioSpec;
use scfo::serving::{OnlineServer, ServerOptions};
use scfo::util::json::Json;
use scfo::util::prop::{forall_cases, PropResult};
use scfo::util::rng::Rng;
use scfo::workload::{Trace, Workload, WorkloadSpec};

/// Every batchable model family, in `ModelSpec::named` preset form.
const FAMILIES: [&str; 5] = ["poisson", "diurnal", "mmpp", "flash-crowd", "drift"];

fn equiv_seed() -> u64 {
    std::env::var("SCFO_EQUIV_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
}

fn test_net() -> Network {
    let sc = Scenario::table2("abilene").unwrap();
    let mut rng = Rng::new(sc.seed);
    sc.build(&mut rng).unwrap()
}

/// A two-app variant of `net`: the original app survives (as app 1) and a
/// new app with a single source at node 5 is prepended — the "grow" side
/// of a control-plane rebind. Shrinking back maps `[None, Some(0)]`.
fn grown_net(net: &Network) -> Network {
    let mut apps = net.apps.clone();
    let mut extra = net.apps[0].clone();
    extra.input_rates.iter_mut().for_each(|r| *r = 0.0);
    extra.input_rates[5] = 0.7;
    apps.insert(0, extra);
    let stages = StageRegistry::new(&apps);
    let cw = vec![vec![1.0; net.n()]; stages.len()];
    Network::new(
        net.graph.clone(),
        apps,
        net.link_cost.clone(),
        net.comp_cost.clone(),
        cw,
    )
    .unwrap()
}

/// Sample one slot on both engines and demand bit equality of arrival
/// totals, per-stream offsets and true rates.
fn compare_slot(
    boxed: &mut Workload,
    batched: &mut Workload,
    slot: usize,
    phase: &str,
) -> Result<(), String> {
    let a = boxed.sample_slot();
    let b = batched.sample_slot();
    if a != b {
        return Err(format!("arrival totals {a} vs {b} at slot {slot} ({phase})"));
    }
    for (i, (sa, sb)) in boxed.streams.iter().zip(&batched.streams).enumerate() {
        let same_offsets = sa.last_offsets.len() == sb.last_offsets.len()
            && sa
                .last_offsets
                .iter()
                .zip(&sb.last_offsets)
                .all(|(x, y)| x.to_bits() == y.to_bits());
        if !same_offsets {
            return Err(format!("stream {i} offsets diverge at slot {slot} ({phase})"));
        }
        if sa.last_rate.to_bits() != sb.last_rate.to_bits() {
            return Err(format!(
                "stream {i} rate {} vs {} at slot {slot} ({phase})",
                sa.last_rate, sb.last_rate
            ));
        }
    }
    Ok(())
}

/// The central property: for every (family, seed), the batched engine is
/// bit-identical to the boxed reference over base serving, a grow rebind
/// and a shrink rebind. Failures shrink toward the minimal (model, seed)
/// pair; the message pins the first diverging slot and phase.
#[test]
fn batched_sampler_is_bit_identical_to_boxed_across_rebinds() {
    let net = test_net();
    let net2 = grown_net(&net);
    forall_cases(
        "soa batched == boxed",
        25,
        |g| (g.usize_in(0, FAMILIES.len() - 1), g.rng().next_u64()),
        |&(model, seed)| {
            let Some(&family) = FAMILIES.get(model) else {
                return PropResult::Discard;
            };
            let spec = WorkloadSpec::named(family).unwrap();
            let fail = |msg: String| PropResult::Fail(format!("family {family} seed {seed}: {msg}"));
            let mut boxed = Workload::from_spec(&spec, &net, 1.0, seed).unwrap();
            let mut batched = Workload::from_spec(&spec, &net, 1.0, seed).unwrap();
            if !batched.enable_batching() {
                return fail("family must be batchable".into());
            }
            for slot in 0..12 {
                if let Err(e) = compare_slot(&mut boxed, &mut batched, slot, "base") {
                    return fail(e);
                }
            }
            // grow: old app 0 survives as app 1, node-5 stream spawns
            boxed.rebind(&net2, &[Some(1)]);
            batched.rebind(&net2, &[Some(1)]);
            if !batched.batching() {
                return fail("grow rebind must re-enable batching".into());
            }
            for slot in 12..20 {
                if let Err(e) = compare_slot(&mut boxed, &mut batched, slot, "grown") {
                    return fail(e);
                }
            }
            // shrink: drop the spawned app, survivor renumbers back to 0
            boxed.rebind(&net, &[None, Some(0)]);
            batched.rebind(&net, &[None, Some(0)]);
            if !batched.batching() {
                return fail("shrink rebind must re-enable batching".into());
            }
            for slot in 20..28 {
                if let Err(e) = compare_slot(&mut boxed, &mut batched, slot, "shrunk") {
                    return fail(e);
                }
            }
            PropResult::Pass
        },
    );
}

/// Traces recorded *from the batched engine* replay byte-identical serving
/// results — through both on-disk formats. The replay workload itself
/// stays boxed (trace history is external), so this also pins the
/// batched-record → boxed-replay seam.
#[test]
fn batched_traces_replay_byte_identically_in_both_formats() {
    let net = test_net();
    let wspec = WorkloadSpec::named("mmpp").unwrap();
    let serve = |wl: Workload| -> Vec<u64> {
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::with_workload(net.clone(), gp, wl, ServerOptions::default());
        srv.run(40).unwrap().iter().map(|m| m.cost.to_bits()).collect()
    };
    let mut live_wl = Workload::from_spec(&wspec, &net, 1.0, 29).unwrap();
    assert!(live_wl.enable_batching());
    let live = serve(live_wl);

    let mut rec = Workload::from_spec(&wspec, &net, 1.0, 29).unwrap();
    assert!(rec.enable_batching());
    let trace = Trace::record(&mut rec, 40, None);
    assert!(!trace.workload().batching(), "trace replay stays boxed");

    let dir = std::env::temp_dir().join(format!("scfo-soa-equiv-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for name in ["t.json", "t.csv"] {
        let path = dir.join(name);
        trace.save(&path).unwrap();
        let re = Trace::load(&path).unwrap();
        assert_eq!(trace, re, "{name} round trip must be lossless");
        let replayed = serve(re.workload());
        assert_eq!(
            live, replayed,
            "{name}: trace-driven serving must be byte-identical to the batched live model"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Massive-scale smoke: a 10,000-stream batched workload on the massive
/// tier's er-1000-4000 family round-trips checkpoint/restore, and the
/// restored engine tracks the original with f64 bit equality for 30
/// post-restore slots.
#[test]
fn massive_scale_checkpoint_restore_is_bit_exact() {
    let spec = ScenarioSpec::massive_matrix_sized(10, 1000, 20)
        .pop()
        .expect("massive matrix has one spec");
    let wspec = spec.workload.as_ref().expect("massive spec carries a workload");
    let sc = spec.effective_base();
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng).unwrap();
    let mut a = Workload::from_spec(wspec, &net, 1.0, sc.seed).unwrap();
    assert_eq!(a.streams.len(), 10_000, "10 apps x 1000 sources");
    assert!(a.enable_batching());
    for _ in 0..20 {
        a.sample_slot();
    }
    // serialize through text, as a checkpoint file would
    let snap = Json::parse(&a.state_json().unwrap().to_string_pretty()).unwrap();
    let mut b = Workload::from_state_json(&snap).unwrap();
    assert!(b.batching(), "restore must re-enable the batched engine");
    assert_eq!(b.slot(), a.slot(), "slot cursor must survive the checkpoint");
    for slot in 0..30 {
        let ta = a.sample_slot();
        let tb = b.sample_slot();
        assert_eq!(ta, tb, "post-restore slot {slot} arrival total");
        for (i, (sa, sb)) in a.streams.iter().zip(&b.streams).enumerate() {
            assert_eq!(
                sa.last_offsets.len(),
                sb.last_offsets.len(),
                "post-restore slot {slot} stream {i}"
            );
            assert!(
                sa.last_offsets
                    .iter()
                    .zip(&sb.last_offsets)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "post-restore slot {slot} stream {i} offsets"
            );
            assert_eq!(
                sa.last_rate.to_bits(),
                sb.last_rate.to_bits(),
                "post-restore slot {slot} stream {i} rate"
            );
        }
    }
}

/// One `equiv-digest` line per model family: an FNV-1a fold over every
/// arrival-offset and true-rate bit pattern from 40 batched slots. The CI
/// flakiness gate replays this under several `SCFO_EQUIV_SEED` values,
/// twice each, and diffs the output.
#[test]
fn equiv_digest_is_stable() {
    let seed = equiv_seed();
    let net = test_net();
    for family in FAMILIES {
        let spec = WorkloadSpec::named(family).unwrap();
        let mut wl = Workload::from_spec(&spec, &net, 1.0, seed).unwrap();
        assert!(wl.enable_batching(), "{family} must be batchable");
        let mut acc: u64 = 0xcbf29ce484222325;
        let mut fold = |bits: u64| acc = (acc ^ bits).wrapping_mul(0x100000001b3);
        let mut arrivals = 0usize;
        for _ in 0..40 {
            arrivals += wl.sample_slot();
            for s in &wl.streams {
                for x in &s.last_offsets {
                    fold(x.to_bits());
                }
                fold(s.last_rate.to_bits());
            }
        }
        println!("equiv-digest {family} {acc:016x} arrivals={arrivals}");
    }
}
