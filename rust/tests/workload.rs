//! Workload-subsystem integration tests: model determinism (property),
//! trace record → replay fidelity through the full serving loop, and the
//! adaptation controller's stationary null behavior.

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::config::Scenario;
use scfo::prop_assert;
use scfo::serving::{
    AdaptationController, ControllerOptions, OnlineServer, ServerOptions,
};
use scfo::util::prop::forall;
use scfo::util::rng::Rng;
use scfo::workload::{ModelSpec, Trace, Workload, WorkloadSpec};

fn test_net() -> scfo::app::Network {
    let sc = Scenario::table2("abilene").unwrap();
    let mut rng = Rng::new(sc.seed);
    sc.build(&mut rng).unwrap()
}

/// Per slot, per stream: (arrival offsets, true mean rate).
type Drained = Vec<Vec<(Vec<f64>, f64)>>;

/// Sample `slots` slots and return (offsets, true rates) per slot per stream.
fn drain(wl: &mut Workload, slots: usize) -> Drained {
    (0..slots)
        .map(|_| {
            wl.sample_slot();
            wl.streams
                .iter()
                .map(|s| (s.last_offsets.clone(), s.last_rate))
                .collect()
        })
        .collect()
}

#[test]
fn every_model_is_bit_deterministic_and_trace_faithful() {
    let net = test_net();
    forall("workload determinism", 20, |g| {
        let spec = WorkloadSpec::uniform(match g.usize_in(0, 4) {
            0 => ModelSpec::Poisson,
            1 => ModelSpec::Diurnal {
                period: g.f64_in(4.0, 50.0),
                amplitude: g.f64_in(0.0, 1.0),
                phase: g.f64_in(0.0, 6.28),
            },
            2 => ModelSpec::Mmpp {
                gain: g.f64_in(1.5, 8.0),
                dwell_base: g.f64_in(1.0, 20.0),
                dwell_burst: g.f64_in(1.0, 10.0),
            },
            3 => ModelSpec::FlashCrowd {
                peak: g.f64_in(1.5, 10.0),
                start: g.f64_in(0.0, 20.0),
                ramp: g.f64_in(0.5, 10.0),
                hold: g.f64_in(0.0, 10.0),
                decay: g.f64_in(0.5, 10.0),
            },
            _ => ModelSpec::Drift {
                slope: g.f64_in(-0.01, 0.05),
            },
        });
        let seed = g.rng().next_u64();
        // 1. equal seeds → bit-identical arrival sequences
        let mut w1 = Workload::from_spec(&spec, &net, 1.0, seed).unwrap();
        let mut w2 = Workload::from_spec(&spec, &net, 1.0, seed).unwrap();
        let (a, b) = (drain(&mut w1, 25), drain(&mut w2, 25));
        prop_assert!(g, a == b, "model {} not deterministic", spec.model.kind());
        // 2. recorded-then-replayed traces reproduce the arrivals exactly
        let mut w3 = Workload::from_spec(&spec, &net, 1.0, seed).unwrap();
        let trace = Trace::record(&mut w3, 25, None);
        let mut replayed = trace.workload();
        let c = drain(&mut replayed, 25);
        prop_assert!(g, a == c, "trace replay diverges for {}", spec.model.kind());
        true
    });
}

#[test]
fn trace_files_roundtrip_in_both_formats() {
    let net = test_net();
    let spec = WorkloadSpec::named("mmpp").unwrap();
    let mut wl = Workload::from_spec(&spec, &net, 1.0, 17).unwrap();
    let sc = Scenario::table2("abilene").unwrap();
    let trace = Trace::record(&mut wl, 40, Some(&sc));

    let dir = std::env::temp_dir().join(format!("scfo-trace-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for name in ["t.json", "t.csv"] {
        let path = dir.join(name);
        trace.save(&path).unwrap();
        let re = Trace::load(&path).unwrap();
        assert_eq!(trace, re, "{name} round trip must be lossless");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recorded_trace_replays_to_bit_identical_serving_results() {
    let net = test_net();
    let wspec = WorkloadSpec::named("diurnal").unwrap();
    let serve = |wl: Workload| -> Vec<f64> {
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::with_workload(net.clone(), gp, wl, ServerOptions::default());
        srv.run(60).unwrap().iter().map(|m| m.cost).collect()
    };
    // serve the live model
    let live = serve(Workload::from_spec(&wspec, &net, 1.0, 33).unwrap());
    // record the identically-seeded model, then serve the trace instead
    let mut rec = Workload::from_spec(&wspec, &net, 1.0, 33).unwrap();
    let trace = Trace::record(&mut rec, 60, None);
    let replayed = serve(trace.workload());
    assert_eq!(
        live, replayed,
        "trace-driven serving must be bit-identical to the live model"
    );
    // ... and so must a second replay of the same trace
    let again = serve(trace.workload());
    assert_eq!(replayed, again);
}

#[test]
fn controller_is_silent_under_stationary_poisson_and_cost_converges() {
    let net = test_net();
    let gp = GradientProjection::new(&net, GpOptions::default());
    let mut srv = OnlineServer::new(net.clone(), gp, ServerOptions::default());
    srv.attach_controller(AdaptationController::new(ControllerOptions::default()));
    let metrics = srv.run(150).unwrap();
    let summary = srv.controller.as_ref().unwrap().summary();
    assert_eq!(
        summary.detections, 0,
        "controller fired under stationary Poisson traffic"
    );
    assert_eq!(summary.reconverge_mean, 0.0);
    // the served cost approaches the offline clairvoyant GP optimum
    let mut offline = GradientProjection::new(&net, GpOptions::default());
    let opt = offline.run(&net, 2000).final_cost;
    let served = metrics.last().unwrap().cost;
    assert!(
        served <= opt * 1.15,
        "served cost {served} vs offline optimum {opt}"
    );
    // regret is positive early (cold start) but defined every slot
    assert!(summary.regret_total > 0.0);
    assert!(metrics.iter().all(|m| m.regret.unwrap().is_finite()));
}

#[test]
fn nonstationary_workload_triggers_detection_with_nonzero_metrics() {
    let net = test_net();
    let wl = Workload::from_spec(&WorkloadSpec::named("flash-crowd").unwrap(), &net, 1.0, 5)
        .unwrap();
    let gp = GradientProjection::new(&net, GpOptions::default());
    let mut srv = OnlineServer::with_workload(net, gp, wl, ServerOptions::default());
    srv.attach_controller(AdaptationController::new(ControllerOptions::default()));
    srv.run(90).unwrap();
    let summary = srv.controller.as_ref().unwrap().summary();
    assert!(summary.detections >= 1, "flash crowd must be detected");
    assert!(summary.regret_mean > 0.0);
    assert!(summary.reconverge_mean >= 1.0);
}
