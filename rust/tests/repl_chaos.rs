//! Chaos + linearization suite for the replicated control plane.
//!
//! **Failover chaos**: the `ha` tier scenario (elect → register burst in
//! flight → kill the leader → reconverge) must, under the `clean`, `lossy`
//! and `partition` fault presets,
//!
//! 1. lose **no committed epoch**: every entry committed before the kill is
//!    still at its index, bit-identical, on every survivor;
//! 2. have a surviving follower serving within a bounded number of virtual
//!    ticks of the kill; and
//! 3. be **bit-reproducible**: a rerun with the same `(seed, fault-spec)`
//!    yields identical commit indices, tick counts, fabric counters and
//!    serving cost bits.
//!
//! The fault seed honors `SCFO_CHAOS_SEED` so CI can sweep seeds; every run
//! prints one `repl-digest <scenario> <spec> <cost-bits> ...` line and the
//! CI `chaos-and-golden` job runs the suite twice per seed, failing on any
//! run-to-run output diff (the flakiness gate — see docs/TESTING.md).
//!
//! **Linearization**: the committed order IS the truth. For random command
//! scripts (register/update/drain/remove over a small id pool), random
//! fault knobs and a mid-script leader kill, every survivor's catalog after
//! applying its own committed prefix must equal a single-node
//! [`AppCatalog`] replaying the leader's committed log — same JSON, rate
//! sums within 1e-9. Failures shrink to a minimal counterexample via the
//! [`Shrink`] harness in `util/prop.rs`, at replica counts 3 and 5.

use scfo::control::replication::{apply_to_catalog, ReplCommand, ReplGroup};
use scfo::control::{AppCatalog, AppSpec, AppStatus};
use scfo::distributed::FaultSpec;
use scfo::scenarios::{runner, ScenarioCache, ScenarioSpec};
use scfo::util::prop::{forall_cases, PropResult, Shrink};

/// Fault seed: `SCFO_CHAOS_SEED` (CI sweeps it), default 7.
fn chaos_seed() -> u64 {
    std::env::var("SCFO_CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
}

/// The `ha` tier cell for one fault preset, sized down for the test and
/// re-seeded from the chaos seed (network seed stays fixed; the fault
/// stream is what the CI sweep varies, like `tests/chaos.rs`).
fn ha_spec(fault: &str, seed: u64) -> ScenarioSpec {
    let mut spec = ScenarioSpec::ha_matrix_sized(16, 3)
        .into_iter()
        .find(|s| s.name().ends_with(fault))
        .expect("ha matrix covers every fault preset");
    spec.iters = 120;
    let h = spec.ha.as_mut().expect("ha cell carries an ha spec");
    h.faults = FaultSpec::preset(fault, seed).expect("ha presets are valid");
    spec
}

#[test]
fn leader_kill_loses_no_committed_epoch_under_faults() {
    let seed = chaos_seed();
    let cache = ScenarioCache::new();
    for fault in ["clean", "lossy", "partition"] {
        let spec = ha_spec(fault, seed);
        let rep = runner::run_one(&spec, &cache)
            .unwrap_or_else(|e| panic!("ha scenario under '{fault}' failed: {e:#}"));
        let h = rep.ha.as_ref().expect("ha report carries an ha summary");
        assert_eq!(h.lost, 0, "'{fault}': lost a committed-before-kill entry");
        assert!(
            h.commit_at_kill >= 1,
            "'{fault}': kill happened before anything committed"
        );
        assert!(
            h.committed > h.commit_at_kill,
            "'{fault}': new leader never committed past the kill point"
        );
        // a surviving follower serves within a bounded number of virtual
        // ticks: under `partition` the survivors may have to wait out the
        // cut (heals at tick {heal}), so the bound is the heal horizon
        // plus an election + replication allowance
        let bound = spec
            .ha
            .as_ref()
            .expect("spec has ha")
            .faults
            .last_partition_end()
            + 600;
        assert!(
            h.failover_ticks > 0 && h.failover_ticks <= bound,
            "'{fault}': failover took {} ticks (bound {bound})",
            h.failover_ticks
        );
        assert!(h.final_term >= 2, "'{fault}': no new term after the kill");
        println!(
            "repl-digest {} {fault} {:016x} committed={} kill={} lost={} election={} failover={} msgs={} dropped={}",
            spec.name(),
            rep.gp_cost().to_bits(),
            h.committed,
            h.commit_at_kill,
            h.lost,
            h.election_ticks,
            h.failover_ticks,
            h.msgs_sent,
            h.msgs_dropped,
        );
    }
}

#[test]
fn failover_runs_are_bit_identical_per_seed_and_spec() {
    let seed = chaos_seed();
    let cache = ScenarioCache::new();
    for fault in ["clean", "lossy", "partition"] {
        let spec = ha_spec(fault, seed);
        let a = runner::run_one(&spec, &cache).expect("first run");
        let b = runner::run_one(&spec, &cache).expect("second run");
        let (ha, hb) = (a.ha.as_ref().unwrap(), b.ha.as_ref().unwrap());
        assert_eq!(
            a.gp_cost().to_bits(),
            b.gp_cost().to_bits(),
            "'{fault}': serving cost bits diverged across reruns"
        );
        for (name, va, vb) in [
            ("committed", ha.committed, hb.committed),
            ("commit_at_kill", ha.commit_at_kill, hb.commit_at_kill),
            ("election_ticks", ha.election_ticks, hb.election_ticks),
            ("failover_ticks", ha.failover_ticks, hb.failover_ticks),
            ("msgs_sent", ha.msgs_sent, hb.msgs_sent),
            ("msgs_dropped", ha.msgs_dropped, hb.msgs_dropped),
            ("final_term", ha.final_term, hb.final_term),
            ("elections", ha.elections, hb.elections),
        ] {
            assert_eq!(va, vb, "'{fault}': {name} diverged across reruns");
        }
        assert_eq!(ha.proposed, hb.proposed, "'{fault}': proposed diverged");
        assert_eq!(ha.lost, hb.lost, "'{fault}': lost diverged");
    }
}

// ---- linearization property -----------------------------------------------

/// One step of a random command script over a small app-id pool. A compact
/// op code keeps the case debug-printable and shrinkable.
#[derive(Clone, Debug, PartialEq)]
enum Op {
    Register(usize),
    Update(usize),
    Drain(usize),
    Remove(usize),
}

impl Op {
    fn command(&self) -> ReplCommand {
        // deterministic little specs: the id index fixes every field, so
        // identical ops are identical commands on every shrink re-run
        let spec = |k: usize| AppSpec {
            id: format!("p-{k}"),
            dest: k % 3,
            num_tasks: 2,
            packet_sizes: vec![8.0 + k as f64, 4.0, 1.0],
            rates: vec![(k % 2, 0.2 + 0.1 * k as f64)],
            status: AppStatus::Active,
        };
        match self {
            Op::Register(k) => ReplCommand::Register(spec(*k)),
            Op::Update(k) => {
                let mut s = spec(*k);
                s.rates = vec![(*k % 2, 0.05 + 0.01 * *k as f64)];
                ReplCommand::Update(s)
            }
            Op::Drain(k) => ReplCommand::Drain(format!("p-{k}")),
            Op::Remove(k) => ReplCommand::Remove(format!("p-{k}")),
        }
    }
}

/// A linearization case: a script, a fleet size, fault knobs and a kill
/// point. Shrinking drops script ops, then calms the fault knobs, then
/// moves the kill earlier — in that order, so minimal counterexamples are
/// short clean scripts.
#[derive(Clone, Debug)]
struct ReplCase {
    ops: Vec<Op>,
    replicas: usize,
    kill_after: usize,
    drop: f64,
    max_delay: u64,
    seed: u64,
}

impl Shrink for ReplCase {
    fn shrink(&self) -> Vec<ReplCase> {
        let mut out = Vec::new();
        for i in 0..self.ops.len() {
            let mut c = self.clone();
            c.ops.remove(i);
            c.kill_after = c.kill_after.min(c.ops.len());
            out.push(c);
        }
        if self.drop > 0.0 {
            let mut c = self.clone();
            c.drop = 0.0;
            out.push(c);
        }
        if self.max_delay > 1 {
            let mut c = self.clone();
            c.max_delay = 1;
            out.push(c);
        }
        if self.kill_after > 0 {
            let mut c = self.clone();
            c.kill_after = 0;
            out.push(c);
        }
        if self.replicas > 3 {
            let mut c = self.clone();
            c.replicas = 3;
            out.push(c);
        }
        out
    }
}

/// Drive the case and judge it: every survivor's catalog after applying
/// its own committed prefix must equal the single-node replay of the
/// leader's committed log.
fn check_linearization(case: &ReplCase) -> PropResult {
    let faults = FaultSpec {
        name: "case".to_string(),
        seed: case.seed,
        drop: case.drop,
        dup: 0.0,
        min_delay: 1,
        max_delay: case.max_delay.max(1),
        partitions: Vec::new(),
    };
    let mut g = ReplGroup::new(case.replicas, case.seed, faults);
    if g.run_until_leader(2000).is_none() {
        return PropResult::Discard; // fault knobs too hostile to elect
    }
    let script: Vec<ReplCommand> = case.ops.iter().map(Op::command).collect();
    let mut killed = false;
    for (i, cmd) in script.iter().enumerate() {
        if i == case.kill_after && !killed && case.replicas >= 3 {
            if let Some(victim) = g.leader() {
                g.kill(victim);
                killed = true;
            }
        }
        // client-style retry: re-propose until the current leader carries
        // the command (duplicates are fine — tolerant apply absorbs them)
        let mut budget = 3000u64;
        loop {
            let Some(l) = g.leader() else {
                if budget == 0 {
                    return PropResult::Discard;
                }
                budget -= 1;
                g.step();
                continue;
            };
            let has = (1..=g.replicas[l].log_len())
                .any(|idx| g.replicas[l].log_entry(idx).map(|e| &e.cmd) == Some(cmd));
            if has {
                break;
            }
            if budget == 0 {
                return PropResult::Discard;
            }
            budget -= 1;
            g.propose(cmd.clone());
            g.step();
        }
    }
    // drain: run until every survivor committed the full leader log
    let mut budget = 4000u64;
    loop {
        let Some(l) = g.leader() else {
            if budget == 0 {
                return PropResult::Discard;
            }
            budget -= 1;
            g.step();
            continue;
        };
        let target = g.replicas[l].log_len();
        let all = (0..case.replicas)
            .filter(|&id| g.alive[id])
            .all(|id| g.replicas[id].commit_index() >= target);
        if all && target > 0 {
            break;
        }
        if budget == 0 {
            return PropResult::Discard;
        }
        budget -= 1;
        g.step();
    }

    // reference: a single-node catalog replaying the leader's committed log
    let leader = g.leader().expect("drain loop ended with a leader");
    let commit = g.replicas[leader].commit_index();
    let mut reference = AppCatalog::new();
    for idx in 1..=commit {
        let entry = g.replicas[leader].log_entry(idx).expect("committed entry");
        if let Err(e) = apply_to_catalog(&mut reference, &entry.cmd) {
            return PropResult::Fail(format!("reference apply failed at {idx}: {e:#}"));
        }
    }
    let want = reference.to_json().to_string();
    let want_rate: f64 = reference
        .iter()
        .flat_map(|a| a.rates.iter().map(|&(_, r)| r))
        .sum();

    for id in 0..case.replicas {
        if !g.alive[id] {
            continue;
        }
        let mut cat = AppCatalog::new();
        for (_, cmd) in g.replicas[id].take_committed() {
            if let Err(e) = apply_to_catalog(&mut cat, &cmd) {
                return PropResult::Fail(format!("replica {id} apply failed: {e:#}"));
            }
        }
        let got = cat.to_json().to_string();
        if got != want {
            return PropResult::Fail(format!(
                "replica {id} catalog diverged from the committed-order replay\n got: {got}\nwant: {want}"
            ));
        }
        let got_rate: f64 = cat
            .iter()
            .flat_map(|a| a.rates.iter().map(|&(_, r)| r))
            .sum();
        if (got_rate - want_rate).abs() > 1e-9 {
            return PropResult::Fail(format!(
                "replica {id} rate mass diverged: {got_rate} vs {want_rate}"
            ));
        }
    }
    PropResult::Pass
}

#[test]
fn committed_order_is_a_linearization_with_shrinking() {
    let sweep = chaos_seed();
    forall_cases(
        "repl committed order is a linearization",
        24,
        |g| {
            let len = g.usize_in(1, 8);
            let ops = (0..len)
                .map(|_| {
                    let k = g.usize_in(0, 3);
                    match g.usize_in(0, 3) {
                        0 => Op::Register(k),
                        1 => Op::Update(k),
                        2 => Op::Drain(k),
                        _ => Op::Remove(k),
                    }
                })
                .collect::<Vec<_>>();
            let replicas = if g.bool(0.5) { 3 } else { 5 };
            ReplCase {
                kill_after: g.usize_in(0, ops.len()),
                ops,
                replicas,
                drop: if g.bool(0.5) { 0.1 } else { 0.0 },
                max_delay: g.usize_in(1, 4) as u64,
                seed: sweep ^ g.rng().usize(1 << 30) as u64,
            }
        },
        check_linearization,
    );
}
