//! Distributed-runtime integration: larger topologies, shard sweeps, both
//! transports, online churn, and the serving-loop reconvergence hooks.
//!
//! The acceptance-gated er-1000-4000 run (≥ 4 shards, both transports,
//! within 1e-6 of the centralized final cost, bit-reproducible) is
//! `#[ignore]`d here because it needs a release build to finish promptly;
//! CI's `chaos-and-golden` job runs it with
//! `cargo test --release --test distributed_integration -- --ignored`.

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::distributed::{AsyncRuntime, FaultSpec, RuntimeOptions};
use scfo::prelude::*;

fn build(family: &str) -> Network {
    let mut spec = ScenarioSpec::named(family, Congestion::Nominal).unwrap();
    if family != "abilene" && family != "geant" {
        spec.apply_scale_overrides();
    }
    let sc = spec.effective_base();
    let mut rng = Rng::new(sc.seed);
    sc.build(&mut rng).unwrap()
}

fn centralized(net: &Network, iters: usize) -> f64 {
    let mut gp = GradientProjection::new(
        net,
        GpOptions {
            residual_tol: 1e-9,
            ..GpOptions::default()
        },
    );
    gp.run(net, iters).final_cost
}

fn run_async(net: &Network, faults: Option<FaultSpec>, shards: usize, max_epochs: u64) -> scfo::distributed::RunReport {
    let phi0 = Strategy::shortest_path_to_dest(net);
    let opts = RuntimeOptions {
        shards,
        max_epochs,
        ..RuntimeOptions::default()
    };
    let mut rt = match faults {
        Some(f) => AsyncRuntime::sim_net(net.clone(), phi0, f, opts),
        None => AsyncRuntime::in_mem(net.clone(), phi0, opts),
    };
    rt.run_until_quiescent()
}

#[test]
fn geant_async_runtime_converges_to_centralized_optimum() {
    let net = build("geant");
    let rep = run_async(&net, None, 4, 12_000);
    assert!(rep.converged);
    let opt = centralized(&net, 8000);
    let rel = (rep.final_cost - opt).abs() / (1.0 + opt);
    assert!(rel < 1e-6, "geant async {} vs {opt} (rel {rel:.2e})", rep.final_cost);
}

#[test]
fn er_200_800_four_shards_both_transports_within_1e6() {
    let net = build("er-200-800");
    let opt = centralized(&net, 8000);
    let clean = run_async(&net, None, 4, 12_000);
    assert!(clean.converged, "in-mem: no quiescence in {} epochs", clean.epochs);
    let rel = (clean.final_cost - opt).abs() / (1.0 + opt);
    assert!(rel < 1e-6, "in-mem {} vs {opt} (rel {rel:.2e})", clean.final_cost);

    let lossy = run_async(&net, Some(FaultSpec::lossy(5)), 4, 12_000);
    assert!(lossy.converged, "sim-net: no quiescence in {} epochs", lossy.epochs);
    assert!(lossy.stats.transport.dropped_fault > 0);
    let rel = (lossy.final_cost - opt).abs() / (1.0 + opt);
    assert!(rel < 1e-6, "sim-net {} vs {opt} (rel {rel:.2e})", lossy.final_cost);

    // bit-reproducible per (seed, fault-spec)
    let again = run_async(&net, Some(FaultSpec::lossy(5)), 4, 12_000);
    assert_eq!(lossy.final_cost.to_bits(), again.final_cost.to_bits());
    assert_eq!(lossy.stats, again.stats);
}

#[test]
fn rate_churn_is_tracked_by_the_async_runtime() {
    let net = build("abilene");
    let phi0 = Strategy::shortest_path_to_dest(&net);
    let mut rt = AsyncRuntime::in_mem(net, phi0, RuntimeOptions::default());
    rt.run_until_quiescent();
    for round in 0..2 {
        let scale = if round % 2 == 0 { 1.25 } else { 0.8 };
        let napps = rt.network().apps.len();
        for a in 0..napps {
            let src = rt.network().apps[a]
                .input_rates
                .iter()
                .position(|&r| r > 0.0)
                .unwrap();
            let r = rt.network().apps[a].input_rates[src];
            rt.set_input_rate(a, src, r * scale);
        }
        let rep = rt.run_until_quiescent();
        assert!(rep.converged, "round {round}: no re-quiescence");
        let truth = rt.network().clone();
        let opt = centralized(&truth, 8000);
        let rel = (rep.final_cost - opt).abs() / (1.0 + opt);
        assert!(
            rel < 1e-6,
            "round {round}: settled {} vs optimum {opt} (rel {rel:.2e})",
            rep.final_cost
        );
    }
}

/// Acceptance-gated heavy run: er-1000-4000 with ≥ 4 shards under both
/// transports, within 1e-6 of centralized GP, bit-reproducible.
#[test]
#[ignore = "heavy: run in release (CI chaos-and-golden job runs it with --ignored)"]
fn er_1000_4000_four_shards_both_transports_within_1e6() {
    let net = build("er-1000-4000");
    let opt = centralized(&net, 20_000);

    let clean = run_async(&net, None, 4, 20_000);
    assert!(clean.converged, "in-mem: no quiescence in {} epochs", clean.epochs);
    let rel = (clean.final_cost - opt).abs() / (1.0 + opt);
    assert!(rel < 1e-6, "in-mem {} vs {opt} (rel {rel:.2e})", clean.final_cost);

    let spec = FaultSpec::lossy(9);
    let lossy = run_async(&net, Some(spec.clone()), 4, 20_000);
    assert!(lossy.converged, "sim-net: no quiescence in {} epochs", lossy.epochs);
    let rel = (lossy.final_cost - opt).abs() / (1.0 + opt);
    assert!(rel < 1e-6, "sim-net {} vs {opt} (rel {rel:.2e})", lossy.final_cost);

    let again = run_async(&net, Some(spec), 4, 20_000);
    assert_eq!(
        lossy.final_cost.to_bits(),
        again.final_cost.to_bits(),
        "er-1000-4000 lossy rerun not bit-identical"
    );
    assert_eq!(lossy.stats, again.stats);

    // report columns the scenario tier exposes must be live
    assert!(lossy.stats.transport.sent > 0);
    assert!(lossy.stats.transport.bytes_sent > 0);
    assert!(lossy.stats.transport.max_queue_depth > 0);
}
