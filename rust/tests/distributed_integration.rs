//! Distributed-runtime integration: larger topologies, heavier loss, churn.

use std::time::Duration;

use scfo::config::Scenario;
use scfo::distributed::{Cluster, ClusterOptions, LossyConfig};
use scfo::prelude::*;

#[test]
fn geant_cluster_converges_to_centralized_optimum() {
    let sc = Scenario::table2("geant").unwrap();
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng).unwrap();
    let phi0 = Strategy::shortest_path_to_dest(&net);
    let mut cluster = Cluster::spawn(
        net.clone(),
        phi0,
        ClusterOptions {
            alpha: 0.1,
            ..Default::default()
        },
    );
    cluster.run(1200);
    let distributed = cluster.cost();
    cluster.shutdown();

    let mut gp = GradientProjection::new(&net, GpOptions::default());
    let optimum = gp.run(&net, 2500).final_cost;
    assert!(
        distributed <= optimum * 1.10 + 1e-9,
        "distributed {distributed} vs centralized {optimum}"
    );
}

#[test]
fn heavy_loss_still_makes_progress() {
    // moderate load: this test isolates loss handling, not saturation
    let mut sc = Scenario::table2("abilene").unwrap();
    sc.rate_scale = 0.7;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng).unwrap();
    let phi0 = Strategy::shortest_path_to_dest(&net);
    let start_cost = scfo::flow::FlowState::solve(&net, &phi0).unwrap().total_cost;
    let mut cluster = Cluster::spawn(
        net.clone(),
        phi0,
        ClusterOptions {
            alpha: 0.1,
            slot_timeout: Duration::from_millis(200),
            lossy: Some(LossyConfig {
                drop_prob: 0.05,
                seed: 3,
            }),
            adaptive: true,
        },
    );
    let outcomes = cluster.run(60);
    let applied = outcomes.iter().filter(|o| o.applied).count();
    assert!(applied >= 10, "almost nothing applied under 5% loss: {applied}");
    assert!(cluster.dropped_messages() > 0);
    let end = cluster.cost();
    assert!(
        end < start_cost,
        "no progress under loss: {start_cost} -> {end}"
    );
    // state stays sane throughout
    cluster.phi.validate(&net).unwrap();
    assert!(!cluster.phi.has_loop());
    cluster.shutdown();
}

#[test]
fn rate_churn_tracked_by_cluster() {
    let sc = Scenario::table2("abilene").unwrap();
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng).unwrap();
    let phi0 = Strategy::shortest_path_to_dest(&net);
    let mut cluster = Cluster::spawn(net, phi0, ClusterOptions::default());
    cluster.run(60);
    // churn every app's first source up and down repeatedly; after each
    // stationary stretch the cluster must sit near the clairvoyant optimum
    // for the CURRENT rates
    for round in 0..3 {
        let scale = if round % 2 == 0 { 1.25 } else { 0.8 };
        let napps = cluster.network().apps.len();
        for a in 0..napps {
            let src = cluster
                .network()
                .apps[a]
                .input_rates
                .iter()
                .position(|&r| r > 0.0)
                .unwrap();
            let r = cluster.network().apps[a].input_rates[src];
            cluster.set_input_rate(a, src, r * scale);
        }
        cluster.run(120);
        let settled = cluster.cost();
        let truth = cluster.network().clone();
        let mut gp = GradientProjection::new(&truth, GpOptions::default());
        let opt = gp.run(&truth, 2500).final_cost;
        assert!(
            settled <= opt * 1.15 + 1e-9,
            "round {round}: settled {settled} vs optimum {opt}"
        );
    }
    cluster.shutdown();
}
