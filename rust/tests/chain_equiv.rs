//! Degenerate-equivalence test layer for the generalized chain model.
//!
//! The `chain` subsystem (per-stage data scaling, result-return flows,
//! fractional offload splits) rests on one invariant: a **degenerate**
//! chain — every scale factor 1.0, result size 0.0, no fractional splits —
//! reproduces the original service-chain model exactly. Every return-flow
//! term is gated on `ret > 0` and every conversion factor multiplies by
//! literal 1.0 (bit-exact in IEEE 754), so the degenerate path is not just
//! "close": it is the legacy code path. This suite pins that three ways:
//!
//! 1. a shrinking property test (`util::prop`) over (family, congestion,
//!    spelling, seed) tuples: the identity chain's GP run matches the plain
//!    network's cost trajectory within 1e-9 **and** its φ trajectory
//!    bit-for-bit, for both the `"identity"` named spelling and the
//!    all-ones `Explicit` spelling,
//! 2. the full scenario engine (initial solve, dynamic events, all three
//!    baselines) is bit-identical between a plain spec and the same spec
//!    with an identity chain, across the default-matrix families,
//! 3. a non-degenerate guard: a real DNN profile must *change* the cost,
//!    so a silently ignored chain config cannot pass as equivalence.
//!
//! The `chain_digest_is_stable` case prints one
//! `chain-digest <family> <spec> <cost-bits>` line per (family, chain
//! spec) cell under `SCFO_CHAIN_SEED`; the CI `chaos-and-golden` job runs
//! the suite twice per seed and fails on any run-to-run diff (the
//! flakiness gate — see docs/TESTING.md).

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::chain::ChainSpec;
use scfo::scenarios::{run_batch, Congestion, RunnerOptions, ScenarioCache, ScenarioSpec};
use scfo::util::prop::{forall_cases, PropResult};
use scfo::util::rng::Rng;

/// The default-matrix topology families (mirrors `ScenarioSpec::matrix`).
const FAMILIES: [&str; 5] = ["er-20-40", "grid-4x5", "fat-tree-4", "abilene", "geant"];

fn chain_seed() -> u64 {
    std::env::var("SCFO_CHAIN_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(11)
}

fn quiet() -> RunnerOptions {
    RunnerOptions {
        jobs: 2,
        out_dir: None,
        quiet: true,
    }
}

/// The central property: for every (family, congestion, spelling, seed),
/// a network built with a degenerate chain spec optimizes identically to
/// one built with no chain at all — GP costs within 1e-9 at every
/// iteration and bit-identical φ at every iteration. Failures shrink
/// toward the minimal (family, variant, seed) triple; the message pins
/// the first diverging iteration.
#[test]
fn identity_chain_reproduces_the_legacy_model() {
    forall_cases(
        "identity chain == legacy model",
        20,
        |g| {
            (
                (g.usize_in(0, FAMILIES.len() - 1), g.usize_in(0, 5)),
                g.rng().next_u64(),
            )
        },
        |&((fidx, variant), seed)| {
            let Some(&family) = FAMILIES.get(fidx) else {
                return PropResult::Discard;
            };
            if variant > 5 {
                return PropResult::Discard;
            }
            let congestion = Congestion::ALL[variant % 3];
            let explicit_spelling = variant >= 3;
            let spec = ScenarioSpec::named(family, congestion).unwrap();
            let mut plain = spec.effective_base();
            plain.seed ^= seed;
            let mut chained = plain.clone();
            chained.chain = Some(if explicit_spelling {
                ChainSpec::Explicit {
                    scale: vec![1.0; chained.num_tasks],
                    result_size: 0.0,
                    local_frac: vec![0.0; chained.num_tasks],
                }
            } else {
                ChainSpec::named("identity").unwrap()
            });
            let fail = |msg: String| {
                PropResult::Fail(format!(
                    "family {family} congestion {} spelling {} seed {seed}: {msg}",
                    congestion.name(),
                    if explicit_spelling { "explicit" } else { "named" },
                ))
            };

            let net_a = plain.build(&mut Rng::new(plain.seed)).unwrap();
            let net_b = chained.build(&mut Rng::new(chained.seed)).unwrap();
            if net_b.stage_conv.iter().any(|&c| c != 1.0) {
                return fail("identity chain must resolve to all-ones stage_conv".into());
            }
            if net_b.stage_ret.iter().any(|&u| u != 0.0) {
                return fail("identity chain must resolve to all-zero stage_ret".into());
            }

            let mut gp_a = GradientProjection::new(&net_a, GpOptions::default());
            let mut gp_b = GradientProjection::new(&net_b, GpOptions::default());
            for it in 0..12 {
                let sa = gp_a.step(&net_a);
                let sb = gp_b.step(&net_b);
                if (sa.cost - sb.cost).abs() > 1e-9 {
                    return fail(format!(
                        "iter {it}: plain cost {} vs degenerate-chain cost {}",
                        sa.cost, sb.cost
                    ));
                }
                if gp_a.phi != gp_b.phi {
                    return fail(format!("iter {it}: φ trajectories diverged"));
                }
            }
            PropResult::Pass
        },
    );
}

/// The scenario engine end to end: initial solve, the default dynamic-event
/// schedule, and the GP/SPOC/LCOF/LPR-SC comparison are all bit-identical
/// between a plain spec and the same spec with an identity chain — every
/// baseline walks the generalized recursion through the same degenerate
/// gates the optimizer does.
#[test]
fn identity_chain_is_bit_identical_through_the_scenario_engine() {
    let cache = ScenarioCache::new();
    for family in FAMILIES {
        let mut spec = ScenarioSpec::named(family, Congestion::Nominal).unwrap();
        spec.iters = 120;
        let mut chained = spec.clone();
        chained.base.chain = Some(ChainSpec::named("identity").unwrap());
        let a = scfo::scenarios::runner::run_one(&spec, &cache).unwrap();
        let b = scfo::scenarios::runner::run_one(&chained, &cache).unwrap();
        assert_eq!(a.costs.len(), b.costs.len(), "{family}: algorithm sets differ");
        for ((n1, c1), (n2, c2)) in a.costs.iter().zip(&b.costs) {
            assert_eq!(n1, n2);
            assert!(
                c1.to_bits() == c2.to_bits(),
                "{family}/{n1}: plain {c1} vs identity-chain {c2} must be bit-identical"
            );
        }
        for (p1, p2) in a.phases.iter().zip(&b.phases) {
            assert_eq!(p1.label, p2.label, "{family}: phase schedules differ");
            assert!(
                p1.gp_cost.to_bits() == p2.gp_cost.to_bits(),
                "{family}/{}: plain {} vs identity-chain {}",
                p1.label,
                p1.gp_cost,
                p2.gp_cost
            );
        }
    }
}

/// Guard against a silently ignored chain config: a real DNN profile
/// (data inflation + result return) must move the optimized cost away
/// from the plain model's on every default-matrix family.
#[test]
fn dnn_profile_changes_the_optimized_cost() {
    for family in FAMILIES {
        let spec = ScenarioSpec::named(family, Congestion::Nominal).unwrap();
        let plain = spec.effective_base();
        let mut chained = plain.clone();
        chained.chain = Some(ChainSpec::named("vgg16").unwrap());
        let net_a = plain.build(&mut Rng::new(plain.seed)).unwrap();
        let net_b = chained.build(&mut Rng::new(chained.seed)).unwrap();
        let a = GradientProjection::new(&net_a, GpOptions::default())
            .run(&net_a, 60)
            .final_cost;
        let b = GradientProjection::new(&net_b, GpOptions::default())
            .run(&net_b, 60)
            .final_cost;
        assert!(a.is_finite() && b.is_finite(), "{family}: costs must be finite");
        assert!(
            (a - b).abs() > 1e-6,
            "{family}: vgg16 chain left the cost unchanged ({a} vs {b}) — \
             is the chain config being dropped?"
        );
    }
}

/// Every `dnn`-tier cell runs end to end and GP's generalized cost is at
/// most every baseline's (same tolerance the runner itself pins), strictly
/// below on the heavy-congestion cells where the congestion-blind
/// baselines pay for ignoring inflated inter-stage flows.
#[test]
fn dnn_tier_gp_is_at_most_every_baseline_and_strictly_better_under_heavy_congestion() {
    // sized down from (100, 150): same 12 cells, shorter serving horizon
    let specs = ScenarioSpec::dnn_matrix_sized(8, 40);
    assert_eq!(specs.len(), 12);
    let reports = run_batch(&specs, &quiet()).unwrap();
    for rep in &reports {
        let gp = rep.gp_cost();
        assert!(gp.is_finite() && gp > 0.0, "{}: GP cost {gp}", rep.name);
        assert!(
            rep.gp_within_baselines,
            "{}: GP not within baselines: {:?}",
            rep.name, rep.costs
        );
        for (name, cost) in rep.costs.iter().skip(1) {
            assert!(
                gp <= cost * (1.0 + 1e-6) + 1e-9,
                "{}: GP {gp} vs {name} {cost}",
                rep.name
            );
            if rep.congestion == "heavy" {
                assert!(
                    gp < *cost,
                    "{}: heavy-congestion cell needs a strict GP win over {name} \
                     (GP {gp} vs {cost})",
                    rep.name
                );
            }
        }
    }
}

/// One `chain-digest` line per (family, chain spec) cell: the GP cost bits
/// after a fixed budget on a seed-perturbed build. The CI flakiness gate
/// replays this under several `SCFO_CHAIN_SEED` values, twice each, and
/// diffs the output.
#[test]
fn chain_digest_is_stable() {
    let seed = chain_seed();
    for family in FAMILIES {
        for chain in ["plain", "identity", "vgg16", "resnet50"] {
            let spec = ScenarioSpec::named(family, Congestion::Nominal).unwrap();
            let mut sc = spec.effective_base();
            sc.seed ^= seed;
            if chain != "plain" {
                sc.chain = Some(ChainSpec::named(chain).unwrap());
            }
            let net = sc.build(&mut Rng::new(sc.seed)).unwrap();
            let cost = GradientProjection::new(&net, GpOptions::default())
                .run(&net, 40)
                .final_cost;
            assert!(cost.is_finite(), "{family}/{chain}: cost {cost}");
            println!("chain-digest {family} {chain} {:016x}", cost.to_bits());
        }
    }
}
