//! Allocation-free hot-path regression: after the first (warm-up) iteration,
//! `GradientProjection::step` must not touch the heap — every per-iteration
//! buffer lives in the preallocated `Workspace`. The same counted block
//! pins the observability layer's zero-cost-when-disabled contract: `step`
//! is instrumented with `obs_span!` sites (and the virtual-coordinate
//! stores), so any hidden allocation in a disabled span would trip the
//! counter; an explicit macro-layer block re-checks this directly, and an
//! enabled-recorder block proves recording into the preallocated ring
//! stays allocation-free too.
//!
//! This file holds exactly one test so the counting `#[global_allocator]`
//! only ever observes the allocations of the code under test (integration
//! tests are separate binaries; within this binary no other test thread can
//! allocate concurrently).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::app::{Application, Network, StageRegistry};
use scfo::cost::CostFn;
use scfo::graph::topologies;

struct CountingAlloc;

static ALLOCATIONS: AtomicUsize = AtomicUsize::new(0);
static COUNTING: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Abilene, one 2-task app — the same shape as the unit-test fixture, built
/// inline so this binary needs no crate features.
fn abilene_net() -> Network {
    let g = topologies::abilene();
    let n = g.n();
    let m = g.m();
    let mut r = vec![0.0; n];
    r[0] = 1.0;
    r[3] = 0.8;
    let apps = vec![Application {
        dest: 9,
        num_tasks: 2,
        packet_sizes: vec![10.0, 5.0, 1.0],
        input_rates: r,
    }];
    let stages = StageRegistry::new(&apps);
    let cw = vec![vec![1.0; n]; stages.len()];
    Network::new(
        g,
        apps,
        vec![CostFn::Queue { cap: 40.0 }; m],
        vec![CostFn::Queue { cap: 12.0 }; n],
        cw,
    )
    .unwrap()
}

#[test]
fn gp_step_is_allocation_free_after_warmup() {
    let net = abilene_net();
    let mut gp = GradientProjection::new(&net, GpOptions::default());
    // warm-up: the first step may still fault in lazily-grown structures
    gp.step(&net);

    // tracing is disabled (the default): the obs_span! sites inside step()
    // must be inert, so the 0-allocation assertion below also pins the
    // observability layer's disabled-path cost
    assert!(!scfo::obs::enabled());
    ALLOCATIONS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    let mut last_cost = f64::INFINITY;
    for _ in 0..10 {
        let st = std::hint::black_box(gp.step(&net));
        last_cost = st.cost;
    }
    COUNTING.store(false, Ordering::SeqCst);

    let count = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        count, 0,
        "GradientProjection::step allocated {count} times across 10 warm iterations"
    );
    assert!(last_cost.is_finite());
    // the optimizer still did real work under the counter
    gp.phi.validate(&net).unwrap();
    assert!(!gp.phi.has_loop());

    // the macro layer itself, counted directly: disabled spans and
    // coordinate stores never touch the heap
    ALLOCATIONS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    for i in 0..1000u64 {
        scfo::obs::set_slot(i);
        scfo::obs::set_gp_iter(i);
        scfo::obs::set_control_epoch(i);
        scfo::obs::set_topo_epoch(i);
        let _g = std::hint::black_box(scfo::obs_span!("test", "disabled"));
    }
    COUNTING.store(false, Ordering::SeqCst);
    let count = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        count, 0,
        "disabled obs_span!/coordinate stores allocated {count} times"
    );

    // enabled recording is allocation-free too: the ring's capacity is
    // reserved up front and span records are Copy (the clock read and the
    // mutex lock allocate nothing)
    scfo::obs::enable(4096);
    {
        // warm the recording path (first lock/tid assignment)
        let _g = scfo::obs_span!("test", "warm");
    }
    ALLOCATIONS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    for _ in 0..1000 {
        let _g = std::hint::black_box(scfo::obs_span!("test", "enabled"));
    }
    COUNTING.store(false, Ordering::SeqCst);
    let count = ALLOCATIONS.load(Ordering::SeqCst);
    scfo::obs::clear();
    assert_eq!(
        count, 0,
        "enabled span recording allocated {count} times across 1000 spans"
    );
}
