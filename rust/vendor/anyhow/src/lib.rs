//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The real `anyhow` is unavailable in this offline build, so this vendored
//! shim implements exactly the API subset the workspace uses: [`Error`],
//! [`Result`], and the [`anyhow!`], [`bail!`] and [`ensure!`] macros, plus
//! the blanket `From<E: std::error::Error>` conversion that makes `?` work.
//!
//! Semantics mirror the real crate where it matters:
//! * `Error` intentionally does **not** implement `std::error::Error` (so the
//!   blanket `From` impl does not collide with the reflexive `From<T> for T`);
//! * `Debug` prints the display message (the real crate prints message plus
//!   backtrace; there is no backtrace support here);
//! * no downcasting or context chaining — nothing in the workspace needs it.

use std::fmt;

/// An error message wrapper, boxed so `Result<T, Error>` stays one word.
pub struct Error {
    inner: Box<dyn std::error::Error + Send + Sync + 'static>,
}

/// `Result<T, anyhow::Error>` with the same defaulted form as the real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error {
            inner: Box::new(MessageError(message)),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { inner: Box::new(e) }
    }
}

/// Ad-hoc message error backing [`Error::msg`].
struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> std::error::Error for MessageError<M> {}

/// Create an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn needs_msg(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    fn bails() -> Result<()> {
        bail!("nope: {}", 42);
    }

    fn io_err() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/real/path/xyz")?;
        Ok(s)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(needs_msg(true).unwrap(), 7);
        let e = needs_msg(false).unwrap_err();
        assert_eq!(format!("{e}"), "flag was false");
        let b = bails().unwrap_err();
        assert_eq!(format!("{b}"), "nope: 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_err().unwrap_err();
        assert!(!format!("{e}").is_empty());
        assert!(!format!("{e:?}").is_empty());
    }

    #[test]
    fn anyhow_macro_single_expr() {
        let msg = String::from("plain");
        let e: Error = anyhow!(msg);
        assert_eq!(format!("{e}"), "plain");
    }
}
