//! Parallel scenario execution: thread-pooled batch runs, per-topology
//! caching, dynamic-event application, and machine-readable JSON reports.
//!
//! Determinism contract: a scenario's result is a pure function of its
//! [`ScenarioSpec`]. The topology cache stores, alongside each built graph,
//! the RNG state *after* the topology draws, so a cache hit replays exactly
//! the stream an uncached build would have used — results are identical
//! whatever the worker count or execution order (`--jobs 1` ≡ `--jobs N`;
//! covered by `rust/tests/scenarios.rs`).

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::algo::gp::{GpOptions, GradientProjection};
use crate::algo::Algorithm;
use crate::app::Network;
use crate::control::replication::LogEntry;
use crate::control::{
    AppSpec, AppStatus, ControlOptions, ControlPlane, ReplCommand, ReplGroup, Replica,
};
use crate::distributed::{AsyncRuntime, DistributedOptimizer, RuntimeOptions};
use crate::flow::FlowState;
use crate::graph::{topologies, Graph};
use crate::scenarios::{ChurnAction, DynamicEvent, ScenarioSpec};
use crate::serving::{
    AdaptationController, AdaptationSummary, ControllerOptions, OnlineServer, Optimizer,
    ServerOptions, StreamEstimator,
};
use crate::strategy::Strategy;
use crate::topo::TopologyState;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;
use crate::workload::Workload;

/// Batch-runner configuration.
#[derive(Clone, Debug)]
pub struct RunnerOptions {
    /// Worker threads (clamped to [1, number of scenarios]).
    pub jobs: usize,
    /// If set, one `<name>.json` report is written per scenario.
    pub out_dir: Option<PathBuf>,
    /// Suppress per-scenario progress lines on stderr.
    pub quiet: bool,
}

impl Default for RunnerOptions {
    fn default() -> Self {
        RunnerOptions {
            jobs: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            out_dir: None,
            quiet: false,
        }
    }
}

/// GP cost after one phase of a scenario (initial solve or a dynamic event).
#[derive(Clone, Debug)]
pub struct PhaseOutcome {
    /// `"initial"`, `"rate-scale"`, `"link-down"`, `"link-up"`.
    pub label: String,
    /// GP aggregate cost once the phase's adaptation budget is spent.
    pub gp_cost: f64,
}

/// The result of one executed scenario.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub name: String,
    pub topology: String,
    pub congestion: String,
    pub seed: u64,
    /// Network inventory: nodes, directed links, applications.
    pub n: usize,
    pub m: usize,
    pub apps: usize,
    /// GP cost after the initial solve and after each event.
    pub phases: Vec<PhaseOutcome>,
    /// Final-state cost per algorithm (GP first, then the baselines), all
    /// evaluated on the same final network.
    pub costs: Vec<(String, f64)>,
    /// True iff GP's final cost is ≤ every baseline's (within tolerance).
    pub gp_within_baselines: bool,
    /// Wall-clock seconds this scenario took (not part of determinism).
    pub solve_secs: f64,
    /// Whether the topology came from the shared cache.
    pub cache_hit: bool,
    /// Workload preset name for dynamic (serving-loop) scenarios.
    pub workload: Option<String>,
    /// Serving slots executed (dynamic scenarios only).
    pub slots: usize,
    /// Regret/reconvergence metrics (dynamic scenarios only).
    pub adaptation: Option<AdaptationSummary>,
    /// Async-runtime metrics (distributed scenarios only).
    pub distributed: Option<DistributedSummary>,
    /// Control-plane metrics (churn scenarios only).
    pub churn: Option<ChurnSummary>,
    /// Epoch-rebuild metrics (topo-churn scenarios only).
    pub topo_churn: Option<TopoChurnSummary>,
    /// Workload hot-path throughput metrics (massive scenarios only).
    pub massive: Option<MassiveSummary>,
    /// Replicated-control-plane metrics (ha scenarios only).
    pub ha: Option<HaSummary>,
}

/// Workload hot-path columns of a `massive` scenario report: stream count,
/// arrival volume, and the per-slot wall-time of the batched
/// sample → estimate → detect loop. The wall-time-derived columns
/// (`build_secs`, `slot_wall_ms_*`, `streams_per_sec`) are volatile — the
/// golden comparator skips them; everything else is bit-deterministic.
#[derive(Clone, Debug)]
pub struct MassiveSummary {
    /// Live arrival streams (= apps × sources).
    pub streams: usize,
    /// Serving slots executed.
    pub slots: usize,
    /// Total arrivals sampled across all slots.
    pub arrivals_total: usize,
    /// Change points the column-scan detector fired.
    pub detections: usize,
    /// Σ latest per-stream true rates after the last slot (offered load λ̄).
    pub offered_load: f64,
    /// Wall-clock seconds to build the network + workload + stream table.
    pub build_secs: f64,
    /// Mean wall-clock milliseconds per slot of the hot loop.
    pub slot_wall_ms_mean: f64,
    /// Worst slot wall-time in milliseconds.
    pub slot_wall_ms_max: f64,
    /// Streams processed per second of hot-loop wall time
    /// (streams ÷ mean slot seconds).
    pub streams_per_sec: f64,
    /// Per-phase breakdown of the mean slot wall time (milliseconds):
    /// SoA sampling passes …
    pub phase_sample_ms_mean: f64,
    /// … estimator column scan …
    pub phase_estimate_ms_mean: f64,
    /// … detector scan. Volatile like the other wall-time columns.
    pub phase_detect_ms_mean: f64,
}

impl MassiveSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("streams", Json::Num(self.streams as f64)),
            ("slots", Json::Num(self.slots as f64)),
            ("arrivals_total", Json::Num(self.arrivals_total as f64)),
            ("detections", Json::Num(self.detections as f64)),
            ("offered_load", Json::Num(self.offered_load)),
            ("build_secs", Json::Num(self.build_secs)),
            ("slot_wall_ms_mean", Json::Num(self.slot_wall_ms_mean)),
            ("slot_wall_ms_max", Json::Num(self.slot_wall_ms_max)),
            ("streams_per_sec", Json::Num(self.streams_per_sec)),
            ("phase_sample_ms_mean", Json::Num(self.phase_sample_ms_mean)),
            (
                "phase_estimate_ms_mean",
                Json::Num(self.phase_estimate_ms_mean),
            ),
            ("phase_detect_ms_mean", Json::Num(self.phase_detect_ms_mean)),
        ])
    }
}

/// Control-plane columns of a churn scenario report: scripted lifecycle
/// events, admission outcomes, epoch rebuilds, and the serving-slot spans
/// each accepted arrival needed to reconverge (cost back within 2% of the
/// best cost seen before the next event).
#[derive(Clone, Debug)]
pub struct ChurnSummary {
    pub events: usize,
    pub accepted: usize,
    pub rejected: usize,
    /// Epoch counter after the run (= committed fleet changes).
    pub epochs: u64,
    /// Applications still registered at the end (draining included).
    pub final_apps: usize,
    /// Per accepted arrival, slots from commit until the served cost
    /// re-entered 2% of the window optimum.
    pub reconverge_slots: Vec<usize>,
    /// Mean wall-clock seconds per admission evaluation (volatile — the
    /// golden comparator skips it).
    pub admission_latency_secs_mean: f64,
}

impl ChurnSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("events", Json::Num(self.events as f64)),
            ("accepted", Json::Num(self.accepted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("final_apps", Json::Num(self.final_apps as f64)),
            ("reconverge_slots", Json::arr_usize(&self.reconverge_slots)),
            (
                "admission_latency_secs_mean",
                Json::Num(self.admission_latency_secs_mean),
            ),
        ])
    }
}

/// Replicated-control-plane columns of an `ha` scenario report: one
/// scripted election → register burst → leader kill → failover cycle on a
/// simulated replica group ([`ReplGroup`]). `lost` counts
/// committed-before-kill log entries missing or rewritten after the
/// failover — the tier's core invariant is `lost == 0`, and [`run_ha`]
/// additionally fails the run outright if it is violated. Tick columns are
/// virtual time (bit-deterministic per seed + fault spec); the `*_secs`
/// and `commands_per_sec` columns are wall-clock (volatile — the golden
/// comparator skips them).
#[derive(Clone, Debug)]
pub struct HaSummary {
    /// Replica-group size.
    pub replicas: usize,
    /// Fault-preset name driving the simulated message fabric.
    pub faults: String,
    /// Accepted proposals: scripted registers and client-style retries
    /// after the kill. The failover leader's no-op barrier is appended by
    /// the consensus core itself on election (`become_leader`), so it only
    /// counts here on the fallback re-propose path.
    pub proposed: usize,
    /// Final commit index shared by every surviving replica.
    pub committed: u64,
    /// Highest commit index across the group at the moment of the kill.
    pub commit_at_kill: u64,
    /// Committed-before-kill entries lost or rewritten after failover.
    pub lost: usize,
    /// Election rounds started across the whole group.
    pub elections: u64,
    /// Term of the surviving leader after the run.
    pub final_term: u64,
    /// Virtual ticks from cold start to the first elected leader.
    pub election_ticks: u64,
    /// Virtual ticks from the leader kill to the first commit in the new
    /// leader's term.
    pub failover_ticks: u64,
    /// Control-plane epoch of the survivor after applying the committed log.
    pub epochs: u64,
    /// Applications registered on the survivor's plane.
    pub final_apps: usize,
    /// Fabric messages submitted.
    pub msgs_sent: u64,
    /// Fabric messages dropped (faults + partitions + dead receivers).
    pub msgs_dropped: u64,
    /// Wall-clock seconds of the cold-start election (volatile).
    pub election_secs: f64,
    /// Wall-clock seconds from the kill to fleet reconvergence (volatile).
    pub failover_secs: f64,
    /// Committed log entries per wall-clock second of the replication
    /// drive (volatile).
    pub commands_per_sec: f64,
}

impl HaSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("replicas", Json::Num(self.replicas as f64)),
            ("faults", Json::Str(self.faults.clone())),
            ("proposed", Json::Num(self.proposed as f64)),
            ("committed", Json::Num(self.committed as f64)),
            ("commit_at_kill", Json::Num(self.commit_at_kill as f64)),
            ("lost", Json::Num(self.lost as f64)),
            ("elections", Json::Num(self.elections as f64)),
            ("final_term", Json::Num(self.final_term as f64)),
            ("election_ticks", Json::Num(self.election_ticks as f64)),
            ("failover_ticks", Json::Num(self.failover_ticks as f64)),
            ("epochs", Json::Num(self.epochs as f64)),
            ("final_apps", Json::Num(self.final_apps as f64)),
            ("msgs_sent", Json::Num(self.msgs_sent as f64)),
            ("msgs_dropped", Json::Num(self.msgs_dropped as f64)),
            ("election_secs", Json::Num(self.election_secs)),
            ("failover_secs", Json::Num(self.failover_secs)),
            ("commands_per_sec", Json::Num(self.commands_per_sec)),
        ])
    }
}

/// Topology-churn columns of a `topo-churn` scenario report. Every applied
/// change (scripted flap/outage or a due repair batch) is one epoch rebuild:
/// the CSR arena is rebuilt on the surviving graph, φ is slot-remapped
/// ([`Strategy::rebind_topology`]) and GP warm-starts from it. Per change
/// the report carries the rebind latency (volatile), the serving slots the
/// warm strategy needed to re-enter 2% of a fresh-build oracle's cost, the
/// slots a cold min-hop restart would have needed on the same graph, and
/// the retained cost optimality (oracle cost ÷ warm cost right after the
/// rebind, before any re-optimization — 1.0 means the remap lost nothing).
#[derive(Clone, Debug)]
pub struct TopoChurnSummary {
    /// Scripted events in the schedule.
    pub events: usize,
    /// Applied topology changes = epoch rebuilds (events that removed
    /// something, plus due-repair batches).
    pub changes: usize,
    /// Topology epoch counter after the run.
    pub epochs: u64,
    /// Link pairs removed across the run (before their repairs).
    pub removed_pairs_total: usize,
    /// Mean wall-clock seconds per arena rebind (volatile — the golden
    /// comparator skips it).
    pub rebind_secs_mean: f64,
    /// Per change, slots from the warm rebind until cost ≤ 1.02 · oracle.
    pub reconverge_slots_warm: Vec<usize>,
    /// Per change, iterations a cold min-hop restart needed for the same
    /// target (measured on a throwaway GP, one iteration per slot).
    pub reconverge_slots_cold: Vec<usize>,
    /// Per change, oracle cost ÷ warm post-rebind cost (≤ ~1.0).
    pub retained_optimality: Vec<f64>,
}

impl TopoChurnSummary {
    fn mean(xs: &[f64]) -> f64 {
        if xs.is_empty() {
            0.0
        } else {
            xs.iter().sum::<f64>() / xs.len() as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let warm: Vec<f64> = self.reconverge_slots_warm.iter().map(|&s| s as f64).collect();
        let cold: Vec<f64> = self.reconverge_slots_cold.iter().map(|&s| s as f64).collect();
        Json::obj(vec![
            ("events", Json::Num(self.events as f64)),
            ("changes", Json::Num(self.changes as f64)),
            ("epochs", Json::Num(self.epochs as f64)),
            (
                "removed_pairs_total",
                Json::Num(self.removed_pairs_total as f64),
            ),
            ("rebind_secs_mean", Json::Num(self.rebind_secs_mean)),
            (
                "reconverge_slots_warm",
                Json::arr_usize(&self.reconverge_slots_warm),
            ),
            (
                "reconverge_slots_cold",
                Json::arr_usize(&self.reconverge_slots_cold),
            ),
            ("reconverge_slots_warm_mean", Json::Num(Self::mean(&warm))),
            ("reconverge_slots_cold_mean", Json::Num(Self::mean(&cold))),
            (
                "retained_optimality",
                Json::arr_f64(&self.retained_optimality),
            ),
            (
                "retained_optimality_mean",
                Json::Num(Self::mean(&self.retained_optimality)),
            ),
        ])
    }
}

/// Async-runtime columns of a distributed scenario report: rounds (epochs),
/// message/byte counts, queue depth, stale reads, and the
/// distributed-vs-centralized cost gap.
#[derive(Clone, Debug)]
pub struct DistributedSummary {
    pub shards: usize,
    pub transport: String,
    pub faults: String,
    /// Did the distributed quiescence detector fire within the budget?
    /// `None` in serving (dynamic-tier) mode, where there is no quiescence
    /// run — the adaptation block's regret is the relevant metric there.
    pub converged: Option<bool>,
    /// Measurement epochs ("rounds").
    pub rounds: u64,
    pub ticks: u64,
    pub messages_sent: usize,
    pub messages_delivered: usize,
    pub messages_dropped: usize,
    pub bytes_sent: u64,
    pub max_queue_depth: usize,
    pub stale_reads: u64,
    pub reverted_stages: usize,
    pub control_messages: usize,
    /// |distributed − centralized| / (1 + centralized). `None` in serving
    /// mode (no centralized reference is solved there).
    pub rel_gap_to_centralized: Option<f64>,
}

impl DistributedSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards as f64)),
            ("transport", Json::Str(self.transport.clone())),
            ("faults", Json::Str(self.faults.clone())),
            (
                "converged",
                match self.converged {
                    Some(c) => Json::Bool(c),
                    None => Json::Null,
                },
            ),
            ("rounds", Json::Num(self.rounds as f64)),
            ("ticks", Json::Num(self.ticks as f64)),
            ("messages_sent", Json::Num(self.messages_sent as f64)),
            ("messages_delivered", Json::Num(self.messages_delivered as f64)),
            ("messages_dropped", Json::Num(self.messages_dropped as f64)),
            ("bytes_sent", Json::Num(self.bytes_sent as f64)),
            ("max_queue_depth", Json::Num(self.max_queue_depth as f64)),
            ("stale_reads", Json::Num(self.stale_reads as f64)),
            ("reverted_stages", Json::Num(self.reverted_stages as f64)),
            ("control_messages", Json::Num(self.control_messages as f64)),
            (
                "rel_gap_to_centralized",
                match self.rel_gap_to_centralized {
                    Some(g) => Json::Num(g),
                    None => Json::Null,
                },
            ),
        ])
    }
}

impl ScenarioReport {
    /// GP's final cost.
    pub fn gp_cost(&self) -> f64 {
        self.costs
            .first()
            .map(|(_, c)| *c)
            .unwrap_or(f64::INFINITY)
    }

    /// Serialize for the per-scenario report file.
    pub fn to_json(&self) -> Json {
        let costs = Json::Obj(
            self.costs
                .iter()
                .map(|(name, c)| (name.clone(), Json::Num(*c)))
                .collect(),
        );
        let phases = Json::Arr(
            self.phases
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("label", Json::Str(p.label.clone())),
                        ("gp_cost", Json::Num(p.gp_cost)),
                    ])
                })
                .collect(),
        );
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("congestion", Json::Str(self.congestion.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("n", Json::Num(self.n as f64)),
            ("m", Json::Num(self.m as f64)),
            ("apps", Json::Num(self.apps as f64)),
            ("phases", phases),
            ("costs", costs),
            ("gp_within_baselines", Json::Bool(self.gp_within_baselines)),
            ("solve_secs", Json::Num(self.solve_secs)),
            ("cache_hit", Json::Bool(self.cache_hit)),
        ];
        if let Some(w) = &self.workload {
            pairs.push(("workload", Json::Str(w.clone())));
        }
        if self.workload.is_some()
            || self.churn.is_some()
            || self.topo_churn.is_some()
            || self.ha.is_some()
        {
            pairs.push(("slots", Json::Num(self.slots as f64)));
        }
        if let Some(a) = &self.adaptation {
            pairs.push(("adaptation", a.to_json()));
        }
        if let Some(d) = &self.distributed {
            pairs.push(("distributed", d.to_json()));
        }
        if let Some(c) = &self.churn {
            pairs.push(("churn", c.to_json()));
        }
        if let Some(t) = &self.topo_churn {
            pairs.push(("topo_churn", t.to_json()));
        }
        if let Some(ms) = &self.massive {
            pairs.push(("massive", ms.to_json()));
        }
        if let Some(h) = &self.ha {
            pairs.push(("ha", h.to_json()));
        }
        Json::obj(pairs)
    }
}

/// Shared per-topology state reused between related runs.
///
/// * `graphs` — built topology + post-topology RNG state, keyed by
///   `(topology, seed)`; congestion variants of the same family share it.
/// * `init_strategies` — the min-hop initial strategy per network signature
///   (graph + application destinations/chain lengths), shared across
///   congestion levels since rates do not affect it.
pub struct ScenarioCache {
    graphs: Mutex<BTreeMap<String, (Arc<Graph>, Rng)>>,
    init_strategies: Mutex<BTreeMap<String, Arc<Strategy>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl Default for ScenarioCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ScenarioCache {
    pub fn new() -> ScenarioCache {
        ScenarioCache {
            graphs: Mutex::new(BTreeMap::new()),
            init_strategies: Mutex::new(BTreeMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// (hits, misses) across both caches.
    pub fn stats(&self) -> (usize, usize) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// The topology for `spec`, plus the RNG positioned exactly after the
    /// topology draws, plus whether this was a cache hit.
    fn topology(&self, spec: &ScenarioSpec) -> anyhow::Result<(Arc<Graph>, Rng, bool)> {
        let key = format!("{}#{}", spec.base.topology, spec.base.seed);
        if let Some((g, rng)) = self.graphs.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((Arc::clone(g), rng.clone(), true));
        }
        // Build outside the lock; last writer wins (both built identically).
        let mut rng = Rng::new(spec.base.seed);
        let graph = Arc::new(topologies::by_name(&spec.base.topology, &mut rng)?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.graphs
            .lock()
            .unwrap()
            .insert(key, (Arc::clone(&graph), rng.clone()));
        Ok((graph, rng, false))
    }

    /// The min-hop initial strategy for `net`, cached per network signature.
    fn initial_strategy(&self, spec: &ScenarioSpec, net: &Network) -> Arc<Strategy> {
        let dests: Vec<String> = net
            .apps
            .iter()
            .map(|a| format!("{}:{}", a.dest, a.num_tasks))
            .collect();
        let key = format!(
            "{}#{}#{}",
            spec.base.topology,
            spec.base.seed,
            dests.join(",")
        );
        if let Some(phi) = self.init_strategies.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(phi);
        }
        let phi = Arc::new(Strategy::shortest_path_to_dest(net));
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.init_strategies
            .lock()
            .unwrap()
            .insert(key, Arc::clone(&phi));
        phi
    }
}

/// The most-loaded directed link whose removal keeps every application's
/// destination reachable from every node (deterministic: flow-descending,
/// ties by edge id). Returns `None` when no loaded link can be removed.
fn pick_removable_link(
    net: &Network,
    phi: &Strategy,
    removed: &[(usize, usize)],
) -> Option<(usize, usize)> {
    let fs = FlowState::solve(net, phi).ok()?;
    let mut order: Vec<usize> = (0..net.m()).collect();
    order.sort_by(|&a, &b| {
        fs.link_flow[b]
            .partial_cmp(&fs.link_flow[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    for e in order {
        if fs.link_flow[e] <= 0.0 {
            break; // only loaded links are interesting failures
        }
        let (i, j) = net.graph.edge(e);
        if removed.contains(&(i, j)) {
            continue;
        }
        if reachability_survives(net, removed, (i, j)) {
            return Some((i, j));
        }
    }
    None
}

/// Does every app destination stay reachable from every node once `extra`
/// is removed on top of the already-removed links?
fn reachability_survives(
    net: &Network,
    removed: &[(usize, usize)],
    extra: (usize, usize),
) -> bool {
    let mut excluded: BTreeSet<(usize, usize)> = removed.iter().copied().collect();
    excluded.insert(extra);
    let edges: Vec<(usize, usize)> = net
        .graph
        .edges()
        .iter()
        .copied()
        .filter(|e| !excluded.contains(e))
        .collect();
    match Graph::new(net.n(), &edges) {
        Ok(g) => net.apps.iter().all(|a| g.all_reach(a.dest)),
        Err(_) => false,
    }
}

/// Rebuild `net` without the removed directed links (for the baselines,
/// which have no online-adaptation path and re-solve from scratch).
fn prune_links(net: &Network, removed: &[(usize, usize)]) -> anyhow::Result<Network> {
    let excluded: BTreeSet<(usize, usize)> = removed.iter().copied().collect();
    let mut edges = Vec::with_capacity(net.m() - excluded.len());
    let mut link_cost = Vec::with_capacity(net.m() - excluded.len());
    for e in 0..net.m() {
        let ij = net.graph.edge(e);
        if !excluded.contains(&ij) {
            edges.push(ij);
            link_cost.push(net.link_cost[e].clone());
        }
    }
    let graph = Graph::new(net.n(), &edges)?;
    Network::new(
        graph,
        net.apps.clone(),
        link_cost,
        net.comp_cost.clone(),
        net.comp_weight.clone(),
    )
}

/// Execute one scenario. Specs with a `workload` run through the online
/// serving loop ([`run_dynamic`]); specs with only a `distributed` block run
/// the async runtime to quiescence ([`run_distributed`]); otherwise: initial
/// GP solve, the dynamic-event schedule with online adaptation, then the
/// final GP-vs-baselines comparison on the resulting network state.
pub fn run_one(spec: &ScenarioSpec, cache: &ScenarioCache) -> anyhow::Result<ScenarioReport> {
    if spec.massive {
        return run_massive(spec, cache);
    }
    if spec.ha.is_some() {
        return run_ha(spec);
    }
    if spec.topo_churn.is_some() {
        return run_topo_churn(spec, cache);
    }
    if spec.churn.is_some() {
        return run_churn(spec);
    }
    if spec.workload.is_some() {
        return run_dynamic(spec, cache);
    }
    if spec.distributed.is_some() {
        return run_distributed(spec, cache);
    }
    let watch = Stopwatch::start();
    let (graph, mut rng, cache_hit) = cache.topology(spec)?;
    // `full_net` keeps every link of the built topology (rates mutate in
    // place on demand steps); `net` is the epoch's live network — a pruned
    // rebuild of `full_net` minus the currently-failed links.
    let mut full_net = spec.effective_base().build_on((*graph).clone(), &mut rng)?;

    let phi0 = cache.initial_strategy(spec, &full_net);
    let mut gp =
        GradientProjection::with_strategy(&full_net, (*phi0).clone(), GpOptions::default());
    let mut net = full_net.clone();
    let mut phases = Vec::with_capacity(spec.events.len() + 1);
    gp.run(&net, spec.iters);
    phases.push(PhaseOutcome {
        label: "initial".to_string(),
        gp_cost: gp.cost(&net),
    });

    // Apply the dynamic-event schedule. Each topology event rebuilds the
    // CSR arena on the surviving graph and warm-starts GP from the
    // slot-remapped strategy ([`Strategy::rebind_topology`]); rate steps
    // adapt online with no rebuild.
    let mut removed: Vec<(usize, usize)> = Vec::new();
    for event in &spec.events {
        match event {
            DynamicEvent::RateScale { factor, .. } => {
                for app in full_net.apps.iter_mut().chain(net.apps.iter_mut()) {
                    for r in &mut app.input_rates {
                        *r *= factor;
                    }
                }
            }
            DynamicEvent::LinkDown { .. } => {
                if let Some((i, j)) = pick_removable_link(&net, &gp.phi, &[]) {
                    removed.push((i, j));
                    let pruned = prune_links(&full_net, &removed)?;
                    let phi = gp.phi.rebind_topology(&pruned);
                    gp.rebind(&pruned, &phi);
                    net = pruned;
                }
            }
            DynamicEvent::LinkUp { .. } => {
                if removed.pop().is_some() {
                    let restored = prune_links(&full_net, &removed)?;
                    let phi = gp.phi.rebind_topology(&restored);
                    gp.rebind(&restored, &phi);
                    net = restored;
                }
            }
        }
        gp.run(&net, event.iters());
        phases.push(PhaseOutcome {
            label: event.kind().to_string(),
            gp_cost: gp.cost(&net),
        });
    }

    // Final comparison: the baselines re-solve the final network state from
    // scratch. GP's arena already lives on the pruned graph (failed links
    // are not merely zero-flow — they have no slots), so its cost is
    // directly comparable to the pruned-graph solves.
    let final_net = &net;
    let gp_cost = phases.last().expect("initial phase always present").gp_cost;
    let mut costs: Vec<(String, f64)> = vec![(Algorithm::Gp.name().to_string(), gp_cost)];
    for alg in [Algorithm::Spoc, Algorithm::Lcof, Algorithm::LprSc] {
        costs.push((alg.name().to_string(), alg.solve(final_net, spec.iters)?));
    }
    let gp_within_baselines = costs
        .iter()
        .skip(1)
        .all(|(_, c)| gp_cost <= c * (1.0 + 1e-9) + 1e-12);

    Ok(ScenarioReport {
        name: spec.name().to_string(),
        topology: spec.base.topology.clone(),
        congestion: spec.congestion.name().to_string(),
        seed: spec.base.seed,
        n: net.n(),
        m: net.m(),
        apps: net.apps.len(),
        phases,
        costs,
        gp_within_baselines,
        solve_secs: watch.elapsed_secs(),
        cache_hit,
        workload: None,
        slots: 0,
        adaptation: None,
        distributed: None,
        churn: None,
        topo_churn: None,
        massive: None,
        ha: None,
    })
}

/// Execute a distributed-tier scenario: run the asynchronous sharded
/// runtime ([`AsyncRuntime`]) to quiescence under the spec's transport
/// (`clean` → [`crate::distributed::InMemTransport`], anything else →
/// [`crate::distributed::SimNetTransport`] with the given fault spec), then
/// compare the distributed final cost against a centralized
/// [`GradientProjection`] reference on the same network. The report's
/// `distributed` block carries rounds/messages/bytes/stale-reads.
pub fn run_distributed(
    spec: &ScenarioSpec,
    cache: &ScenarioCache,
) -> anyhow::Result<ScenarioReport> {
    let dspec = spec
        .distributed
        .as_ref()
        .expect("run_distributed requires a distributed spec");
    let watch = Stopwatch::start();
    let (graph, mut rng, cache_hit) = cache.topology(spec)?;
    let net = spec.effective_base().build_on((*graph).clone(), &mut rng)?;
    let phi0 = cache.initial_strategy(spec, &net);

    let opts = RuntimeOptions {
        shards: dspec.shards,
        max_epochs: dspec.max_epochs as u64,
        ..RuntimeOptions::default()
    };
    let mut rt = if dspec.faults.is_clean() {
        AsyncRuntime::in_mem(net.clone(), (*phi0).clone(), opts)
    } else {
        AsyncRuntime::sim_net(net.clone(), (*phi0).clone(), dspec.faults.clone(), opts)
    };
    let rep = rt.run_until_quiescent();

    // centralized reference on the same network and budget
    let mut gp = GradientProjection::with_strategy(&net, (*phi0).clone(), GpOptions::default());
    let central = gp.run(&net, spec.iters).final_cost;
    let rel_gap = (rep.final_cost - central).abs() / (1.0 + central);

    let phases = vec![
        PhaseOutcome {
            label: "distributed-start".to_string(),
            gp_cost: rep.cost_trace.first().copied().unwrap_or(f64::NAN),
        },
        PhaseOutcome {
            label: "distributed-quiesce".to_string(),
            gp_cost: rep.final_cost,
        },
    ];
    let costs = vec![
        ("GP-dist".to_string(), rep.final_cost),
        (Algorithm::Gp.name().to_string(), central),
    ];
    let gp_within_baselines = rep.final_cost <= central * (1.0 + 1e-3) + 1e-9;
    let summary = DistributedSummary {
        shards: rep.stats.shards,
        transport: rep.stats.transport_name.clone(),
        faults: dspec.faults.name.clone(),
        converged: Some(rep.converged),
        rounds: rep.stats.epochs,
        ticks: rep.stats.ticks,
        messages_sent: rep.stats.transport.sent,
        messages_delivered: rep.stats.transport.delivered,
        messages_dropped: rep.stats.transport.dropped_total(),
        bytes_sent: rep.stats.transport.bytes_sent,
        max_queue_depth: rep.stats.transport.max_queue_depth,
        stale_reads: rep.stats.stale_reads,
        reverted_stages: rep.stats.reverted_stages,
        control_messages: rep.stats.control_messages,
        rel_gap_to_centralized: Some(rel_gap),
    };

    Ok(ScenarioReport {
        name: spec.name().to_string(),
        topology: spec.base.topology.clone(),
        congestion: spec.congestion.name().to_string(),
        seed: spec.base.seed,
        n: net.n(),
        m: net.m(),
        apps: net.apps.len(),
        phases,
        costs,
        gp_within_baselines,
        solve_secs: watch.elapsed_secs(),
        cache_hit,
        workload: None,
        slots: 0,
        adaptation: None,
        distributed: Some(summary),
        churn: None,
        topo_churn: None,
        massive: None,
        ha: None,
    })
}

/// Execute a workload-driven (dynamic-tier) scenario: serve `spec.slots`
/// slots of the nonstationary workload through [`OnlineServer`] with the
/// adaptation controller attached, then compare the served GP strategy
/// against the baselines re-solved on the final true rates. The report's
/// `adaptation` block carries regret-vs-oracle and slots-to-reconvergence.
///
/// When the spec also carries a `distributed` block, the serving loop
/// drives the asynchronous runtime ([`DistributedOptimizer`]) instead of
/// the centralized optimizer — the controller's `restart`/`scale_step`
/// reconvergence hooks reach it through the [`Optimizer`] trait — and the
/// report additionally carries the runtime's message/round counters.
pub fn run_dynamic(spec: &ScenarioSpec, cache: &ScenarioCache) -> anyhow::Result<ScenarioReport> {
    let wspec = spec
        .workload
        .as_ref()
        .expect("run_dynamic requires a workload spec");
    anyhow::ensure!(
        spec.slots > 0,
        "dynamic scenario '{}' needs slots >= 1",
        spec.name()
    );
    let watch = Stopwatch::start();
    let (graph, mut rng, cache_hit) = cache.topology(spec)?;
    let net = spec.effective_base().build_on((*graph).clone(), &mut rng)?;
    let workload = Workload::from_spec(wspec, &net, 1.0, spec.base.seed)?;

    let phi0 = cache.initial_strategy(spec, &net);
    let mut dist_stats = None;
    let optimizer: Box<dyn Optimizer> = match &spec.distributed {
        Some(dspec) => {
            let opts = RuntimeOptions {
                shards: dspec.shards,
                ..RuntimeOptions::default()
            };
            let rt = if dspec.faults.is_clean() {
                AsyncRuntime::in_mem(net.clone(), (*phi0).clone(), opts)
            } else {
                AsyncRuntime::sim_net(net.clone(), (*phi0).clone(), dspec.faults.clone(), opts)
            };
            Box::new(DistributedOptimizer::new(rt))
        }
        None => Box::new(GradientProjection::with_strategy(
            &net,
            (*phi0).clone(),
            GpOptions::default(),
        )),
    };
    let mut srv = OnlineServer::with_workload(
        net.clone(),
        optimizer,
        workload,
        ServerOptions {
            slot_secs: 1.0,
            ewma: 0.3,
            seed: spec.base.seed,
        },
    );
    srv.attach_controller(AdaptationController::new(ControllerOptions::default()));
    let metrics = srv.run(spec.slots)?;
    let summary = srv
        .controller
        .as_ref()
        .expect("controller attached above")
        .summary();
    if let Some(dspec) = &spec.distributed {
        // recover the runtime counters from the boxed optimizer; the
        // serving loop has no quiescence/centralized-gap notion, so those
        // columns are absent (null) in serving mode.
        if let Some(stats) = srv.optimizer.runtime_stats() {
            dist_stats = Some(DistributedSummary {
                shards: stats.shards,
                transport: stats.transport_name.clone(),
                faults: dspec.faults.name.clone(),
                converged: None,
                rounds: stats.epochs,
                ticks: stats.ticks,
                messages_sent: stats.transport.sent,
                messages_delivered: stats.transport.delivered,
                messages_dropped: stats.transport.dropped_total(),
                bytes_sent: stats.transport.bytes_sent,
                max_queue_depth: stats.transport.max_queue_depth,
                stale_reads: stats.stale_reads,
                reverted_stages: stats.reverted_stages,
                control_messages: stats.control_messages,
                rel_gap_to_centralized: None,
            });
        }
    }

    // phase trajectory: served cost at start / end of the run
    let phases = vec![
        PhaseOutcome {
            label: "serving-start".to_string(),
            gp_cost: metrics.first().map(|m| m.cost).unwrap_or(f64::NAN),
        },
        PhaseOutcome {
            label: "serving-end".to_string(),
            gp_cost: metrics.last().map(|m| m.cost).unwrap_or(f64::NAN),
        },
    ];

    // final comparison on the true rates of the last served slot: GP's cost
    // is what it actually served; baselines re-solve from scratch.
    let mut truth = net.clone();
    srv.workload.apply_true_rates(&mut truth);
    let gp_cost = metrics.last().map(|m| m.cost).unwrap_or(f64::NAN);
    let mut costs: Vec<(String, f64)> = vec![(Algorithm::Gp.name().to_string(), gp_cost)];
    for alg in [Algorithm::Spoc, Algorithm::Lcof, Algorithm::LprSc] {
        costs.push((alg.name().to_string(), alg.solve(&truth, spec.iters)?));
    }
    let gp_within_baselines = costs
        .iter()
        .skip(1)
        .all(|(_, c)| gp_cost <= c * (1.0 + 1e-9) + 1e-12);

    Ok(ScenarioReport {
        name: spec.name().to_string(),
        topology: spec.base.topology.clone(),
        congestion: spec.congestion.name().to_string(),
        seed: spec.base.seed,
        n: net.n(),
        m: net.m(),
        apps: net.apps.len(),
        phases,
        costs,
        gp_within_baselines,
        solve_secs: watch.elapsed_secs(),
        cache_hit,
        workload: Some(wspec.name().to_string()),
        slots: spec.slots,
        adaptation: Some(summary),
        distributed: dist_stats,
        churn: None,
        topo_churn: None,
        massive: None,
        ha: None,
    })
}

/// Execute a churn-tier scenario: serve `spec.slots` slots through the
/// multi-tenant [`ControlPlane`], firing the scripted app
/// arrival/departure schedule. Every register is admission-checked (the
/// report counts accepts/rejects) and commits through the epoch-rebuild
/// warm-start path; after the run the report's `churn` block carries the
/// per-arrival reconvergence spans (slots until the served cost re-entered
/// 2% of the best cost before the next event). The final GP strategy is
/// compared against the baselines re-solved on the final true rates, like
/// the dynamic tier.
///
/// No topology cache: the control plane builds its own graph from the
/// scenario seed (bit-identical to a cached build — `Scenario::build` is
/// deterministic), and churn scenarios are rare enough per batch that the
/// reuse would not pay for the plumbing.
pub fn run_churn(spec: &ScenarioSpec) -> anyhow::Result<ScenarioReport> {
    let churn = spec
        .churn
        .as_ref()
        .expect("run_churn requires a churn spec");
    anyhow::ensure!(
        spec.slots > 0,
        "churn scenario '{}' needs slots >= 1",
        spec.name()
    );
    let watch = Stopwatch::start();
    let copts = ControlOptions {
        workload: spec.workload.clone(),
        ..ControlOptions::default()
    };
    let mut plane = ControlPlane::new(spec.effective_base(), copts)?;
    let n = plane.graph().n();
    let sc = plane.scenario.clone();
    // register-random draws are forked off the scenario seed, independent
    // of the workload/topology streams
    let mut churn_rng = Rng::new(sc.seed ^ 0xC0FF_EE00);

    let mut accepted = 0usize;
    let mut rejected = 0usize;
    let mut arrival_slots: Vec<usize> = Vec::new();
    let mut costs = Vec::with_capacity(spec.slots);
    let mut event_idx = 0usize;
    for slot in 0..spec.slots {
        while event_idx < churn.events.len() && churn.events[event_idx].at_slot <= slot {
            let event = &churn.events[event_idx];
            event_idx += 1;
            // a scripted register whose id already exists (e.g. re-register
            // while draining) goes through the admission-checked update
            // path, like the HTTP surface — it must not abort the scenario
            let mut admit = |plane: &mut ControlPlane, app: AppSpec| -> anyhow::Result<()> {
                let decision = if plane.catalog.get(&app.id).is_some() {
                    plane.update(app)?
                } else {
                    plane.register(app)?
                };
                if decision.accepted() {
                    accepted += 1;
                    arrival_slots.push(slot);
                } else {
                    rejected += 1;
                }
                Ok(())
            };
            match &event.action {
                ChurnAction::Register(app) => {
                    let mut app = app.clone();
                    app.status = AppStatus::Active;
                    admit(&mut plane, app)?;
                }
                ChurnAction::RegisterRandom { id, rate } => {
                    let dest = churn_rng.usize(n);
                    let sources = churn_rng.choose_distinct(n, sc.num_sources.min(n));
                    let rates = sources
                        .into_iter()
                        .map(|i| {
                            (i, churn_rng.range(sc.rate_lo, sc.rate_hi) * sc.rate_scale * rate)
                        })
                        .collect();
                    let app = AppSpec {
                        id: id.clone(),
                        dest,
                        num_tasks: sc.num_tasks,
                        packet_sizes: (0..=sc.num_tasks).map(|k| sc.packet_size(k)).collect(),
                        rates,
                        status: AppStatus::Active,
                    };
                    admit(&mut plane, app)?;
                }
                // scripted schedules may drain/remove an app whose register
                // was rejected by admission — skip, don't abort the run
                ChurnAction::Drain { id } => {
                    if plane.catalog.get(id).is_some() {
                        plane.drain(id)?;
                    }
                }
                ChurnAction::Remove { id } => {
                    if plane.catalog.get(id).is_some() {
                        plane.remove(id)?;
                    }
                }
            }
        }
        costs.push(plane.run_slot()?.cost);
    }

    // post-hoc reconvergence per accepted arrival: within the window up to
    // the next event (or run end), slots until cost <= 1.02 · window min
    let event_slots: Vec<usize> = churn.events.iter().map(|e| e.at_slot).collect();
    let reconverge_slots: Vec<usize> = arrival_slots
        .iter()
        .map(|&t| {
            let end = event_slots
                .iter()
                .copied()
                .find(|&u| u > t)
                .unwrap_or(spec.slots)
                .min(spec.slots);
            let window = &costs[t..end];
            let target = window.iter().cloned().fold(f64::INFINITY, f64::min);
            window
                .iter()
                .position(|&c| c <= target * 1.02)
                .unwrap_or(window.len())
        })
        .collect();

    let summary = ChurnSummary {
        events: churn.events.len(),
        accepted,
        rejected,
        epochs: plane.epoch(),
        final_apps: plane.catalog.len(),
        reconverge_slots,
        admission_latency_secs_mean: plane.stats.admission_latency.mean(),
    };

    // final comparison on the last slot's true rates, like the dynamic tier
    let mut truth = plane.server.net.clone();
    plane.server.workload.apply_true_rates(&mut truth);
    let gp_cost = costs.last().copied().unwrap_or(f64::NAN);
    let mut cost_rows: Vec<(String, f64)> = vec![(Algorithm::Gp.name().to_string(), gp_cost)];
    for alg in [Algorithm::Spoc, Algorithm::Lcof, Algorithm::LprSc] {
        cost_rows.push((alg.name().to_string(), alg.solve(&truth, spec.iters)?));
    }
    let gp_within_baselines = cost_rows
        .iter()
        .skip(1)
        .all(|(_, c)| gp_cost <= c * (1.0 + 1e-9) + 1e-12);

    let phases = vec![
        PhaseOutcome {
            label: "serving-start".to_string(),
            gp_cost: costs.first().copied().unwrap_or(f64::NAN),
        },
        PhaseOutcome {
            label: "serving-end".to_string(),
            gp_cost,
        },
    ];

    Ok(ScenarioReport {
        name: spec.name().to_string(),
        topology: spec.base.topology.clone(),
        congestion: spec.congestion.name().to_string(),
        seed: spec.base.seed,
        n: truth.n(),
        m: truth.m(),
        apps: truth.apps.len(),
        phases,
        costs: cost_rows,
        gp_within_baselines,
        solve_secs: watch.elapsed_secs(),
        cache_hit: false,
        workload: spec.workload.as_ref().map(|w| w.name().to_string()),
        slots: spec.slots,
        adaptation: None,
        distributed: None,
        churn: Some(summary),
        topo_churn: None,
        massive: None,
        ha: None,
    })
}

/// Execute an `ha`-tier scenario: drive a simulated replica group
/// ([`ReplGroup`]) through a cold-start election, a scripted register
/// burst proposed all-in-flight, a leader kill mid-churn, and the
/// failover, under the spec's declarative fault model. The run asserts the
/// tier's core invariants inline — no committed-before-kill log entry is
/// lost or rewritten, and every surviving replica's control plane, after
/// applying its committed prefix, agrees on catalog and epoch — then
/// serves `spec.slots` slots on the survivor's plane and compares the
/// final GP strategy against the baselines re-solved on the final true
/// rates, like the churn tier.
///
/// Failover is client-realistic: a retry loop re-proposes scripted
/// commands missing from the new leader's log (it cannot distinguish a
/// lost request from a lost leader). The new leader asserts its term with
/// a no-op barrier — the raft idiom, since a leader may only count
/// replicas toward commit for entries of its own term; the consensus core
/// appends it on election (`become_leader`), and the runner keeps a
/// fallback re-propose in case a future election path skips it. The
/// tolerant committed-apply ([`ControlPlane::apply_committed`]) makes any
/// resulting duplicates converge.
pub fn run_ha(spec: &ScenarioSpec) -> anyhow::Result<ScenarioReport> {
    let h = spec.ha.as_ref().expect("run_ha requires an ha spec").clone();
    anyhow::ensure!(
        spec.slots > 0,
        "ha scenario '{}' needs slots >= 1",
        spec.name()
    );
    anyhow::ensure!(
        h.replicas >= 3,
        "ha scenario '{}' needs >= 3 replicas to survive a leader kill",
        spec.name()
    );
    let watch = Stopwatch::start();
    let copts = ControlOptions {
        workload: spec.workload.clone(),
        ..ControlOptions::default()
    };
    // one plane per replica, built identically — they may only diverge if
    // the committed logs diverge, which the run asserts they do not
    let mut planes = Vec::with_capacity(h.replicas);
    for _ in 0..h.replicas {
        planes.push(ControlPlane::new(spec.effective_base(), copts.clone())?);
    }
    let n = planes[0].graph().n();
    let sc = planes[0].scenario.clone();

    // the scripted register burst, drawn like the churn tier's
    // RegisterRandom (forked off the scenario seed, independent streams)
    let mut script_rng = Rng::new(sc.seed ^ 0x4A50_C0DE);
    let script: Vec<ReplCommand> = (0..h.registers)
        .map(|k| {
            let dest = script_rng.usize(n);
            let sources = script_rng.choose_distinct(n, sc.num_sources.min(n));
            let rates = sources
                .into_iter()
                .map(|i| {
                    (i, script_rng.range(sc.rate_lo, sc.rate_hi) * sc.rate_scale * 0.25)
                })
                .collect();
            ReplCommand::Register(AppSpec {
                id: format!("ha-app-{k}"),
                dest,
                num_tasks: sc.num_tasks,
                packet_sizes: (0..=sc.num_tasks).map(|t| sc.packet_size(t)).collect(),
                rates,
                status: AppStatus::Active,
            })
        })
        .collect();
    let contains = |r: &Replica, cmd: &ReplCommand| -> bool {
        (1..=r.log_len()).any(|i| &r.log_entry(i).expect("index in range").cmd == cmd)
    };

    // phase 1: cold-start election
    let e_watch = Stopwatch::start();
    let mut g = ReplGroup::new(h.replicas, sc.seed, h.faults.clone());
    let election_ticks = g.run_until_leader(h.max_ticks).ok_or_else(|| {
        anyhow::anyhow!("ha '{}': no leader within {} ticks", spec.name(), h.max_ticks)
    })?;
    let election_secs = e_watch.elapsed_secs();
    let initial_leader = g.leader().expect("run_until_leader returned Some");

    // phase 2: propose the whole burst (in flight at once), give
    // replication a few ticks — enough for the leader to commit, not
    // enough for every follower to learn it — then kill the leader
    let r_watch = Stopwatch::start();
    let mut proposed = 0usize;
    for cmd in &script {
        if g.propose(cmd.clone()).is_some() {
            proposed += 1;
        }
    }
    for _ in 0..6 {
        g.step();
    }
    let victim = g.leader().unwrap_or(initial_leader);
    let commit_at_kill = g
        .replicas
        .iter()
        .map(Replica::commit_index)
        .max()
        .unwrap_or(0);
    let rich = (0..g.replicas.len())
        .max_by_key(|&id| g.replicas[id].commit_index())
        .expect("group is non-empty");
    let pre_entries: Vec<LogEntry> = (1..=commit_at_kill)
        .map(|i| {
            g.replicas[rich]
                .log_entry(i)
                .expect("committed prefix present")
                .clone()
        })
        .collect();
    g.kill(victim);

    // phase 3: failover and reconvergence
    let kill_tick = g.now();
    let f_watch = Stopwatch::start();
    let mut failover_ticks: Option<u64> = None;
    loop {
        anyhow::ensure!(
            g.now() - kill_tick < h.max_ticks,
            "ha '{}': fleet did not reconverge within {} ticks of the kill",
            spec.name(),
            h.max_ticks
        );
        g.step();
        let Some(l) = g.leader() else { continue };
        // no-op barrier asserting the new term; become_leader appends one
        // itself when an uncommitted tail exists, so this is a fallback
        // for the tail-free case (commit == log_len at election)
        let term = g.replicas[l].term();
        let has_term_entry = (1..=g.replicas[l].log_len())
            .any(|i| g.replicas[l].log_entry(i).expect("in range").term == term);
        if !has_term_entry && g.propose(ReplCommand::SnapshotBarrier).is_some() {
            proposed += 1;
        }
        // client retry of scripted commands the failover orphaned
        for cmd in &script {
            if !contains(&g.replicas[l], cmd) && g.propose(cmd.clone()).is_some() {
                proposed += 1;
            }
        }
        if failover_ticks.is_none() && g.replicas[l].commit_index() > commit_at_kill {
            failover_ticks = Some(g.now() - kill_tick);
        }
        let target = g.replicas[l].log_len();
        let all_committed = g
            .replicas
            .iter()
            .enumerate()
            .filter(|(id, _)| g.alive[*id])
            .all(|(_, r)| r.commit_index() >= target);
        if all_committed
            && failover_ticks.is_some()
            && script.iter().all(|c| contains(&g.replicas[l], c))
        {
            break;
        }
    }
    let failover_secs = f_watch.elapsed_secs();
    let failover_ticks = failover_ticks.expect("loop breaks only once recorded");
    let final_leader = g.leader().expect("loop ended with a leader");
    let final_term = g.replicas[final_leader].term();
    let committed = g.replicas[final_leader].commit_index();
    let repl_secs = r_watch.elapsed_secs();
    let commands_per_sec = if repl_secs > 0.0 {
        committed as f64 / repl_secs
    } else {
        0.0
    };

    // the no-loss invariant: every entry committed before the kill is
    // still at its index, bit-identical, on every surviving replica
    let mut lost = 0usize;
    for (id, r) in g.replicas.iter().enumerate() {
        if !g.alive[id] {
            continue;
        }
        for (i, pre) in pre_entries.iter().enumerate() {
            let idx = i as u64 + 1;
            if r.log_entry(idx).map(|e| e != pre).unwrap_or(true) {
                lost += 1;
            }
        }
    }
    anyhow::ensure!(
        lost == 0,
        "ha '{}': {lost} committed-before-kill entries lost or rewritten after failover",
        spec.name()
    );

    // phase 4: apply each survivor's committed prefix to its own plane
    // and check the fleet agrees on catalog + epoch
    let mut survivor: Option<usize> = None;
    for id in 0..h.replicas {
        if !g.alive[id] {
            continue;
        }
        let committed_cmds: Vec<ReplCommand> = g.replicas[id]
            .take_committed()
            .into_iter()
            .map(|(_, c)| c)
            .collect();
        for cmd in &committed_cmds {
            planes[id].apply_committed(cmd)?;
        }
        if let Some(s) = survivor {
            anyhow::ensure!(
                planes[id].epoch() == planes[s].epoch()
                    && planes[id].catalog.to_json().to_string()
                        == planes[s].catalog.to_json().to_string(),
                "ha '{}': surviving replicas {s} and {id} diverged after applying the committed log",
                spec.name()
            );
        } else {
            survivor = Some(id);
        }
    }
    let survivor = survivor.expect("at least one replica survives the kill");

    let elections = g.replicas.iter().map(Replica::elections_started).sum();
    let fs = g.stats();
    let summary = HaSummary {
        replicas: h.replicas,
        faults: h.faults.name.clone(),
        proposed,
        committed,
        commit_at_kill,
        lost,
        elections,
        final_term,
        election_ticks,
        failover_ticks,
        epochs: planes[survivor].epoch(),
        final_apps: planes[survivor].catalog.len(),
        msgs_sent: fs.sent,
        msgs_dropped: fs.dropped_fault + fs.dropped_partition + fs.dropped_dead,
        election_secs,
        failover_secs,
        commands_per_sec,
    };

    // phase 5: serve on the survivor's plane, then the final truth compare
    let plane = &mut planes[survivor];
    let mut costs = Vec::with_capacity(spec.slots);
    for _ in 0..spec.slots {
        costs.push(plane.run_slot()?.cost);
    }
    let mut truth = plane.server.net.clone();
    plane.server.workload.apply_true_rates(&mut truth);
    let gp_cost = costs.last().copied().unwrap_or(f64::NAN);
    let mut cost_rows: Vec<(String, f64)> = vec![(Algorithm::Gp.name().to_string(), gp_cost)];
    for alg in [Algorithm::Spoc, Algorithm::Lcof, Algorithm::LprSc] {
        cost_rows.push((alg.name().to_string(), alg.solve(&truth, spec.iters)?));
    }
    let gp_within_baselines = cost_rows
        .iter()
        .skip(1)
        .all(|(_, c)| gp_cost <= c * (1.0 + 1e-9) + 1e-12);

    let phases = vec![
        PhaseOutcome {
            label: "serving-start".to_string(),
            gp_cost: costs.first().copied().unwrap_or(f64::NAN),
        },
        PhaseOutcome {
            label: "serving-end".to_string(),
            gp_cost,
        },
    ];

    Ok(ScenarioReport {
        name: spec.name().to_string(),
        topology: spec.base.topology.clone(),
        congestion: spec.congestion.name().to_string(),
        seed: spec.base.seed,
        n: truth.n(),
        m: truth.m(),
        apps: truth.apps.len(),
        phases,
        costs: cost_rows,
        gp_within_baselines,
        solve_secs: watch.elapsed_secs(),
        cache_hit: false,
        workload: spec.workload.as_ref().map(|w| w.name().to_string()),
        slots: spec.slots,
        adaptation: None,
        distributed: None,
        churn: None,
        topo_churn: None,
        massive: None,
        ha: Some(summary),
    })
}

/// Execute a topo-churn-tier scenario: serve `spec.slots` slots (one GP
/// adaptation step per slot) while the scripted
/// [`crate::topo::TopoChurnSpec`] schedule
/// flaps links and degrades regions. Every applied change — a scripted
/// event that removed something, or a due repair batch — is one epoch
/// rebuild: [`TopologyState::current_network`] rebuilds the pruned network
/// (CSR arena included), [`Strategy::rebind_topology`] slot-remaps φ onto
/// it, and [`Optimizer::rebind`] warm-starts GP from the remapped strategy.
///
/// Per change the runner also solves a *fresh-build oracle* (cold GP from
/// min-hop, full `spec.iters` budget) on the post-change graph and derives
/// the report's `topo_churn` block: rebind latency, warm-vs-cold
/// reconvergence slots against the oracle's 2% band, and the retained cost
/// optimality of the remapped strategy before any re-optimization.
pub fn run_topo_churn(
    spec: &ScenarioSpec,
    cache: &ScenarioCache,
) -> anyhow::Result<ScenarioReport> {
    let tspec = spec
        .topo_churn
        .as_ref()
        .expect("run_topo_churn requires a topo_churn spec");
    anyhow::ensure!(
        spec.slots > 0,
        "topo-churn scenario '{}' needs slots >= 1",
        spec.name()
    );
    let watch = Stopwatch::start();
    let (graph, mut rng, cache_hit) = cache.topology(spec)?;
    let base = spec.effective_base().build_on((*graph).clone(), &mut rng)?;
    let phi0 = cache.initial_strategy(spec, &base);
    let mut gp = GradientProjection::with_strategy(&base, (*phi0).clone(), GpOptions::default());
    gp.run(&base, spec.iters);

    let mut topo = TopologyState::new(base.clone());
    let mut cur = base;
    let mut events = tspec.events.clone();
    events.sort_by_key(|e| e.at_slot);
    // flap-pick draws are forked off the scenario seed, independent of the
    // topology/workload streams (and of the app-churn fork)
    let mut churn_rng = Rng::new(spec.base.seed ^ 0x70D0_CAFE);

    let mut phases = vec![PhaseOutcome {
        label: "initial".to_string(),
        gp_cost: gp.cost(&cur),
    }];
    let mut rebind_secs: Vec<f64> = Vec::new();
    let mut reconverge_warm: Vec<usize> = Vec::new();
    let mut reconverge_cold: Vec<usize> = Vec::new();
    let mut retained: Vec<f64> = Vec::new();
    let mut removed_total = 0usize;
    let mut changes = 0usize;
    // warm-reconvergence measurement in flight: (cost target, slots so far)
    let mut measuring: Option<(f64, usize)> = None;

    let mut event_idx = 0usize;
    let mut costs = Vec::with_capacity(spec.slots);
    for slot in 0..spec.slots {
        let mut changed = false;
        let mut label = "";
        if !topo.due_repairs(slot).is_empty() {
            changed = true;
            label = "topo-repair";
        }
        while event_idx < events.len() && events[event_idx].at_slot <= slot {
            let picked = topo.apply_event(slot, &events[event_idx].action, &mut churn_rng);
            event_idx += 1;
            if !picked.is_empty() {
                removed_total += picked.len();
                changed = true;
                label = "topo-rebind";
            }
        }
        if changed {
            changes += 1;
            // a change preempting an unfinished measurement caps it at the
            // window length — warm never scores worse than the window
            if let Some((_, slots)) = measuring.take() {
                reconverge_warm.push(slots);
            }
            cur = topo.current_network();
            let w = Stopwatch::start();
            let phi = gp.phi.rebind_topology(&cur);
            gp.rebind(&cur, &phi);
            rebind_secs.push(w.elapsed_secs());
            let warm_now = gp.cost(&cur);
            // fresh-build oracle: cold GP from min-hop, full budget
            let mut oracle = GradientProjection::with_strategy(
                &cur,
                Strategy::shortest_path_to_dest(&cur),
                GpOptions::default(),
            );
            let oracle_cost = oracle.run(&cur, spec.iters).final_cost;
            retained.push(oracle_cost / warm_now);
            let target = oracle_cost * 1.02;
            // cold reconvergence: one iteration per slot from min-hop
            let mut cold = GradientProjection::with_strategy(
                &cur,
                Strategy::shortest_path_to_dest(&cur),
                GpOptions::default(),
            );
            let mut cold_slots = 0usize;
            while cold.cost(&cur) > target && cold_slots < spec.slots {
                cold.run(&cur, 1);
                cold_slots += 1;
            }
            reconverge_cold.push(cold_slots);
            if warm_now <= target {
                reconverge_warm.push(0);
            } else {
                measuring = Some((target, 0));
            }
            phases.push(PhaseOutcome {
                label: label.to_string(),
                gp_cost: warm_now,
            });
        }
        // serve the slot: one online adaptation step
        gp.run(&cur, 1);
        let cost = gp.cost(&cur);
        costs.push(cost);
        if let Some((target, slots)) = measuring {
            let slots = slots + 1;
            if cost <= target {
                reconverge_warm.push(slots);
                measuring = None;
            } else {
                measuring = Some((target, slots));
            }
        }
    }
    // run ended mid-measurement: cap at the remaining window
    if let Some((_, slots)) = measuring.take() {
        reconverge_warm.push(slots);
    }

    let gp_cost = costs.last().copied().unwrap_or(f64::NAN);
    phases.push(PhaseOutcome {
        label: "serving-end".to_string(),
        gp_cost,
    });

    // final comparison on the final network state (all scheduled repairs
    // that came due have been applied), like the event-schedule tier
    let mut cost_rows: Vec<(String, f64)> = vec![(Algorithm::Gp.name().to_string(), gp_cost)];
    for alg in [Algorithm::Spoc, Algorithm::Lcof, Algorithm::LprSc] {
        cost_rows.push((alg.name().to_string(), alg.solve(&cur, spec.iters)?));
    }
    let gp_within_baselines = cost_rows
        .iter()
        .skip(1)
        .all(|(_, c)| gp_cost <= c * (1.0 + 1e-9) + 1e-12);

    let rebind_secs_mean = if rebind_secs.is_empty() {
        0.0
    } else {
        rebind_secs.iter().sum::<f64>() / rebind_secs.len() as f64
    };
    let summary = TopoChurnSummary {
        events: events.len(),
        changes,
        epochs: topo.epoch(),
        removed_pairs_total: removed_total,
        rebind_secs_mean,
        reconverge_slots_warm: reconverge_warm,
        reconverge_slots_cold: reconverge_cold,
        retained_optimality: retained,
    };

    Ok(ScenarioReport {
        name: spec.name().to_string(),
        topology: spec.base.topology.clone(),
        congestion: spec.congestion.name().to_string(),
        seed: spec.base.seed,
        n: cur.n(),
        m: cur.m(),
        apps: cur.apps.len(),
        phases,
        costs: cost_rows,
        gp_within_baselines,
        solve_secs: watch.elapsed_secs(),
        cache_hit,
        workload: None,
        slots: spec.slots,
        adaptation: None,
        distributed: None,
        churn: None,
        topo_churn: Some(summary),
        massive: None,
        ha: None,
    })
}

/// Execute a massive-tier scenario: serve `spec.slots` slots of the
/// batched SoA workload hot path — [`crate::workload::StreamTable`]
/// family-batched sampling, the flat [`StreamEstimator`] EWMA columns, and
/// one [`AdaptationController::observe`] column scan — with wall-time
/// instrumentation per slot. No optimizer runs: at a thousand applications
/// the GP arena would dwarf the workload itself, and the tier exists to pin
/// workload throughput (streams/sec), not routing quality. Everything in
/// the report except the wall-time columns is bit-deterministic.
pub fn run_massive(spec: &ScenarioSpec, cache: &ScenarioCache) -> anyhow::Result<ScenarioReport> {
    let wspec = spec.workload.as_ref().ok_or_else(|| {
        anyhow::anyhow!("massive scenario '{}' needs a workload", spec.name())
    })?;
    anyhow::ensure!(
        spec.slots > 0,
        "massive scenario '{}' needs slots >= 1",
        spec.name()
    );
    let watch = Stopwatch::start();
    let (graph, mut rng, cache_hit) = cache.topology(spec)?;
    let build = Stopwatch::start();
    let net = spec.effective_base().build_on((*graph).clone(), &mut rng)?;
    let mut workload = Workload::from_spec(wspec, &net, 1.0, spec.base.seed)?;
    anyhow::ensure!(
        workload.enable_batching(),
        "massive scenario '{}': workload is not batchable (trace streams?)",
        spec.name()
    );
    let build_secs = build.elapsed_secs();
    let streams = workload.streams.len();

    // The hot loop. Per slot: one batched sample pass per model family,
    // one linear EWMA pass over the estimator columns, one detector scan.
    let mut est = StreamEstimator::new(1.0, 0.3);
    let mut ctrl = AdaptationController::new(ControllerOptions::default());
    let mut arrivals_total = 0usize;
    let mut slot_ms = Vec::with_capacity(spec.slots);
    let mut sample_ms = Vec::with_capacity(spec.slots);
    let mut estimate_ms = Vec::with_capacity(spec.slots);
    let mut detect_ms = Vec::with_capacity(spec.slots);
    for slot in 0..spec.slots {
        crate::obs::set_slot(slot as u64 + 1);
        let _slot_span = crate::obs_span!("scenarios", "massive-slot");
        let w = Stopwatch::start();
        arrivals_total += workload.sample_slot();
        sample_ms.push(w.elapsed_secs() * 1e3);
        let wp = Stopwatch::start();
        let (obs, fast) = est.update(&workload);
        estimate_ms.push(wp.elapsed_secs() * 1e3);
        let wp = Stopwatch::start();
        let _ = ctrl.observe(obs, fast);
        detect_ms.push(wp.elapsed_secs() * 1e3);
        slot_ms.push(w.elapsed_secs() * 1e3);
    }
    let detections = ctrl.events().len();
    let offered_load = workload.total_true_rate();

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let phase_sample_ms_mean = mean(&sample_ms);
    let phase_estimate_ms_mean = mean(&estimate_ms);
    let phase_detect_ms_mean = mean(&detect_ms);
    let slot_wall_ms_mean = slot_ms.iter().sum::<f64>() / slot_ms.len() as f64;
    let slot_wall_ms_max = slot_ms.iter().cloned().fold(0.0, f64::max);
    let streams_per_sec = if slot_wall_ms_mean > 0.0 {
        streams as f64 / (slot_wall_ms_mean / 1e3)
    } else {
        0.0
    };

    let summary = MassiveSummary {
        streams,
        slots: spec.slots,
        arrivals_total,
        detections,
        offered_load,
        build_secs,
        slot_wall_ms_mean,
        slot_wall_ms_max,
        streams_per_sec,
        phase_sample_ms_mean,
        phase_estimate_ms_mean,
        phase_detect_ms_mean,
    };

    Ok(ScenarioReport {
        name: spec.name().to_string(),
        topology: spec.base.topology.clone(),
        congestion: spec.congestion.name().to_string(),
        seed: spec.base.seed,
        n: net.n(),
        m: net.m(),
        apps: net.apps.len(),
        // no optimizer, so no phase trajectory or cost comparison
        phases: Vec::new(),
        costs: Vec::new(),
        gp_within_baselines: true,
        solve_secs: watch.elapsed_secs(),
        cache_hit,
        workload: Some(wspec.name().to_string()),
        slots: spec.slots,
        adaptation: None,
        distributed: None,
        churn: None,
        topo_churn: None,
        massive: Some(summary),
        ha: None,
    })
}

/// Run a batch of scenarios across a worker pool. Reports come back in spec
/// order regardless of scheduling; if `opts.out_dir` is set, one JSON file
/// per scenario is written there.
pub fn run_batch(
    specs: &[ScenarioSpec],
    opts: &RunnerOptions,
) -> anyhow::Result<Vec<ScenarioReport>> {
    anyhow::ensure!(!specs.is_empty(), "no scenarios to run");
    let cache = ScenarioCache::new();
    let jobs = opts.jobs.clamp(1, specs.len());
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<anyhow::Result<ScenarioReport>>>> =
        (0..specs.len()).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let idx = next.fetch_add(1, Ordering::SeqCst);
                if idx >= specs.len() {
                    break;
                }
                let spec = &specs[idx];
                let result = run_one(spec, &cache);
                if !opts.quiet {
                    match &result {
                        Ok(rep) => eprintln!(
                            "scenario {:<24} GP {:.4} ({} phases, {:.2}s{})",
                            rep.name,
                            rep.gp_cost(),
                            rep.phases.len(),
                            rep.solve_secs,
                            if rep.cache_hit { ", cached topo" } else { "" },
                        ),
                        Err(e) => eprintln!("scenario {:<24} FAILED: {e}", spec.name()),
                    }
                }
                *slots[idx].lock().unwrap() = Some(result);
            });
        }
    });

    let mut reports = Vec::with_capacity(specs.len());
    for (i, slot) in slots.into_iter().enumerate() {
        let result = slot
            .into_inner()
            .unwrap()
            .expect("worker pool covered every index");
        reports.push(result.map_err(|e| anyhow::anyhow!("scenario '{}': {e}", specs[i].name()))?);
    }

    if let Some(dir) = &opts.out_dir {
        std::fs::create_dir_all(dir)?;
        for rep in &reports {
            let file = dir.join(format!("{}.json", sanitize(&rep.name)));
            std::fs::write(&file, rep.to_json().to_string_pretty())?;
        }
    }
    Ok(reports)
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::Congestion;

    fn quick_spec(family: &str, congestion: Congestion) -> ScenarioSpec {
        let mut spec = ScenarioSpec::named(family, congestion).unwrap();
        spec.iters = 120;
        spec.events = vec![
            DynamicEvent::RateScale {
                factor: 1.3,
                iters: 80,
            },
            DynamicEvent::LinkDown { iters: 80 },
            DynamicEvent::LinkUp { iters: 80 },
        ];
        spec
    }

    #[test]
    fn run_one_produces_full_report() {
        let cache = ScenarioCache::new();
        let rep = run_one(&quick_spec("abilene", Congestion::Nominal), &cache).unwrap();
        assert_eq!(rep.n, 11);
        assert_eq!(rep.apps, 3);
        assert_eq!(rep.phases.len(), 4); // initial + 3 events
        assert_eq!(rep.costs.len(), 4); // GP + 3 baselines
        assert!(rep.gp_cost().is_finite() && rep.gp_cost() > 0.0);
        // the demand step must raise GP's settled cost vs the initial phase
        assert!(rep.phases[1].gp_cost > rep.phases[0].gp_cost);
    }

    #[test]
    fn congestion_levels_share_cached_topology() {
        let cache = ScenarioCache::new();
        let a = run_one(&quick_spec("er-20-40", Congestion::Light), &cache).unwrap();
        let b = run_one(&quick_spec("er-20-40", Congestion::Heavy), &cache).unwrap();
        assert!(!a.cache_hit);
        assert!(b.cache_hit);
        assert_eq!(a.m, b.m);
        // heavier load costs more
        assert!(b.gp_cost() > a.gp_cost());
        let (hits, misses) = cache.stats();
        assert!(hits >= 2, "graph + phi0 reuse expected, got {hits}/{misses}");
    }

    #[test]
    fn cached_and_uncached_runs_agree() {
        let spec = quick_spec("er-20-40", Congestion::Nominal);
        let cold = run_one(&spec, &ScenarioCache::new()).unwrap();
        let warm_cache = ScenarioCache::new();
        let _ = run_one(&quick_spec("er-20-40", Congestion::Light), &warm_cache).unwrap();
        let warm = run_one(&spec, &warm_cache).unwrap();
        assert!(warm.cache_hit);
        assert_eq!(cold.costs.len(), warm.costs.len());
        for ((n1, c1), (n2, c2)) in cold.costs.iter().zip(&warm.costs) {
            assert_eq!(n1, n2);
            assert!(
                (c1 - c2).abs() == 0.0,
                "{n1}: cold {c1} vs warm {c2} must be bit-identical"
            );
        }
    }

    #[test]
    fn link_churn_is_applied_and_reverted() {
        let cache = ScenarioCache::new();
        let mut spec = quick_spec("abilene", Congestion::Nominal);
        spec.events = vec![DynamicEvent::LinkDown { iters: 100 }];
        let rep = run_one(&spec, &cache).unwrap();
        // the failure phase exists and the final comparison ran on the
        // pruned network
        assert_eq!(rep.phases.last().unwrap().label, "link-down");
        assert!(rep.gp_cost().is_finite());
    }

    #[test]
    fn report_json_is_machine_readable() {
        let cache = ScenarioCache::new();
        let mut spec = quick_spec("abilene", Congestion::Light);
        spec.events.clear();
        spec.iters = 60;
        let rep = run_one(&spec, &cache).unwrap();
        let v = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("abilene-light"));
        assert_eq!(v.get("n").unwrap().as_usize(), Some(11));
        let costs = v.get("costs").unwrap();
        for alg in ["GP", "SPOC", "LCOF", "LPR-SC"] {
            assert!(
                costs.get(alg).and_then(Json::as_f64).unwrap() > 0.0,
                "{alg} missing from report"
            );
        }
        assert_eq!(
            v.get("gp_within_baselines").unwrap().as_bool(),
            Some(rep.gp_within_baselines)
        );
    }

    fn quick_dynamic_spec(workload: &str, slots: usize) -> ScenarioSpec {
        let mut spec = ScenarioSpec::named("abilene", Congestion::Nominal).unwrap();
        spec.base.name = format!("abilene-{workload}");
        spec.events.clear();
        spec.iters = 200;
        spec.slots = slots;
        spec.workload = Some(crate::workload::WorkloadSpec::named(workload).unwrap());
        spec
    }

    #[test]
    fn dynamic_scenario_reports_nonzero_regret_and_reconvergence() {
        let cache = ScenarioCache::new();
        let rep = run_one(&quick_dynamic_spec("flash-crowd", 90), &cache).unwrap();
        assert_eq!(rep.workload.as_deref(), Some("flash-crowd"));
        assert_eq!(rep.slots, 90);
        let a = rep.adaptation.as_ref().expect("dynamic report has adaptation");
        assert!(a.detections >= 1, "flash crowd must be detected");
        assert!(a.regret_mean > 0.0, "regret must be nonzero");
        assert!(a.reconverge_mean >= 1.0, "reconvergence slots must be nonzero");
        assert_eq!(rep.costs.len(), 4);
        assert!(rep.gp_cost().is_finite() && rep.gp_cost() > 0.0);
        // the JSON report exposes the acceptance-gated fields
        let v = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        let adapt = v.get("adaptation").expect("adaptation block serialized");
        assert!(adapt.get("regret_mean").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            adapt
                .get("reconvergence_slots_mean")
                .unwrap()
                .as_f64()
                .unwrap()
                > 0.0
        );
        assert_eq!(v.get("workload").unwrap().as_str(), Some("flash-crowd"));
    }

    #[test]
    fn dynamic_scenario_is_deterministic() {
        let spec = quick_dynamic_spec("mmpp", 60);
        let a = run_one(&spec, &ScenarioCache::new()).unwrap();
        let b = run_one(&spec, &ScenarioCache::new()).unwrap();
        assert_eq!(a.costs.len(), b.costs.len());
        for ((n1, c1), (n2, c2)) in a.costs.iter().zip(&b.costs) {
            assert_eq!(n1, n2);
            assert!((c1 - c2).abs() == 0.0, "{n1}: {c1} vs {c2} must be bit-identical");
        }
        let (sa, sb) = (a.adaptation.unwrap(), b.adaptation.unwrap());
        assert_eq!(sa.detections, sb.detections);
        assert!((sa.regret_total - sb.regret_total).abs() == 0.0);
    }

    fn quick_distributed_spec(fault: &str) -> ScenarioSpec {
        use crate::distributed::FaultSpec;
        use crate::scenarios::DistributedSpec;
        let mut spec = ScenarioSpec::named("abilene", Congestion::Nominal).unwrap();
        spec.base.name = format!("abilene-dist-{fault}");
        spec.events.clear();
        spec.iters = 1200;
        spec.distributed = Some(DistributedSpec {
            shards: 2,
            faults: FaultSpec::preset(fault, spec.base.seed).unwrap(),
            max_epochs: 4000,
        });
        spec
    }

    #[test]
    fn distributed_scenario_reports_rounds_messages_bytes() {
        let cache = ScenarioCache::new();
        let rep = run_one(&quick_distributed_spec("lossy"), &cache).unwrap();
        let d = rep.distributed.as_ref().expect("distributed block present");
        assert_eq!(d.converged, Some(true), "runtime must quiesce on abilene");
        assert!(d.rounds > 0 && d.ticks > d.rounds);
        assert!(d.messages_sent > 0 && d.bytes_sent > 0);
        assert!(d.messages_dropped > 0, "lossy spec must drop something");
        assert!(d.max_queue_depth > 0);
        assert_eq!(d.transport, "sim-net");
        assert_eq!(d.shards, 2);
        assert_eq!(rep.costs[0].0, "GP-dist");
        // the report's centralized reference runs at the default residual
        // tolerance (1e-7), so the gap bound here is looser than the
        // acceptance-grade 1e-6 asserted in rust/tests/chaos.rs against a
        // 1e-9-residual reference
        let gap = d.rel_gap_to_centralized.expect("quiescence-mode gap");
        assert!(gap < 1e-5, "async vs centralized gap {gap}");
        // the JSON report exposes the acceptance-gated columns
        let v = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        let block = v.get("distributed").expect("distributed block serialized");
        for key in ["rounds", "messages_sent", "bytes_sent", "stale_reads"] {
            assert!(block.get(key).is_some(), "missing column {key}");
        }
    }

    #[test]
    fn distributed_scenario_is_bit_deterministic() {
        let spec = quick_distributed_spec("partition");
        let a = run_one(&spec, &ScenarioCache::new()).unwrap();
        let b = run_one(&spec, &ScenarioCache::new()).unwrap();
        assert_eq!(a.gp_cost().to_bits(), b.gp_cost().to_bits());
        let (da, db) = (a.distributed.unwrap(), b.distributed.unwrap());
        assert_eq!(da.messages_sent, db.messages_sent);
        assert_eq!(da.messages_dropped, db.messages_dropped);
        assert_eq!(da.rounds, db.rounds);
        assert_eq!(da.stale_reads, db.stale_reads);
    }

    #[test]
    fn dynamic_tier_can_run_distributed() {
        let mut spec = quick_dynamic_spec("flash-crowd", 60);
        spec.base.name = "abilene-flash-crowd-dist".to_string();
        spec.distributed = Some(crate::scenarios::DistributedSpec {
            shards: 2,
            faults: crate::distributed::FaultSpec::clean(0),
            max_epochs: 100,
        });
        let cache = ScenarioCache::new();
        let rep = run_one(&spec, &cache).unwrap();
        assert_eq!(rep.workload.as_deref(), Some("flash-crowd"));
        let a = rep.adaptation.as_ref().expect("controller attached");
        assert!(a.detections >= 1, "flash crowd must be detected");
        let d = rep.distributed.as_ref().expect("runtime stats recovered");
        assert!(d.rounds >= 60, "one epoch per slot minimum");
        assert!(d.messages_sent > 0);
        assert_eq!(d.transport, "in-mem");
        // serving mode has no quiescence run or centralized reference
        assert_eq!(d.converged, None);
        assert_eq!(d.rel_gap_to_centralized, None);
    }

    fn quick_churn_spec(slots: usize) -> ScenarioSpec {
        let mut spec = ScenarioSpec::churn_matrix_sized(slots)
            .into_iter()
            .find(|s| s.base.topology == "abilene")
            .unwrap();
        spec.iters = 120;
        spec
    }

    #[test]
    fn churn_scenario_reports_admissions_and_reconvergence() {
        let rep = run_one(&quick_churn_spec(80), &ScenarioCache::new()).unwrap();
        let c = rep.churn.as_ref().expect("churn report has a churn block");
        assert_eq!(c.events, 4);
        assert_eq!(c.accepted + c.rejected, 3, "three registers scripted");
        assert!(c.accepted >= 1, "light congestion must admit something");
        // epochs = accepts + the drain (which only fires if its target was
        // itself admitted)
        let epochs = c.epochs as usize;
        assert!(
            epochs == c.accepted || epochs == c.accepted + 1,
            "epochs {epochs} vs accepted {}",
            c.accepted
        );
        assert_eq!(c.reconverge_slots.len(), c.accepted);
        assert!(rep.gp_cost().is_finite() && rep.gp_cost() > 0.0);
        assert_eq!(rep.costs.len(), 4, "GP + three baselines");
        // the JSON report exposes the churn block
        let v = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        let block = v.get("churn").expect("churn block serialized");
        assert!(block.get("accepted").unwrap().as_usize().unwrap() >= 1);
        assert!(block.get("reconverge_slots").is_some());
    }

    #[test]
    fn churn_scenario_is_deterministic() {
        let spec = quick_churn_spec(60);
        let a = run_one(&spec, &ScenarioCache::new()).unwrap();
        let b = run_one(&spec, &ScenarioCache::new()).unwrap();
        assert_eq!(a.gp_cost().to_bits(), b.gp_cost().to_bits());
        let (ca, cb) = (a.churn.unwrap(), b.churn.unwrap());
        assert_eq!(ca.accepted, cb.accepted);
        assert_eq!(ca.rejected, cb.rejected);
        assert_eq!(ca.reconverge_slots, cb.reconverge_slots);
    }

    fn quick_topo_churn_spec(slots: usize) -> ScenarioSpec {
        let mut spec = ScenarioSpec::named("er-20-40", Congestion::Nominal).unwrap();
        spec.base.name = "er-20-40-topo-churn".to_string();
        spec.events.clear();
        spec.iters = 150;
        spec.slots = slots;
        spec.topo_churn = Some(crate::topo::TopoChurnSpec::default_schedule(slots));
        spec
    }

    #[test]
    fn topo_churn_scenario_reports_rebinds_and_reconvergence() {
        let cache = ScenarioCache::new();
        let rep = run_one(&quick_topo_churn_spec(60), &cache).unwrap();
        let t = rep.topo_churn.as_ref().expect("topo-churn block present");
        assert_eq!(t.events, 3, "default schedule fires three events");
        // three removals + three repair batches, minus any the connectivity
        // filter emptied — at least the repairs of what was removed
        assert!(t.changes >= 2, "changes {} too few", t.changes);
        // ≥: a repair batch and an event landing on the same slot merge
        // into one rebuild but bump the epoch twice
        assert!(
            t.epochs as usize >= t.changes,
            "epochs {} vs changes {}",
            t.epochs,
            t.changes
        );
        assert!(t.removed_pairs_total >= 1);
        assert_eq!(t.reconverge_slots_warm.len(), t.changes);
        assert_eq!(t.reconverge_slots_cold.len(), t.changes);
        assert_eq!(t.retained_optimality.len(), t.changes);
        for &r in &t.retained_optimality {
            assert!(r.is_finite() && r > 0.0, "retained optimality {r}");
        }
        // the epoch rebuilds show up as phases, and the final comparison
        // ran on the fully-repaired network
        assert!(rep.phases.iter().any(|p| p.label == "topo-rebind"));
        assert_eq!(rep.phases.last().unwrap().label, "serving-end");
        assert_eq!(rep.costs.len(), 4, "GP + three baselines");
        assert!(rep.gp_cost().is_finite() && rep.gp_cost() > 0.0);
        // the JSON report exposes the acceptance-gated v5 columns
        let v = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        let block = v.get("topo_churn").expect("topo_churn block serialized");
        for key in [
            "changes",
            "rebind_secs_mean",
            "reconverge_slots_warm",
            "reconverge_slots_cold",
            "retained_optimality_mean",
        ] {
            assert!(block.get(key).is_some(), "missing column {key}");
        }
    }

    #[test]
    fn topo_churn_scenario_is_deterministic() {
        let spec = quick_topo_churn_spec(50);
        let a = run_one(&spec, &ScenarioCache::new()).unwrap();
        let b = run_one(&spec, &ScenarioCache::new()).unwrap();
        assert_eq!(a.gp_cost().to_bits(), b.gp_cost().to_bits());
        let (ta, tb) = (a.topo_churn.unwrap(), b.topo_churn.unwrap());
        assert_eq!(ta.changes, tb.changes);
        assert_eq!(ta.removed_pairs_total, tb.removed_pairs_total);
        assert_eq!(ta.reconverge_slots_warm, tb.reconverge_slots_warm);
        assert_eq!(ta.reconverge_slots_cold, tb.reconverge_slots_cold);
        for (x, y) in ta.retained_optimality.iter().zip(&tb.retained_optimality) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    fn quick_massive_spec(apps: usize, sources: usize, slots: usize) -> ScenarioSpec {
        crate::scenarios::ScenarioSpec::massive_matrix_sized(apps, sources, slots)
            .into_iter()
            .next()
            .unwrap()
    }

    #[test]
    fn massive_scenario_reports_streams_and_throughput() {
        let cache = ScenarioCache::new();
        let rep = run_one(&quick_massive_spec(4, 50, 12), &cache).unwrap();
        let ms = rep.massive.as_ref().expect("massive block present");
        assert_eq!(ms.streams, 4 * 50, "one stream per (app, source)");
        assert_eq!(ms.slots, 12);
        assert!(ms.arrivals_total > 0, "mmpp streams must produce arrivals");
        assert!(ms.offered_load > 0.0);
        assert!(ms.slot_wall_ms_mean >= 0.0 && ms.slot_wall_ms_max >= ms.slot_wall_ms_mean);
        assert!(ms.streams_per_sec > 0.0);
        // no optimizer ran
        assert!(rep.phases.is_empty());
        assert!(rep.costs.is_empty());
        assert_eq!(rep.workload.as_deref(), Some("mmpp"));
        // the JSON report exposes the acceptance-gated v6/v7 columns
        let v = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        let block = v.get("massive").expect("massive block serialized");
        for key in [
            "streams",
            "arrivals_total",
            "slot_wall_ms_mean",
            "streams_per_sec",
            "phase_sample_ms_mean",
            "phase_estimate_ms_mean",
            "phase_detect_ms_mean",
        ] {
            assert!(block.get(key).is_some(), "missing column {key}");
        }
        assert_eq!(block.get("streams").unwrap().as_usize(), Some(200));
    }

    #[test]
    fn massive_scenario_is_deterministic_modulo_wall_time() {
        let spec = quick_massive_spec(3, 40, 10);
        let a = run_one(&spec, &ScenarioCache::new()).unwrap();
        let b = run_one(&spec, &ScenarioCache::new()).unwrap();
        let (ma, mb) = (a.massive.unwrap(), b.massive.unwrap());
        assert_eq!(ma.streams, mb.streams);
        assert_eq!(ma.arrivals_total, mb.arrivals_total);
        assert_eq!(ma.detections, mb.detections);
        assert_eq!(ma.offered_load.to_bits(), mb.offered_load.to_bits());
    }

    fn quick_ha_spec(fault: &str, slots: usize) -> ScenarioSpec {
        let mut spec = ScenarioSpec::ha_matrix_sized(slots, 3)
            .into_iter()
            .find(|s| s.name().ends_with(fault))
            .expect("fault preset is in the ha matrix");
        spec.iters = 120;
        spec
    }

    #[test]
    fn ha_scenario_loses_no_committed_epoch() {
        let rep = run_one(&quick_ha_spec("clean", 20), &ScenarioCache::new()).unwrap();
        let h = rep.ha.as_ref().expect("ha report has an ha block");
        assert_eq!(h.lost, 0);
        assert_eq!(h.replicas, 3);
        assert!(h.commit_at_kill >= 1, "burst must commit before the kill");
        assert!(h.committed > h.commit_at_kill, "new term must commit");
        assert!(h.final_term >= 2, "failover must raise the term");
        assert!(h.election_ticks > 0 && h.failover_ticks > 0);
        assert!(h.final_apps >= 1, "some scripted register must be admitted");
        assert!(h.epochs >= h.final_apps as u64);
        assert!(rep.gp_cost().is_finite() && rep.gp_cost() > 0.0);
        // the JSON block is machine-readable and slot-gated
        let v = Json::parse(&rep.to_json().to_string_pretty()).unwrap();
        assert_eq!(
            v.get("ha").unwrap().get("lost").unwrap().as_usize(),
            Some(0)
        );
        assert_eq!(v.get("slots").unwrap().as_usize(), Some(20));
    }

    #[test]
    fn ha_runs_are_deterministic_per_spec() {
        let spec = quick_ha_spec("lossy", 12);
        let a = run_one(&spec, &ScenarioCache::new()).unwrap();
        let b = run_one(&spec, &ScenarioCache::new()).unwrap();
        assert_eq!(a.gp_cost().to_bits(), b.gp_cost().to_bits());
        let (ha_a, ha_b) = (a.ha.unwrap(), b.ha.unwrap());
        assert_eq!(ha_a.committed, ha_b.committed);
        assert_eq!(ha_a.commit_at_kill, ha_b.commit_at_kill);
        assert_eq!(ha_a.election_ticks, ha_b.election_ticks);
        assert_eq!(ha_a.failover_ticks, ha_b.failover_ticks);
        assert_eq!(ha_a.msgs_sent, ha_b.msgs_sent);
        assert_eq!(ha_a.msgs_dropped, ha_b.msgs_dropped);
        assert_eq!(ha_a.final_term, ha_b.final_term);
    }

    #[test]
    fn batch_runs_in_spec_order_and_writes_reports() {
        let specs = vec![
            quick_spec("abilene", Congestion::Light),
            quick_spec("abilene", Congestion::Heavy),
        ];
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("../target")
            .join(format!("scfo-scenarios-test-{}", std::process::id()));
        let reports = run_batch(
            &specs,
            &RunnerOptions {
                jobs: 2,
                out_dir: Some(dir.clone()),
                quiet: true,
            },
        )
        .unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].name, "abilene-light");
        assert_eq!(reports[1].name, "abilene-heavy");
        for rep in &reports {
            let path = dir.join(format!("{}.json", rep.name));
            let text = std::fs::read_to_string(&path).unwrap();
            assert!(Json::parse(&text).is_ok(), "unparseable report {path:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
