//! Scenario engine: declarative experiment composition + batch execution.
//!
//! A [`ScenarioSpec`] composes one experiment out of orthogonal dimensions:
//!
//! * **topology family** — any name [`crate::graph::topologies::by_name`]
//!   understands, including the generator-backed families (`er-<n>-<m>`,
//!   `grid-<r>x<c>`, `fat-tree-<k>`) and the real-network presets
//!   (`abilene`, `geant`, …);
//! * **workload** — the application/service-chain parameters of the
//!   underlying [`Scenario`] (apps, sources, chain length, packet schedule);
//! * **cost kind** — `queue` (M/M/1) or `linear` link/CPU costs;
//! * **congestion level** — a [`Congestion`] multiplier on all input rates;
//! * **dynamic-event schedule** — an ordered list of [`DynamicEvent`]s
//!   (input-rate steps and link churn) driving the online-adaptation path of
//!   [`crate::algo::gp::GradientProjection`] mid-run;
//! * **workload** (optional) — a nonstationary traffic spec
//!   ([`crate::workload::WorkloadSpec`]); when present the scenario runs
//!   through the online serving loop with the adaptation controller and its
//!   report carries regret/reconvergence metrics (`dynamic` tier,
//!   [`ScenarioSpec::dynamic_matrix`]);
//! * **topology churn** (optional) — a scripted link-flap/outage schedule
//!   ([`crate::topo::TopoChurnSpec`]); when present the scenario serves its
//!   slots under epoch-versioned CSR rebinds and the report compares warm
//!   reconvergence against a fresh-build oracle (`topo-churn` tier,
//!   [`ScenarioSpec::topo_churn_matrix`]).
//!
//! [`ScenarioSpec::matrix`] expands the default evaluation matrix (families ×
//! congestion levels, each with the standard event schedule); the
//! [`runner`] executes specs across a thread pool and emits one JSON report
//! per scenario comparing GP against the SPOC/LCOF/LPR-SC baselines. Specs
//! round-trip through JSON and load from `.json`/`.toml` files.
//!
//! # Examples
//!
//! Expand the default matrix and inspect its shape:
//!
//! ```
//! use scfo::scenarios::ScenarioSpec;
//!
//! let matrix = ScenarioSpec::matrix();
//! assert!(matrix.len() >= 12, "acceptance floor: >= 12 scenarios");
//! // three congestion levels per family, every spec carries a schedule
//! assert!(matrix.len() % 3 == 0);
//! assert!(matrix.iter().all(|s| !s.events.is_empty()));
//! ```
//!
//! Run a single (shrunk) scenario end to end:
//!
//! ```
//! use scfo::scenarios::{runner, Congestion, ScenarioSpec};
//!
//! let mut spec = ScenarioSpec::named("abilene", Congestion::Light).unwrap();
//! spec.iters = 40;          // keep the doctest fast
//! spec.events.clear();      // no churn for this smoke run
//! let cache = runner::ScenarioCache::new();
//! let report = runner::run_one(&spec, &cache).unwrap();
//! assert!(report.gp_cost() > 0.0);
//! assert_eq!(report.costs.len(), 4); // GP + three baselines
//! ```

pub mod runner;

pub use runner::{
    run_batch, run_massive, ChurnSummary, DistributedSummary, HaSummary, MassiveSummary,
    RunnerOptions, ScenarioCache, ScenarioReport, TopoChurnSummary,
};

use crate::config::Scenario;
use crate::control::AppSpec;
use crate::cost::CostKind;
use crate::distributed::FaultSpec;
use crate::topo::TopoChurnSpec;
use crate::util::json::Json;
use crate::workload::WorkloadSpec;

/// How a scenario runs the asynchronous distributed runtime
/// ([`crate::distributed::AsyncRuntime`]) instead of the centralized
/// optimizer. The report then carries a `distributed` block with
/// rounds/messages/bytes/stale-reads columns and compares the distributed
/// final cost against a centralized reference solve.
#[derive(Clone, Debug, PartialEq)]
pub struct DistributedSpec {
    /// Worker threads the node actors are sharded across.
    pub shards: usize,
    /// Fault model; `clean` selects the ideal in-memory transport.
    pub faults: FaultSpec,
    /// Epoch budget for the quiescence run.
    pub max_epochs: usize,
}

impl DistributedSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards as f64)),
            ("faults", self.faults.to_json()),
            ("max_epochs", Json::Num(self.max_epochs as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<DistributedSpec> {
        let shards = v.get("shards").and_then(Json::as_usize).unwrap_or(4);
        anyhow::ensure!(shards >= 1, "distributed.shards must be >= 1");
        let faults = match v.get("faults") {
            Some(f) => FaultSpec::from_json(f)?,
            None => FaultSpec::clean(0),
        };
        let max_epochs = v
            .get("max_epochs")
            .and_then(Json::as_usize)
            .unwrap_or(2000);
        Ok(DistributedSpec {
            shards,
            faults,
            max_epochs,
        })
    }
}

/// How a scenario runs the replicated control plane (the `ha` tier): a
/// [`crate::control::replication::ReplGroup`] of sans-IO replicas elects a
/// leader under the given fault model, commits a scripted register burst
/// through the multipaxos log, loses the leader mid-churn, and fails over —
/// the report's `ha` block pins that no committed catalog epoch is lost and
/// carries election/failover latency and commit-throughput columns.
#[derive(Clone, Debug, PartialEq)]
pub struct HaSpec {
    /// Replica-group size (3 or 5 in the tier matrices).
    pub replicas: usize,
    /// Fault model for the simulated message fabric — the same
    /// [`FaultSpec`] presets that drive the distributed tier's transport.
    pub faults: FaultSpec,
    /// Scripted app registrations proposed before (and re-proposed after)
    /// the leader kill.
    pub registers: usize,
    /// Virtual-tick budget for each election/replication phase.
    pub max_ticks: u64,
}

impl HaSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("replicas", Json::Num(self.replicas as f64)),
            ("faults", self.faults.to_json()),
            ("registers", Json::Num(self.registers as f64)),
            ("max_ticks", Json::Num(self.max_ticks as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<HaSpec> {
        let replicas = v.get("replicas").and_then(Json::as_usize).unwrap_or(3);
        anyhow::ensure!(replicas >= 2, "ha.replicas must be >= 2");
        let faults = match v.get("faults") {
            Some(f) => FaultSpec::from_json(f)?,
            None => FaultSpec::clean(0),
        };
        let registers = v.get("registers").and_then(Json::as_usize).unwrap_or(3);
        anyhow::ensure!(registers >= 1, "ha.registers must be >= 1");
        let max_ticks = v
            .get("max_ticks")
            .and_then(Json::as_usize)
            .unwrap_or(2000) as u64;
        Ok(HaSpec {
            replicas,
            faults,
            registers,
            max_ticks,
        })
    }
}

/// Congestion level: a multiplier applied to every exogenous input rate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Congestion {
    /// 0.6× the nominal rates — queues stay far from their knees.
    Light,
    /// The workload's nominal rates.
    Nominal,
    /// 1.4× the nominal rates — the congested regime where the paper's
    /// GP-vs-baseline gaps live.
    Heavy,
}

impl Congestion {
    /// All levels, in increasing load order.
    pub const ALL: [Congestion; 3] = [Congestion::Light, Congestion::Nominal, Congestion::Heavy];

    /// The input-rate multiplier.
    pub fn rate_multiplier(&self) -> f64 {
        match self {
            Congestion::Light => 0.6,
            Congestion::Nominal => 1.0,
            Congestion::Heavy => 1.4,
        }
    }

    /// Stable lowercase name (used in scenario names and JSON).
    pub fn name(&self) -> &'static str {
        match self {
            Congestion::Light => "light",
            Congestion::Nominal => "nominal",
            Congestion::Heavy => "heavy",
        }
    }

    /// Parse a level name.
    pub fn parse(s: &str) -> anyhow::Result<Congestion> {
        match s.to_ascii_lowercase().as_str() {
            "light" => Ok(Congestion::Light),
            "nominal" => Ok(Congestion::Nominal),
            "heavy" => Ok(Congestion::Heavy),
            other => anyhow::bail!("unknown congestion level '{other}' (light|nominal|heavy)"),
        }
    }
}

/// One dynamic event in a scenario's schedule. After the network mutation is
/// applied, the online optimizer gets `iters` further slots to adapt before
/// the next event fires.
#[derive(Clone, Debug, PartialEq)]
pub enum DynamicEvent {
    /// Multiply every application's input rates by `factor` (a demand step).
    RateScale { factor: f64, iters: usize },
    /// Remove the most-loaded removable link (deterministic choice: highest
    /// GP link flow whose removal keeps every destination reachable). The
    /// runner rebuilds the CSR arena on the pruned graph and warm-starts GP
    /// from the slot-remapped strategy
    /// ([`crate::strategy::Strategy::rebind_topology`] →
    /// [`crate::serving::Optimizer::rebind`]).
    LinkDown { iters: usize },
    /// Restore the most recently removed link: another epoch rebuild, back
    /// onto the denser arena — the repaired slots re-enter at zero mass and
    /// the optimizer shifts flow onto them only as the marginals warrant.
    LinkUp { iters: usize },
}

impl DynamicEvent {
    /// Adaptation budget after the event.
    pub fn iters(&self) -> usize {
        match self {
            DynamicEvent::RateScale { iters, .. }
            | DynamicEvent::LinkDown { iters }
            | DynamicEvent::LinkUp { iters } => *iters,
        }
    }

    /// Stable kind tag (used in JSON and reports).
    pub fn kind(&self) -> &'static str {
        match self {
            DynamicEvent::RateScale { .. } => "rate-scale",
            DynamicEvent::LinkDown { .. } => "link-down",
            DynamicEvent::LinkUp { .. } => "link-up",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            DynamicEvent::RateScale { factor, iters } => Json::obj(vec![
                ("kind", Json::Str("rate-scale".into())),
                ("factor", Json::Num(*factor)),
                ("iters", Json::Num(*iters as f64)),
            ]),
            DynamicEvent::LinkDown { iters } => Json::obj(vec![
                ("kind", Json::Str("link-down".into())),
                ("iters", Json::Num(*iters as f64)),
            ]),
            DynamicEvent::LinkUp { iters } => Json::obj(vec![
                ("kind", Json::Str("link-up".into())),
                ("iters", Json::Num(*iters as f64)),
            ]),
        }
    }

    pub fn from_json(v: &Json, default_iters: usize) -> anyhow::Result<DynamicEvent> {
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("event: missing 'kind'"))?;
        let iters = v
            .get("iters")
            .and_then(Json::as_usize)
            .unwrap_or(default_iters);
        match kind {
            "rate-scale" => {
                let factor = v
                    .get("factor")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("rate-scale event: missing 'factor'"))?;
                anyhow::ensure!(factor > 0.0, "rate-scale factor must be positive");
                Ok(DynamicEvent::RateScale { factor, iters })
            }
            "link-down" => Ok(DynamicEvent::LinkDown { iters }),
            "link-up" => Ok(DynamicEvent::LinkUp { iters }),
            other => anyhow::bail!("unknown event kind '{other}'"),
        }
    }
}

/// One scripted control-plane action within a [`ChurnSpec`].
#[derive(Clone, Debug, PartialEq)]
pub enum ChurnAction {
    /// Register an explicitly specified application (admission-checked).
    Register(AppSpec),
    /// Register a deterministically *generated* application: destination
    /// and sources are drawn from a churn RNG forked off the scenario
    /// seed, rates from the scenario's `[rate_lo, rate_hi] · rate_scale ·
    /// rate` range — portable across topology families.
    RegisterRandom { id: String, rate: f64 },
    /// Stop an app's traffic (kept in the network while in-flight work
    /// drains).
    Drain { id: String },
    /// Remove an app entirely.
    Remove { id: String },
}

impl ChurnAction {
    pub fn kind(&self) -> &'static str {
        match self {
            ChurnAction::Register(_) => "register",
            ChurnAction::RegisterRandom { .. } => "register-random",
            ChurnAction::Drain { .. } => "drain",
            ChurnAction::Remove { .. } => "remove",
        }
    }
}

/// One timed control-plane event in a churn schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct ChurnEvent {
    /// Serving slot (0-based) the action fires *before*.
    pub at_slot: usize,
    pub action: ChurnAction,
}

impl ChurnEvent {
    pub fn to_json(&self) -> Json {
        let mut obj = match &self.action {
            ChurnAction::Register(spec) => match spec.to_json() {
                Json::Obj(o) => o,
                _ => unreachable!("AppSpec::to_json returns an object"),
            },
            ChurnAction::RegisterRandom { id, rate } => {
                let mut o = std::collections::BTreeMap::new();
                o.insert("id".to_string(), Json::Str(id.clone()));
                o.insert("rate".to_string(), Json::Num(*rate));
                o
            }
            ChurnAction::Drain { id } | ChurnAction::Remove { id } => {
                let mut o = std::collections::BTreeMap::new();
                o.insert("id".to_string(), Json::Str(id.clone()));
                o
            }
        };
        obj.insert("kind".to_string(), Json::Str(self.action.kind().into()));
        obj.insert("at_slot".to_string(), Json::Num(self.at_slot as f64));
        Json::Obj(obj)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<ChurnEvent> {
        let at_slot = v
            .get("at_slot")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("churn event: missing 'at_slot'"))?;
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("churn event: missing 'kind'"))?;
        let id = || -> anyhow::Result<String> {
            Ok(v.get("id")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("churn '{kind}' event: missing 'id'"))?
                .to_string())
        };
        let action = match kind {
            "register" => ChurnAction::Register(AppSpec::from_json(v)?),
            "register-random" => ChurnAction::RegisterRandom {
                id: id()?,
                rate: v.get("rate").and_then(Json::as_f64).unwrap_or(1.0),
            },
            "drain" => ChurnAction::Drain { id: id()? },
            "remove" => ChurnAction::Remove { id: id()? },
            other => anyhow::bail!("unknown churn event kind '{other}'"),
        };
        Ok(ChurnEvent { at_slot, action })
    }
}

/// Scripted app arrival/departure schedule — the control-plane (`churn`)
/// tier. Served through [`crate::control::ControlPlane`] by
/// [`runner::run_churn`]: every action is admission-checked and triggers an
/// epoch rebuild; the report carries accept/reject counts and the
/// reconvergence slots after each accepted arrival.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChurnSpec {
    /// Events in firing order (sorted by `at_slot` at load time).
    pub events: Vec<ChurnEvent>,
}

impl ChurnSpec {
    pub fn to_json(&self) -> Json {
        Json::Arr(self.events.iter().map(ChurnEvent::to_json).collect())
    }

    pub fn from_json(v: &Json) -> anyhow::Result<ChurnSpec> {
        let mut events = Vec::new();
        for e in v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("churn: expected an array of events"))?
        {
            events.push(ChurnEvent::from_json(e)?);
        }
        events.sort_by_key(|e| e.at_slot);
        Ok(ChurnSpec { events })
    }

    /// The default schedule: two arrivals, a drain of the second arrival,
    /// and a late third arrival — spread across `slots` serving slots.
    pub fn default_schedule(slots: usize) -> ChurnSpec {
        let at = |frac_num: usize| slots * frac_num / 100;
        ChurnSpec {
            events: vec![
                ChurnEvent {
                    at_slot: at(20),
                    action: ChurnAction::RegisterRandom {
                        id: "churn-a".into(),
                        rate: 1.0,
                    },
                },
                ChurnEvent {
                    at_slot: at(40),
                    action: ChurnAction::RegisterRandom {
                        id: "churn-b".into(),
                        rate: 1.0,
                    },
                },
                ChurnEvent {
                    at_slot: at(60),
                    action: ChurnAction::Drain {
                        id: "churn-b".into(),
                    },
                },
                ChurnEvent {
                    at_slot: at(80),
                    action: ChurnAction::RegisterRandom {
                        id: "churn-c".into(),
                        rate: 0.8,
                    },
                },
            ],
        }
    }
}

/// A fully specified experiment: base workload × congestion × schedule.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    /// Topology + workload + cost parameters. `base.name` is the spec's
    /// unique name within a batch.
    pub base: Scenario,
    pub congestion: Congestion,
    /// Ordered dynamic-event schedule.
    pub events: Vec<DynamicEvent>,
    /// Optimization budget for the initial solve (and the per-algorithm
    /// budget for the final baseline comparison).
    pub iters: usize,
    /// Nonstationary traffic spec. When set, the scenario runs through the
    /// online serving loop ([`crate::serving::OnlineServer`] + adaptation
    /// controller) for [`ScenarioSpec::slots`] slots instead of the
    /// event-schedule path, and the report carries regret/reconvergence
    /// metrics.
    pub workload: Option<WorkloadSpec>,
    /// Serving slots for workload-driven (dynamic-tier) scenarios.
    pub slots: usize,
    /// Distributed-runtime spec. Alone, the scenario runs the async runtime
    /// to quiescence and compares it against a centralized reference;
    /// combined with `workload`, the dynamic serving loop drives the
    /// distributed optimizer instead of the centralized one.
    pub distributed: Option<DistributedSpec>,
    /// Scripted app arrival/departure schedule (the `churn` tier). When
    /// set, the scenario serves [`ScenarioSpec::slots`] slots through the
    /// multi-tenant control plane, applying the schedule's
    /// admission-checked lifecycle actions; combines with `workload` for
    /// nonstationary traffic underneath the churn.
    pub churn: Option<ChurnSpec>,
    /// Scripted topology-churn schedule (the `topo-churn` tier). When set,
    /// the scenario serves [`ScenarioSpec::slots`] slots under
    /// epoch-versioned link flaps and regional outages: each change
    /// rebuilds the CSR arena on the surviving graph and warm-starts GP
    /// from the slot-remapped strategy; the report carries rebind latency,
    /// warm-vs-cold reconvergence slots and the retained cost optimality
    /// against a fresh-build oracle.
    pub topo_churn: Option<TopoChurnSpec>,
    /// Million-stream workload hot-path marker (the `massive` tier). When
    /// set, the scenario skips the optimizer entirely and serves
    /// [`ScenarioSpec::slots`] slots of the batched SoA sampler
    /// ([`crate::workload::StreamTable`]) through the flat
    /// estimator/detector columns ([`runner::run_massive`]); the report's
    /// `massive` block carries slot wall-time and streams/sec. Stream count
    /// is `base.num_apps × base.num_sources`.
    pub massive: bool,
    /// Replicated-control-plane spec (the `ha` tier). When set, the
    /// scenario drives a simulated replica group through election →
    /// scripted register churn → leader kill → failover, asserts no
    /// committed epoch is lost, then serves [`ScenarioSpec::slots`] slots
    /// on the surviving fleet's plane and compares the final cost against
    /// a single-node truth solve.
    pub ha: Option<HaSpec>,
}

/// Topology families of the `large` scale tier
/// ([`ScenarioSpec::large_matrix`]): ≥1000-node sparse networks (plus a
/// 320-switch fat-tree fabric) that are only tractable under the CSR
/// slot layout.
pub const LARGE_FAMILIES: [&str; 4] = [
    "er-1000-4000",
    "grid-32x32",
    "fat-tree-16",
    "sw-1024-2048",
];

/// Default per-family workload parameters for generator families that have
/// no Table-II row: (num_apps, num_sources, link_param, comp_param).
fn family_defaults(family: &str) -> (usize, usize, f64, f64) {
    if family.starts_with("fat-tree") {
        (4, 3, 18.0, 12.0)
    } else if family.starts_with("grid") {
        (4, 3, 15.0, 12.0)
    } else {
        // er-*, sw-* and anything else generator-backed
        (4, 3, 15.0, 12.0)
    }
}

impl ScenarioSpec {
    /// The default dynamic-event schedule: a demand step up, a link failure,
    /// and the link's restoration — each followed by `iters` adaptation
    /// slots.
    pub fn default_schedule(iters: usize) -> Vec<DynamicEvent> {
        vec![
            DynamicEvent::RateScale {
                factor: 1.3,
                iters,
            },
            DynamicEvent::LinkDown { iters },
            DynamicEvent::LinkUp { iters },
        ]
    }

    /// Build the spec for one (family, congestion) cell of the matrix, with
    /// the default workload, queue costs and event schedule.
    pub fn named(family: &str, congestion: Congestion) -> anyhow::Result<ScenarioSpec> {
        let mut base = match Scenario::table2(family) {
            Ok(sc) => sc,
            Err(_) => {
                let (num_apps, num_sources, link_param, comp_param) = family_defaults(family);
                Scenario {
                    name: family.to_string(),
                    topology: family.to_string(),
                    num_apps,
                    num_sources,
                    num_tasks: 2,
                    link_kind: CostKind::Queue,
                    link_param,
                    comp_kind: CostKind::Queue,
                    comp_param,
                    rate_lo: 0.5,
                    rate_hi: 1.5,
                    rate_scale: 1.0,
                    packet_base: 10.0,
                    packet_decay: 5.0,
                    comp_weight: 0.25,
                    chain: None,
                    seed: 2023,
                }
            }
        };
        base.name = format!("{family}-{}", congestion.name());
        Ok(ScenarioSpec {
            base,
            congestion,
            events: Self::default_schedule(300),
            iters: 600,
            workload: None,
            slots: 200,
            distributed: None,
            churn: None,
            topo_churn: None,
            massive: false,
            ha: None,
        })
    }

    /// Topology family of the `massive` scale tier: the thousand-node
    /// sparse ER graph, with enough apps × sources to cross one million
    /// concurrent arrival streams.
    pub const MASSIVE_FAMILY: &'static str = "er-1000-4000";

    /// The `massive` scale tier: one cell, ≥1,000,000 MMPP streams on
    /// [`ScenarioSpec::MASSIVE_FAMILY`], served through the batched SoA
    /// workload hot path (no optimizer — the tier pins sampling, EWMA
    /// estimation and change-point detection throughput).
    pub fn massive_matrix() -> Vec<ScenarioSpec> {
        Self::massive_matrix_sized(1000, 1000, 20)
    }

    /// The `massive` tier with explicit app/source counts and slot budget
    /// (streams = apps × sources; tests size this down).
    pub fn massive_matrix_sized(apps: usize, sources: usize, slots: usize) -> Vec<ScenarioSpec> {
        let mut spec = Self::named(Self::MASSIVE_FAMILY, Congestion::Nominal)
            .expect("massive family is valid");
        spec.base.name = format!("{}-massive", Self::MASSIVE_FAMILY);
        spec.base.num_apps = apps;
        spec.base.num_sources = sources;
        // generous capacities like the other scale tiers, so the offered
        // load stays physically meaningful in the report
        spec.base.link_param = 60.0;
        spec.base.comp_param = 40.0;
        spec.events.clear();
        spec.iters = 0; // no optimizer runs in this tier
        spec.slots = slots;
        spec.workload = Some(WorkloadSpec::named("mmpp").expect("mmpp is a valid workload"));
        spec.massive = true;
        vec![spec]
    }

    /// Topology families of the `churn` tier.
    pub const CHURN_FAMILIES: [&'static str; 3] = ["abilene", "er-20-40", "grid-4x5"];

    /// The `churn` scale tier: small families at light congestion (leaving
    /// admission headroom for arrivals), each serving the default scripted
    /// app arrival/departure schedule through the control plane.
    pub fn churn_matrix() -> Vec<ScenarioSpec> {
        Self::churn_matrix_sized(200)
    }

    /// The `churn` tier with an explicit serving-slot budget.
    pub fn churn_matrix_sized(slots: usize) -> Vec<ScenarioSpec> {
        Self::CHURN_FAMILIES
            .iter()
            .map(|family| {
                let mut spec =
                    Self::named(family, Congestion::Light).expect("churn families are valid");
                spec.base.name = format!("{family}-churn");
                spec.events.clear();
                spec.iters = 300;
                spec.slots = slots;
                spec.churn = Some(ChurnSpec::default_schedule(slots));
                spec
            })
            .collect()
    }

    /// Topology family of the `ha` tier: one small real network — the tier
    /// pins control-plane replication behavior, not data-plane scale.
    pub const HA_FAMILY: &'static str = "abilene";

    /// Fault presets the `ha` tier crosses the replica group with (same
    /// presets as the distributed tier's transport).
    pub const HA_FAULTS: [&'static str; 3] = ["clean", "lossy", "partition"];

    /// The `ha` scale tier: a 3-replica group on [`ScenarioSpec::HA_FAMILY`]
    /// at light congestion (admission headroom for the scripted registers),
    /// crossed with the clean/lossy/partition fault presets. Each cell
    /// elects, commits a register burst, kills the leader mid-churn, and
    /// fails over without losing a committed epoch.
    pub fn ha_matrix() -> Vec<ScenarioSpec> {
        Self::ha_matrix_sized(80, 3)
    }

    /// The `ha` tier with explicit serving-slot budget and replica count.
    pub fn ha_matrix_sized(slots: usize, replicas: usize) -> Vec<ScenarioSpec> {
        Self::HA_FAULTS
            .iter()
            .map(|fault| {
                let mut spec = Self::named(Self::HA_FAMILY, Congestion::Light)
                    .expect("ha family is valid");
                spec.base.name = format!("{}-ha-{fault}", Self::HA_FAMILY);
                spec.events.clear();
                spec.iters = 300;
                spec.slots = slots;
                spec.ha = Some(HaSpec {
                    replicas,
                    faults: FaultSpec::preset(fault, spec.base.seed)
                        .expect("ha presets are valid"),
                    registers: 3,
                    max_ticks: 2000,
                });
                spec
            })
            .collect()
    }

    /// Topology families of the `topo-churn` tier: the thousand-node scale
    /// rungs plus a ten-thousand-node ER graph — topology churn is only
    /// interesting where a cold rebuild is expensive enough for the
    /// incremental rebind to matter.
    pub const TOPO_CHURN_FAMILIES: [&'static str; 4] = [
        "er-1000-4000",
        "grid-32x32",
        "sw-1024-2048",
        "er-10000-30000",
    ];

    /// The `topo-churn` scale tier: each family serves the default scripted
    /// flap/outage schedule ([`TopoChurnSpec::default_schedule`]) — every
    /// topology change is an epoch rebuild (incremental CSR rebind +
    /// φ remap) and the report compares warm reconvergence against a
    /// cold fresh-build oracle.
    pub fn topo_churn_matrix() -> Vec<ScenarioSpec> {
        Self::topo_churn_matrix_sized(150, 150)
    }

    /// The `topo-churn` tier with explicit serving-slot and oracle budgets.
    pub fn topo_churn_matrix_sized(slots: usize, iters: usize) -> Vec<ScenarioSpec> {
        Self::TOPO_CHURN_FAMILIES
            .iter()
            .map(|family| {
                let mut spec = Self::named(family, Congestion::Nominal)
                    .expect("topo-churn families are valid");
                spec.apply_scale_overrides();
                spec.base.name = format!("{family}-topo-churn");
                spec.events.clear();
                spec.iters = iters;
                spec.slots = slots;
                spec.topo_churn = Some(TopoChurnSpec::default_schedule(slots));
                spec
            })
            .collect()
    }

    /// Topology families of the `dnn` tier: one small real network plus two
    /// scale rungs — generalized chains matter most where the inflated
    /// inter-stage flows contend for shared cut links.
    pub const DNN_FAMILIES: [&'static str; 3] = ["abilene", "er-200-800", "er-1000-4000"];

    /// Chain profiles the `dnn` tier crosses the families with
    /// (VGG/ResNet-style activation-size sequences, see [`crate::chain`]).
    pub const DNN_PROFILES: [&'static str; 2] = ["vgg16", "resnet50"];

    /// Congestion levels of the `dnn` tier: nominal plus the heavy regime
    /// where GP's advantage over the congestion-blind baselines is pinned.
    pub const DNN_CONGESTION: [Congestion; 2] = [Congestion::Nominal, Congestion::Heavy];

    /// The `dnn` scale tier: DNN-split service chains (per-stage data
    /// inflation, result-return flows, fractional offload splits) under a
    /// flash-crowd workload — families × chain profiles × congestion
    /// levels, each served online like the `dynamic` tier. Reports carry
    /// the GP-vs-baseline cost comparison on the generalized cost.
    pub fn dnn_matrix() -> Vec<ScenarioSpec> {
        Self::dnn_matrix_sized(100, 150)
    }

    /// The `dnn` tier with explicit serving-slot and optimization budgets.
    pub fn dnn_matrix_sized(slots: usize, iters: usize) -> Vec<ScenarioSpec> {
        let mut out = Vec::with_capacity(
            Self::DNN_FAMILIES.len() * Self::DNN_PROFILES.len() * Self::DNN_CONGESTION.len(),
        );
        for family in Self::DNN_FAMILIES {
            for profile in Self::DNN_PROFILES {
                for congestion in Self::DNN_CONGESTION {
                    let mut spec =
                        Self::named(family, congestion).expect("dnn families are valid");
                    if family != "abilene" {
                        spec.apply_scale_overrides();
                    }
                    spec.base.name =
                        format!("{family}-dnn-{profile}-{}", congestion.name());
                    spec.base.chain = Some(
                        crate::chain::ChainSpec::named(profile)
                            .expect("dnn profiles are valid"),
                    );
                    spec.events.clear();
                    spec.iters = iters;
                    spec.slots = slots;
                    spec.workload = Some(
                        WorkloadSpec::named("flash-crowd")
                            .expect("flash-crowd is a valid workload"),
                    );
                    out.push(spec);
                }
            }
        }
        out
    }

    /// Topology families of the `dynamic` tier.
    pub const DYNAMIC_FAMILIES: [&'static str; 3] = ["abilene", "er-20-40", "grid-4x5"];

    /// Workload presets the `dynamic` tier crosses the families with.
    pub const DYNAMIC_WORKLOADS: [&'static str; 3] = ["diurnal", "flash-crowd", "mmpp"];

    /// The `dynamic` scale tier: topology families × nonstationary
    /// workloads, each served online with the adaptation controller
    /// attached. Reports carry per-slot regret vs the omniscient oracle and
    /// slots-to-reconvergence per detected change point.
    pub fn dynamic_matrix() -> Vec<ScenarioSpec> {
        Self::dynamic_matrix_sized(200)
    }

    /// The `dynamic` tier with an explicit serving-slot budget.
    pub fn dynamic_matrix_sized(slots: usize) -> Vec<ScenarioSpec> {
        let mut out = Vec::with_capacity(Self::DYNAMIC_FAMILIES.len() * Self::DYNAMIC_WORKLOADS.len());
        for family in Self::DYNAMIC_FAMILIES {
            for workload in Self::DYNAMIC_WORKLOADS {
                let mut spec = Self::named(family, Congestion::Nominal)
                    .expect("dynamic families are valid");
                spec.base.name = format!("{family}-{workload}");
                spec.events.clear();
                spec.iters = 300;
                spec.slots = slots;
                spec.workload =
                    Some(WorkloadSpec::named(workload).expect("dynamic workloads are valid"));
                out.push(spec);
            }
        }
        out
    }

    /// The default evaluation matrix: five topology families × three
    /// congestion levels, each with the default dynamic-event schedule —
    /// 15 scenarios.
    pub fn matrix() -> Vec<ScenarioSpec> {
        Self::matrix_sized(600, 300)
    }

    /// The `large` scale tier: thousand-node-class topologies that the
    /// former dense `[stage][n×(n+1)]` layout could not hold (a single
    /// dense stage at n=1024 is ~8.4 MB of φ plus the same again for δ,
    /// blocked flags and support — per stage; the CSR arena is ~(m+n)
    /// entries instead). One nominal-congestion cell per family, with the
    /// standard dynamic-event schedule. See `docs/PERFORMANCE.md`.
    pub fn large_matrix() -> Vec<ScenarioSpec> {
        Self::large_matrix_sized(150, 60)
    }

    /// Workload overrides shared by every scale-tier (≥200-node) cell —
    /// keep |𝒮| small and capacities generous: a 1000-node sparse topology
    /// funnels many sources' flow through few cut links, so per-link
    /// headroom must grow with the network diameter. Used by the `large`
    /// and `distributed` tiers and the heavy integration tests, so a retune
    /// reaches all of them.
    pub fn apply_scale_overrides(&mut self) {
        self.base.num_apps = 2;
        self.base.num_sources = 3;
        self.base.link_param = 60.0;
        self.base.comp_param = 40.0;
    }

    /// The `large` tier with explicit optimization budgets.
    pub fn large_matrix_sized(iters: usize, event_iters: usize) -> Vec<ScenarioSpec> {
        LARGE_FAMILIES
            .iter()
            .map(|family| {
                let mut spec = Self::named(family, Congestion::Nominal)
                    .expect("large families are valid");
                spec.apply_scale_overrides();
                spec.iters = iters;
                spec.events = Self::default_schedule(event_iters);
                spec
            })
            .collect()
    }

    /// Topology families of the `distributed` tier: one small real network
    /// plus three scale rungs of the sharded async runtime.
    pub const DISTRIBUTED_FAMILIES: [&'static str; 4] =
        ["abilene", "er-200-800", "er-1000-4000", "sw-1024-2048"];

    /// Fault presets the `distributed` tier crosses the families with.
    pub const DISTRIBUTED_FAULTS: [&'static str; 3] = ["clean", "lossy", "partition"];

    /// The `distributed` scale tier: families × fault presets, each running
    /// the asynchronous sharded runtime to quiescence and comparing against
    /// a centralized reference solve. Reports carry the
    /// rounds/messages/bytes/stale-reads columns.
    pub fn distributed_matrix() -> Vec<ScenarioSpec> {
        Self::distributed_matrix_sized(4, 2000)
    }

    /// The `distributed` tier with explicit shard count and epoch budget.
    pub fn distributed_matrix_sized(shards: usize, max_epochs: usize) -> Vec<ScenarioSpec> {
        let mut out =
            Vec::with_capacity(Self::DISTRIBUTED_FAMILIES.len() * Self::DISTRIBUTED_FAULTS.len());
        for family in Self::DISTRIBUTED_FAMILIES {
            for fault in Self::DISTRIBUTED_FAULTS {
                let mut spec =
                    Self::named(family, Congestion::Nominal).expect("distributed families are valid");
                if family != "abilene" {
                    spec.apply_scale_overrides();
                }
                spec.base.name = format!("{family}-dist-{fault}");
                spec.events.clear();
                spec.iters = 1500; // centralized-reference budget
                spec.distributed = Some(DistributedSpec {
                    shards,
                    faults: FaultSpec::preset(fault, spec.base.seed)
                        .expect("distributed presets are valid"),
                    max_epochs,
                });
                out.push(spec);
            }
        }
        out
    }

    /// The default matrix with explicit optimization budgets (`iters` for
    /// the initial solve and final comparison, `event_iters` per event).
    pub fn matrix_sized(iters: usize, event_iters: usize) -> Vec<ScenarioSpec> {
        let families = ["er-20-40", "grid-4x5", "fat-tree-4", "abilene", "geant"];
        let mut out = Vec::with_capacity(families.len() * Congestion::ALL.len());
        for family in families {
            for congestion in Congestion::ALL {
                let mut spec =
                    Self::named(family, congestion).expect("matrix families are valid");
                spec.iters = iters;
                spec.events = Self::default_schedule(event_iters);
                out.push(spec);
            }
        }
        out
    }

    /// The spec's unique name (the base scenario's name).
    pub fn name(&self) -> &str {
        &self.base.name
    }

    /// The base scenario with the congestion multiplier folded into
    /// `rate_scale` — what the runner actually builds.
    pub fn effective_base(&self) -> Scenario {
        let mut sc = self.base.clone();
        sc.rate_scale *= self.congestion.rate_multiplier();
        sc
    }

    pub fn to_json(&self) -> Json {
        let mut obj = match self.base.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!("Scenario::to_json returns an object"),
        };
        obj.insert(
            "congestion".to_string(),
            Json::Str(self.congestion.name().to_string()),
        );
        obj.insert("iters".to_string(), Json::Num(self.iters as f64));
        obj.insert(
            "events".to_string(),
            Json::Arr(self.events.iter().map(DynamicEvent::to_json).collect()),
        );
        if let Some(w) = &self.workload {
            obj.insert("workload".to_string(), w.to_json());
        }
        if self.workload.is_some()
            || self.churn.is_some()
            || self.topo_churn.is_some()
            || self.ha.is_some()
        {
            obj.insert("slots".to_string(), Json::Num(self.slots as f64));
        }
        if let Some(d) = &self.distributed {
            obj.insert("distributed".to_string(), d.to_json());
        }
        if let Some(c) = &self.churn {
            obj.insert("churn".to_string(), c.to_json());
        }
        if let Some(t) = &self.topo_churn {
            obj.insert("topo_churn".to_string(), t.to_json());
        }
        if self.massive {
            obj.insert("massive".to_string(), Json::Bool(true));
        }
        if let Some(h) = &self.ha {
            obj.insert("ha".to_string(), h.to_json());
        }
        Json::Obj(obj)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<ScenarioSpec> {
        let base = Scenario::from_json(v)?;
        let congestion = match v.get("congestion").and_then(Json::as_str) {
            Some(s) => Congestion::parse(s)?,
            None => Congestion::Nominal,
        };
        let iters = v.get("iters").and_then(Json::as_usize).unwrap_or(600);
        let mut events = Vec::new();
        if let Some(arr) = v.get("events").and_then(Json::as_arr) {
            for e in arr {
                events.push(DynamicEvent::from_json(e, iters)?);
            }
        }
        // `workload = "diurnal"` (string) or a full `[workload]` table
        let workload = match v.get("workload") {
            Some(w) => Some(WorkloadSpec::from_json(w)?),
            None => None,
        };
        let slots = v.get("slots").and_then(Json::as_usize).unwrap_or(200);
        let distributed = match v.get("distributed") {
            Some(d) => Some(DistributedSpec::from_json(d)?),
            None => None,
        };
        let churn = match v.get("churn") {
            Some(c) => Some(ChurnSpec::from_json(c)?),
            None => None,
        };
        let topo_churn = match v.get("topo_churn") {
            Some(t) => Some(TopoChurnSpec::from_json(t)?),
            None => None,
        };
        let massive = v.get("massive").and_then(Json::as_bool).unwrap_or(false);
        let ha = match v.get("ha") {
            Some(h) => Some(HaSpec::from_json(h)?),
            None => None,
        };
        Ok(ScenarioSpec {
            base,
            congestion,
            events,
            iters,
            workload,
            slots,
            distributed,
            churn,
            topo_churn,
            massive,
            ha,
        })
    }

    /// Load a spec from a `.json` or `.toml` file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<ScenarioSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        let v = crate::config::parse_config_text(&text, path)?;
        ScenarioSpec::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_covers_families_and_levels() {
        let m = ScenarioSpec::matrix();
        assert_eq!(m.len(), 15);
        let families: std::collections::BTreeSet<&str> =
            m.iter().map(|s| s.base.topology.as_str()).collect();
        assert!(families.len() >= 3, "need >= 3 topology families");
        for level in Congestion::ALL {
            assert_eq!(
                m.iter().filter(|s| s.congestion == level).count(),
                families.len()
            );
        }
        // every cell has the dynamic schedule and a unique name
        let names: std::collections::BTreeSet<&str> =
            m.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), m.len());
        assert!(m.iter().all(|s| s.events.len() == 3));
    }

    #[test]
    fn large_matrix_targets_thousand_node_class() {
        let m = ScenarioSpec::large_matrix();
        assert_eq!(m.len(), LARGE_FAMILIES.len());
        // at least one ≥1000-node family, all nominal, all scheduled
        assert!(m
            .iter()
            .any(|s| s.base.topology == "er-1000-4000"));
        for s in &m {
            assert_eq!(s.congestion, Congestion::Nominal);
            assert!(!s.events.is_empty());
            assert!(LARGE_FAMILIES.contains(&s.base.topology.as_str()));
        }
    }

    #[test]
    fn spec_json_roundtrip() {
        let spec = ScenarioSpec::named("grid-4x5", Congestion::Heavy).unwrap();
        let v = spec.to_json();
        let re = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(re.name(), spec.name());
        assert_eq!(re.congestion, spec.congestion);
        assert_eq!(re.events, spec.events);
        assert_eq!(re.iters, spec.iters);
        assert_eq!(re.base.topology, spec.base.topology);
        assert_eq!(re.workload, None);
    }

    #[test]
    fn dynamic_matrix_crosses_families_and_workloads() {
        let m = ScenarioSpec::dynamic_matrix();
        assert_eq!(
            m.len(),
            ScenarioSpec::DYNAMIC_FAMILIES.len() * ScenarioSpec::DYNAMIC_WORKLOADS.len()
        );
        let names: std::collections::BTreeSet<&str> = m.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), m.len(), "dynamic names must be unique");
        for s in &m {
            let w = s.workload.as_ref().expect("dynamic specs carry a workload");
            assert!(ScenarioSpec::DYNAMIC_WORKLOADS.contains(&w.name()));
            assert!(s.events.is_empty(), "dynamic tier replaces the event path");
            assert!(s.slots > 0);
        }
        // every workload appears once per family
        for wname in ScenarioSpec::DYNAMIC_WORKLOADS {
            let count = m
                .iter()
                .filter(|s| s.workload.as_ref().unwrap().name() == wname)
                .count();
            assert_eq!(count, ScenarioSpec::DYNAMIC_FAMILIES.len());
        }
    }

    #[test]
    fn dynamic_spec_roundtrips_with_workload() {
        let matrix = ScenarioSpec::dynamic_matrix();
        let spec = &matrix[0];
        let re = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(re.workload, spec.workload);
        assert_eq!(re.slots, spec.slots);
        assert_eq!(re.name(), spec.name());
    }

    #[test]
    fn spec_workload_parses_from_toml_string_and_table() {
        let as_string = r#"
            name = "dyn-a"
            topology = "abilene"
            workload = "flash-crowd"
            slots = 90
        "#;
        let v = crate::util::toml::parse(as_string).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(spec.workload.as_ref().unwrap().name(), "flash-crowd");
        assert_eq!(spec.slots, 90);

        let as_table = r#"
            name = "dyn-b"
            topology = "abilene"
            [workload]
            kind = "diurnal"
            period = 16.0
        "#;
        let v = crate::util::toml::parse(as_table).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        match &spec.workload.as_ref().unwrap().model {
            crate::workload::ModelSpec::Diurnal { period, .. } => assert_eq!(*period, 16.0),
            other => panic!("expected diurnal, got {other:?}"),
        }
    }

    #[test]
    fn spec_parses_from_toml_text() {
        let toml_text = r#"
            name = "custom-heavy"
            topology = "er-15-30"
            congestion = "heavy"
            iters = 123
            [[events]]
            kind = "rate-scale"
            factor = 1.5
            [[events]]
            kind = "link-down"
        "#;
        let v = crate::util::toml::parse(toml_text).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(spec.name(), "custom-heavy");
        assert_eq!(spec.congestion, Congestion::Heavy);
        assert_eq!(spec.iters, 123);
        assert_eq!(spec.events.len(), 2);
        assert_eq!(
            spec.events[0],
            DynamicEvent::RateScale {
                factor: 1.5,
                iters: 123
            }
        );
        assert_eq!(spec.events[1], DynamicEvent::LinkDown { iters: 123 });
    }

    #[test]
    fn distributed_matrix_crosses_families_and_faults() {
        let m = ScenarioSpec::distributed_matrix();
        assert_eq!(
            m.len(),
            ScenarioSpec::DISTRIBUTED_FAMILIES.len() * ScenarioSpec::DISTRIBUTED_FAULTS.len()
        );
        let names: std::collections::BTreeSet<&str> = m.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), m.len(), "distributed names must be unique");
        for s in &m {
            let d = s.distributed.as_ref().expect("distributed specs carry a spec");
            assert!(d.shards >= 1);
            assert!(ScenarioSpec::DISTRIBUTED_FAULTS.contains(&d.faults.name.as_str()));
            assert!(s.events.is_empty());
            assert!(s.workload.is_none());
        }
        assert!(m.iter().any(|s| s.base.topology == "er-1000-4000"));
    }

    #[test]
    fn distributed_spec_roundtrips() {
        let matrix = ScenarioSpec::distributed_matrix();
        let spec = &matrix[1];
        let re = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(re.distributed, spec.distributed);
        assert_eq!(re.name(), spec.name());
        // a plain spec round-trips without one
        let plain = ScenarioSpec::named("abilene", Congestion::Light).unwrap();
        let re = ScenarioSpec::from_json(&plain.to_json()).unwrap();
        assert_eq!(re.distributed, None);
    }

    #[test]
    fn churn_matrix_carries_schedules() {
        let m = ScenarioSpec::churn_matrix();
        assert_eq!(m.len(), ScenarioSpec::CHURN_FAMILIES.len());
        for s in &m {
            let c = s.churn.as_ref().expect("churn specs carry a schedule");
            assert!(c.events.len() >= 3);
            assert!(s.slots > 0);
            assert_eq!(s.congestion, Congestion::Light);
            assert!(s.name().ends_with("-churn"));
            // sorted by firing slot, all inside the serving window
            for w in c.events.windows(2) {
                assert!(w[0].at_slot <= w[1].at_slot);
            }
            assert!(c.events.iter().all(|e| e.at_slot < s.slots));
        }
    }

    #[test]
    fn churn_spec_roundtrips_json_and_toml() {
        let spec = &ScenarioSpec::churn_matrix()[0];
        let re = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(re.churn, spec.churn);
        assert_eq!(re.slots, spec.slots);

        let toml_text = r#"
            name = "my-churn"
            topology = "abilene"
            slots = 120
            [[churn]]
            at_slot = 10
            kind = "register"
            id = "svc"
            dest = 3
            num_tasks = 1
            packet_sizes = [4.0, 1.0]
            rates = [[0, 0.5]]
            [[churn]]
            at_slot = 60
            kind = "drain"
            id = "svc"
        "#;
        let v = crate::util::toml::parse(toml_text).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        let c = spec.churn.as_ref().unwrap();
        assert_eq!(c.events.len(), 2);
        match &c.events[0].action {
            ChurnAction::Register(app) => {
                assert_eq!(app.id, "svc");
                assert_eq!(app.rates, vec![(0, 0.5)]);
            }
            other => panic!("expected register, got {other:?}"),
        }
        assert_eq!(
            c.events[1].action,
            ChurnAction::Drain { id: "svc".into() }
        );
    }

    #[test]
    fn topo_churn_matrix_carries_schedules() {
        let m = ScenarioSpec::topo_churn_matrix();
        assert_eq!(m.len(), ScenarioSpec::TOPO_CHURN_FAMILIES.len());
        for s in &m {
            let t = s
                .topo_churn
                .as_ref()
                .expect("topo-churn specs carry a schedule");
            assert_eq!(t.events.len(), 3);
            assert!(s.slots > 0);
            assert!(s.name().ends_with("-topo-churn"));
            // every event fires AND repairs inside the serving window, so
            // the final epoch exercises the restore path
            for e in &t.events {
                assert!(e.at_slot < s.slots);
                assert!(e.at_slot + e.action.repair_after() < s.slots);
            }
        }
        assert!(m.iter().any(|s| s.base.topology == "er-10000-30000"));
    }

    #[test]
    fn topo_churn_spec_roundtrips() {
        let spec = &ScenarioSpec::topo_churn_matrix()[0];
        let re = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(re.topo_churn, spec.topo_churn);
        assert_eq!(re.slots, spec.slots);
        assert_eq!(re.name(), spec.name());
        // a plain spec round-trips without one
        let plain = ScenarioSpec::named("abilene", Congestion::Light).unwrap();
        let re = ScenarioSpec::from_json(&plain.to_json()).unwrap();
        assert_eq!(re.topo_churn, None);
    }

    #[test]
    fn ha_matrix_crosses_fault_presets() {
        let m = ScenarioSpec::ha_matrix();
        assert_eq!(m.len(), ScenarioSpec::HA_FAULTS.len());
        let names: std::collections::BTreeSet<&str> = m.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), m.len(), "ha names must be unique");
        for s in &m {
            let h = s.ha.as_ref().expect("ha specs carry an HaSpec");
            assert_eq!(h.replicas, 3);
            assert!(h.registers >= 1);
            assert!(h.max_ticks > 0);
            assert_eq!(s.congestion, Congestion::Light);
            assert_eq!(s.base.topology, ScenarioSpec::HA_FAMILY);
            assert!(s.name().contains("-ha-"));
            assert!(s.slots > 0);
            assert!(s.events.is_empty());
        }
        // the three cells differ exactly in their fault model; one is clean
        assert!(m.iter().any(|s| s.ha.as_ref().unwrap().faults.is_clean()));
        assert!(m.iter().any(|s| !s.ha.as_ref().unwrap().faults.is_clean()));
    }

    #[test]
    fn ha_spec_roundtrips_json_and_toml() {
        for spec in &ScenarioSpec::ha_matrix() {
            let re = ScenarioSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(re.ha, spec.ha);
            assert_eq!(re.slots, spec.slots);
            assert_eq!(re.name(), spec.name());
        }
        // a plain spec round-trips without one
        let plain = ScenarioSpec::named("abilene", Congestion::Light).unwrap();
        let re = ScenarioSpec::from_json(&plain.to_json()).unwrap();
        assert_eq!(re.ha, None);

        let toml_text = r#"
            name = "my-ha"
            topology = "abilene"
            slots = 60
            [ha]
            replicas = 5
            registers = 4
        "#;
        let v = crate::util::toml::parse(toml_text).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        let h = spec.ha.as_ref().unwrap();
        assert_eq!(h.replicas, 5);
        assert_eq!(h.registers, 4);
        assert!(h.faults.is_clean(), "faults default to clean");
        assert_eq!(h.max_ticks, 2000);
    }

    #[test]
    fn massive_matrix_targets_a_million_streams() {
        let m = ScenarioSpec::massive_matrix();
        assert_eq!(m.len(), 1);
        let s = &m[0];
        assert!(s.massive);
        assert_eq!(s.base.topology, ScenarioSpec::MASSIVE_FAMILY);
        assert!(
            s.base.num_apps * s.base.num_sources >= 1_000_000,
            "acceptance floor: >= 1M streams"
        );
        assert!(s.workload.is_some(), "massive tier carries a workload");
        assert!(s.slots > 0);
        assert!(s.events.is_empty());
        // the marker survives the JSON round trip
        let re = ScenarioSpec::from_json(&s.to_json()).unwrap();
        assert!(re.massive);
        assert_eq!(re.base.num_apps, s.base.num_apps);
        assert_eq!(re.base.num_sources, s.base.num_sources);
        assert_eq!(re.slots, s.slots);
        // a plain spec round-trips without the marker
        let plain = ScenarioSpec::named("abilene", Congestion::Light).unwrap();
        let re = ScenarioSpec::from_json(&plain.to_json()).unwrap();
        assert!(!re.massive);
    }

    #[test]
    fn dnn_matrix_crosses_families_profiles_and_congestion() {
        let m = ScenarioSpec::dnn_matrix();
        assert_eq!(
            m.len(),
            ScenarioSpec::DNN_FAMILIES.len()
                * ScenarioSpec::DNN_PROFILES.len()
                * ScenarioSpec::DNN_CONGESTION.len()
        );
        let names: std::collections::BTreeSet<&str> = m.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), m.len(), "dnn names must be unique");
        for s in &m {
            let chain = s.base.chain.as_ref().expect("dnn specs carry a chain");
            assert!(ScenarioSpec::DNN_PROFILES.contains(&chain.name()));
            let w = s.workload.as_ref().expect("dnn specs carry a workload");
            assert_eq!(w.name(), "flash-crowd");
            assert!(s.events.is_empty(), "dnn tier uses the serving loop");
            assert!(s.slots > 0);
            assert!(ScenarioSpec::DNN_CONGESTION.contains(&s.congestion));
        }
        // heavy-congestion cells exist for every family (the acceptance
        // criterion's GP-vs-baseline gap is pinned there)
        for family in ScenarioSpec::DNN_FAMILIES {
            assert!(m.iter().any(|s| {
                s.base.topology == family && s.congestion == Congestion::Heavy
            }));
        }
    }

    #[test]
    fn dnn_spec_roundtrips_with_chain() {
        let matrix = ScenarioSpec::dnn_matrix();
        for spec in matrix.iter().take(4) {
            let re = ScenarioSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(re.base.chain, spec.base.chain);
            assert_eq!(re.workload, spec.workload);
            assert_eq!(re.name(), spec.name());
        }
        // chain also parses from a TOML string form
        let toml_text = r#"
            name = "my-dnn"
            topology = "abilene"
            chain = "vgg16"
            workload = "flash-crowd"
            slots = 50
        "#;
        let v = crate::util::toml::parse(toml_text).unwrap();
        let spec = ScenarioSpec::from_json(&v).unwrap();
        assert_eq!(
            spec.base.chain,
            Some(crate::chain::ChainSpec::named("vgg16").unwrap())
        );
    }

    #[test]
    fn effective_base_scales_rates() {
        let spec = ScenarioSpec::named("abilene", Congestion::Heavy).unwrap();
        let eff = spec.effective_base();
        assert!((eff.rate_scale - 1.4).abs() < 1e-12);
        // base itself untouched
        assert!((spec.base.rate_scale - 1.0).abs() < 1e-12);
    }

    #[test]
    fn congestion_parse_roundtrip() {
        for c in Congestion::ALL {
            assert_eq!(Congestion::parse(c.name()).unwrap(), c);
        }
        assert!(Congestion::parse("extreme").is_err());
    }
}
