//! Congestion-dependent cost functions D_ij(F) and C_i(G).
//!
//! The paper requires costs that are increasing, continuously differentiable
//! and convex with D(0)=0. We provide:
//!
//! * [`CostFn::Linear`] — `d·x` (pure transmission/processing delay),
//! * [`CostFn::Queue`] — `x/(c-x)`, the expected number of packets in an
//!   M/M/1 queue with service rate `c` (by Little's law, aggregate queue
//!   length ≡ expected system delay),
//! * [`CostFn::Quadratic`] — `a·x + b·x²` (polynomial congestion proxy).
//!
//! The queue cost is *smoothly extended* beyond `SAT_FRAC·c`: above the
//! saturation knee the exact hyperbola is replaced by its second-order Taylor
//! expansion, which keeps the function finite, C¹-continuous, increasing and
//! convex. This matters for the optimizer: an infeasible iterate (F ≥ c)
//! still produces finite, very steep marginals that push flow away, instead
//! of NaN/∞ poisoning the gradient. Inside the knee the values are exact.

/// Fraction of capacity at which the exact M/M/1 curve hands over to the
/// quadratic extension.
pub const SAT_FRAC: f64 = 0.99;

/// A scalar convex cost function with closed-form derivative.
#[derive(Clone, Debug, PartialEq)]
pub enum CostFn {
    /// d·x
    Linear { d: f64 },
    /// x/(c-x) for x < SAT_FRAC·c, quadratic extension above.
    Queue { cap: f64 },
    /// a·x + b·x²
    Quadratic { a: f64, b: f64 },
}

impl CostFn {
    /// Cost value at load `x ≥ 0`.
    pub fn cost(&self, x: f64) -> f64 {
        debug_assert!(x >= -1e-9, "negative load {x}");
        let x = x.max(0.0);
        match *self {
            CostFn::Linear { d } => d * x,
            CostFn::Quadratic { a, b } => a * x + b * x * x,
            CostFn::Queue { cap } => {
                let knee = SAT_FRAC * cap;
                if x < knee {
                    x / (cap - x)
                } else {
                    // 2nd-order Taylor at the knee: value + slope·dx + ½curv·dx²
                    let v = knee / (cap - knee);
                    let s = cap / ((cap - knee) * (cap - knee));
                    let c2 = 2.0 * cap / ((cap - knee).powi(3));
                    let dx = x - knee;
                    v + s * dx + 0.5 * c2 * dx * dx
                }
            }
        }
    }

    /// Derivative (marginal cost) at load `x ≥ 0`.
    pub fn deriv(&self, x: f64) -> f64 {
        let x = x.max(0.0);
        match *self {
            CostFn::Linear { d } => d,
            CostFn::Quadratic { a, b } => a + 2.0 * b * x,
            CostFn::Queue { cap } => {
                let knee = SAT_FRAC * cap;
                if x < knee {
                    cap / ((cap - x) * (cap - x))
                } else {
                    let s = cap / ((cap - knee) * (cap - knee));
                    let c2 = 2.0 * cap / ((cap - knee).powi(3));
                    s + c2 * (x - knee)
                }
            }
        }
    }

    /// Second derivative (curvature) at load `x ≥ 0` — used by the
    /// diagonally-scaled (quasi-Newton) GP step of [`crate::algo::gp`].
    pub fn deriv2(&self, x: f64) -> f64 {
        let x = x.max(0.0);
        match *self {
            CostFn::Linear { .. } => 0.0,
            CostFn::Quadratic { b, .. } => 2.0 * b,
            CostFn::Queue { cap } => {
                let knee = SAT_FRAC * cap;
                let xx = x.min(knee); // extension region has constant curvature c2
                2.0 * cap / ((cap - xx).powi(3))
            }
        }
    }

    /// Is the load within the exact (non-extended) region?
    pub fn within_capacity(&self, x: f64) -> bool {
        match *self {
            CostFn::Queue { cap } => x < SAT_FRAC * cap,
            _ => true,
        }
    }

    /// Nominal capacity if any.
    pub fn capacity(&self) -> Option<f64> {
        match *self {
            CostFn::Queue { cap } => Some(cap),
            _ => None,
        }
    }
}

/// Cost family selector used by the config system (Table II "Link"/"Comp").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CostKind {
    Linear,
    Queue,
}

impl CostKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "linear" => Ok(CostKind::Linear),
            "queue" => Ok(CostKind::Queue),
            other => anyhow::bail!("unknown cost kind '{other}' (linear|queue)"),
        }
    }
    /// Instantiate with Table II's parameter (d̄_ij or s̄_i): a linear cost of
    /// slope 1/p (delay per unit on a link of "speed" p) or a queue of
    /// capacity p.
    pub fn instantiate(&self, p: f64) -> CostFn {
        match self {
            CostKind::Linear => CostFn::Linear { d: 1.0 / p },
            CostKind::Queue => CostFn::Queue { cap: p },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_basics() {
        let c = CostFn::Linear { d: 2.0 };
        assert_eq!(c.cost(0.0), 0.0);
        assert_eq!(c.cost(3.0), 6.0);
        assert_eq!(c.deriv(100.0), 2.0);
    }

    #[test]
    fn queue_exact_region() {
        let c = CostFn::Queue { cap: 10.0 };
        assert_eq!(c.cost(0.0), 0.0);
        assert!((c.cost(5.0) - 1.0).abs() < 1e-12); // 5/(10-5)
        assert!((c.deriv(5.0) - 0.4).abs() < 1e-12); // 10/25
        assert!(c.within_capacity(5.0));
        assert!(!c.within_capacity(9.95));
    }

    #[test]
    fn queue_extension_is_c1_and_monotone() {
        let c = CostFn::Queue { cap: 10.0 };
        let knee = SAT_FRAC * 10.0;
        let eps = 1e-7;
        // continuity of value and slope across the knee (slope ~1e3 there,
        // so value gap over 2·eps is ~2e-4·slope-scale)
        assert!((c.cost(knee - eps) - c.cost(knee + eps)).abs() < 1e-3);
        assert!((c.deriv(knee - eps) - c.deriv(knee + eps)).abs() < 1e-1);
        // monotone increasing + convex well past capacity
        let mut prev_c = 0.0;
        let mut prev_d = 0.0;
        for i in 0..200 {
            let x = i as f64 * 0.1; // up to 2x capacity
            let cc = c.cost(x);
            let dd = c.deriv(x);
            assert!(cc >= prev_c);
            assert!(dd >= prev_d);
            assert!(cc.is_finite() && dd.is_finite());
            prev_c = cc;
            prev_d = dd;
        }
    }

    #[test]
    fn derivative_matches_finite_difference() {
        let cases = [
            CostFn::Linear { d: 3.0 },
            CostFn::Queue { cap: 7.0 },
            CostFn::Quadratic { a: 1.0, b: 0.5 },
        ];
        for c in cases {
            for &x in &[0.1, 1.0, 3.0, 5.0] {
                let h = 1e-6;
                let fd = (c.cost(x + h) - c.cost(x - h)) / (2.0 * h);
                let an = c.deriv(x);
                assert!(
                    (fd - an).abs() < 1e-4 * (1.0 + an.abs()),
                    "{c:?} at {x}: fd={fd} an={an}"
                );
            }
        }
    }

    #[test]
    fn deriv2_matches_finite_difference() {
        let cases = [
            CostFn::Linear { d: 3.0 },
            CostFn::Queue { cap: 7.0 },
            CostFn::Quadratic { a: 1.0, b: 0.5 },
        ];
        for c in cases {
            for &x in &[0.1, 1.0, 3.0, 5.0] {
                let h = 1e-5;
                let fd = (c.deriv(x + h) - c.deriv(x - h)) / (2.0 * h);
                let an = c.deriv2(x);
                assert!(
                    (fd - an).abs() < 1e-3 * (1.0 + an.abs()),
                    "{c:?} at {x}: fd={fd} an={an}"
                );
            }
        }
    }

    #[test]
    fn deriv2_finite_beyond_capacity() {
        let c = CostFn::Queue { cap: 5.0 };
        for &x in &[4.9, 5.0, 7.5, 10.0] {
            assert!(c.deriv2(x).is_finite() && c.deriv2(x) > 0.0);
        }
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(CostKind::parse("Queue").unwrap(), CostKind::Queue);
        assert_eq!(CostKind::parse("linear").unwrap(), CostKind::Linear);
        assert!(CostKind::parse("cubic").is_err());
    }
}
