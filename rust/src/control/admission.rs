//! Admission control: feasibility gate against the congestion-aware
//! capacity region.
//!
//! The M/M/1 queue costs `F/(C − F)` blow up at capacity — admitting an
//! application that pushes any link or CPU past its capacity makes the
//! operating point infeasible no matter how the optimizer routes. Before a
//! register/update commits, the [`AdmissionController`] therefore evaluates
//! the *candidate* network (current fleet + the new spec) at a probed
//! operating point: warm-start φ (surviving apps keep their rows, the
//! candidate gets min-hop seeding), run a short burst of GP iterations, and
//! require
//!
//! 1. every link utilization `F_e / C_e` and CPU utilization `G_i / C_i`
//!    strictly below a configurable headroom fraction, and
//! 2. the predicted aggregate-cost increase within a configurable budget.
//!
//! Accepts return the probed strategy so the commit path can warm-start the
//! live optimizer from the already-reconverged point; rejects return a
//! machine-readable reason (surfaced as HTTP 409 by the ops API).

use crate::algo::gp::{GpOptions, GradientProjection};
use crate::app::Network;
use crate::flow::FlowState;
use crate::strategy::Strategy;
use crate::util::json::Json;

/// Admission policy knobs.
#[derive(Clone, Debug)]
pub struct AdmissionOptions {
    /// Utilization ceiling as a fraction of capacity: admit only if every
    /// link/CPU stays strictly below `headroom · C` at the probed point.
    pub headroom: f64,
    /// Reject if the probed aggregate cost exceeds the current cost by more
    /// than this (absolute). `f64::INFINITY` disables the budget.
    pub max_cost_increase: f64,
    /// GP iterations spent probing the candidate operating point. More
    /// iterations tighten the estimate (and warm the commit further) at the
    /// price of admission latency — the tradeoff BENCH.json v5 measures.
    pub probe_iters: usize,
}

impl Default for AdmissionOptions {
    fn default() -> Self {
        AdmissionOptions {
            headroom: 0.9,
            max_cost_increase: f64::INFINITY,
            probe_iters: 60,
        }
    }
}

/// The outcome of an admission evaluation.
#[derive(Clone, Debug)]
pub enum AdmissionDecision {
    Accepted {
        /// Aggregate cost at the probed operating point.
        predicted_cost: f64,
        /// Worst link/CPU utilization at the probed point (diagnostics).
        peak_utilization: f64,
        /// The probed strategy — commit warm-starts the optimizer from it.
        probe: Strategy,
    },
    Rejected {
        /// Human- and machine-readable reason (`reason` field of the HTTP
        /// 409 body).
        reason: String,
    },
}

impl AdmissionDecision {
    pub fn accepted(&self) -> bool {
        matches!(self, AdmissionDecision::Accepted { .. })
    }

    pub fn to_json(&self) -> Json {
        match self {
            AdmissionDecision::Accepted {
                predicted_cost,
                peak_utilization,
                ..
            } => Json::obj(vec![
                ("accepted", Json::Bool(true)),
                ("predicted_cost", Json::Num(*predicted_cost)),
                ("peak_utilization", Json::Num(*peak_utilization)),
            ]),
            AdmissionDecision::Rejected { reason } => Json::obj(vec![
                ("accepted", Json::Bool(false)),
                ("reason", Json::Str(reason.clone())),
            ]),
        }
    }
}

/// The admission gate. Stateless between evaluations; the control plane
/// owns the accept/reject counters and latency histogram.
#[derive(Clone, Debug, Default)]
pub struct AdmissionController {
    pub opts: AdmissionOptions,
}

impl AdmissionController {
    pub fn new(opts: AdmissionOptions) -> AdmissionController {
        AdmissionController { opts }
    }

    /// Evaluate a candidate network at its probed operating point.
    /// `warm` must be feasible and loop-free for `net` (the control plane
    /// passes the per-stage row remap of the live φ with min-hop seeding
    /// for the candidate app); `current_cost` is the fleet's aggregate cost
    /// before the change (the cost-budget baseline).
    pub fn evaluate(
        &self,
        net: &Network,
        warm: &Strategy,
        current_cost: f64,
    ) -> AdmissionDecision {
        let mut gp = GradientProjection::with_strategy(net, warm.clone(), GpOptions::default());
        gp.run(net, self.opts.probe_iters);
        let fs = match FlowState::solve(net, &gp.phi) {
            Ok(fs) => fs,
            Err(e) => {
                return AdmissionDecision::Rejected {
                    reason: format!("probe produced an unsolvable strategy: {e}"),
                }
            }
        };
        let headroom = self.opts.headroom;
        let mut peak = 0.0f64;
        for e in 0..net.m() {
            if let Some(cap) = net.link_cost[e].capacity() {
                let util = fs.link_flow[e] / cap;
                peak = peak.max(util);
                if util >= headroom {
                    let (i, j) = net.graph.edge(e);
                    return AdmissionDecision::Rejected {
                        reason: format!(
                            "link ({i} -> {j}) utilization {util:.3} >= headroom {headroom:.2}"
                        ),
                    };
                }
            }
        }
        for i in 0..net.n() {
            if let Some(cap) = net.comp_cost[i].capacity() {
                let util = fs.workload[i] / cap;
                peak = peak.max(util);
                if util >= headroom {
                    return AdmissionDecision::Rejected {
                        reason: format!(
                            "cpu {i} utilization {util:.3} >= headroom {headroom:.2}"
                        ),
                    };
                }
            }
        }
        let delta = fs.total_cost - current_cost;
        if current_cost.is_finite() && delta > self.opts.max_cost_increase {
            return AdmissionDecision::Rejected {
                reason: format!(
                    "predicted cost increase {delta:.4} exceeds budget {:.4}",
                    self.opts.max_cost_increase
                ),
            };
        }
        AdmissionDecision::Accepted {
            predicted_cost: fs.total_cost,
            peak_utilization: peak,
            probe: gp.phi,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::small_net;

    #[test]
    fn feasible_candidate_is_accepted_with_probe() {
        let net = small_net(true);
        let warm = Strategy::shortest_path_to_dest(&net);
        let ctl = AdmissionController::default();
        let d = ctl.evaluate(&net, &warm, f64::INFINITY);
        match d {
            AdmissionDecision::Accepted {
                predicted_cost,
                peak_utilization,
                ref probe,
            } => {
                assert!(predicted_cost > 0.0 && predicted_cost.is_finite());
                assert!(peak_utilization < ctl.opts.headroom);
                probe.validate(&net).unwrap();
            }
            AdmissionDecision::Rejected { ref reason } => panic!("rejected: {reason}"),
        }
        assert!(d.to_json().get("accepted").unwrap().as_bool().unwrap());
    }

    #[test]
    fn overload_is_rejected_with_a_reason() {
        let mut net = small_net(true);
        // scale demand far past any queue capacity
        for app in &mut net.apps {
            for r in &mut app.input_rates {
                *r *= 1e4;
            }
        }
        let warm = Strategy::shortest_path_to_dest(&net);
        let ctl = AdmissionController::default();
        match ctl.evaluate(&net, &warm, 1.0) {
            AdmissionDecision::Rejected { reason } => {
                assert!(
                    reason.contains("utilization"),
                    "reason should name the bottleneck: {reason}"
                );
            }
            AdmissionDecision::Accepted { .. } => panic!("overload admitted"),
        }
    }

    #[test]
    fn cost_budget_rejects_expensive_candidates() {
        let net = small_net(true);
        let warm = Strategy::shortest_path_to_dest(&net);
        let ctl = AdmissionController::new(AdmissionOptions {
            max_cost_increase: 1e-12,
            ..AdmissionOptions::default()
        });
        // current cost ~0 makes any real fleet blow the budget
        match ctl.evaluate(&net, &warm, 0.0) {
            AdmissionDecision::Rejected { reason } => {
                assert!(reason.contains("budget"), "{reason}");
            }
            AdmissionDecision::Accepted { .. } => panic!("budget ignored"),
        }
    }
}
