//! Replicated control plane: a sans-IO multipaxos (raft-flavored) log for
//! catalog commands.
//!
//! The single-process control plane (`rust/src/control/`) adapts the data
//! plane to churn, but its durability story was checkpoint files: a crash
//! mid-churn loses every epoch since the last snapshot. This module makes
//! the orchestration layer itself replicated — every catalog command
//! (register / update / drain / remove, scripted topology events, snapshot
//! barriers) flows through a majority-committed log *before* it is applied,
//! so killing the leader loses no committed epoch and a follower resumes
//! serving from replicated state.
//!
//! The design follows the deterministic actor runtime
//! (`rust/src/distributed/`): each [`Replica`] is a pure state machine over
//! virtual ticks with an inbox (`recv`) and an outbox — no wall clock, no
//! sockets, no threads. Ballots are raft terms, phase-1 prepare is the vote
//! round, phase-2 accept is the append round; election timeouts are
//! randomized but drawn from a seeded [`crate::util::rng::Rng`], so a whole
//! failover is a deterministic function of `(seed, fault spec)`. The
//! simulated message fabric ([`fabric::SimFabric`]) applies the *same*
//! declarative [`crate::distributed::FaultSpec`] fault model as
//! `SimNetTransport` — partition check, then drop, then duplication, then
//! per-copy delay jitter, delivery ordered by `(sent_at, from, seq)` — so
//! the clean / lossy / partition presets drive replication unmodified.
//!
//! Three layers:
//!
//! * [`replica`] — the sans-IO consensus state machine ([`Replica`],
//!   [`ReplMsg`], [`ReplicaConfig`]);
//! * [`fabric`] — the deterministic simulated network + [`ReplGroup`]
//!   harness (elect, propose, kill, step) used by the `ha` scenario tier,
//!   `rust/tests/repl_chaos.rs` and the linearization property test;
//! * [`live`] — [`LiveReplica`], a thin synchronous driver that carries
//!   [`ReplMsg`]s over the ops HTTP surface (`POST /raftish/msg`) for the
//!   real 3-process loopback deployment exercised by CI.
//!
//! Committed commands are applied through one shared, *tolerant* dispatch
//! ([`apply_to_catalog`] at the catalog level,
//! [`crate::control::ControlPlane::apply_committed`] for a full plane):
//! registering an existing id degrades to an update, draining or removing a
//! missing id is a no-op. Tolerance matters because a client may re-propose
//! a command after a failover it cannot distinguish from a lost request;
//! the committed log then holds the command twice and every replica must
//! converge to the same state anyway.
//!
//! Snapshot v3 (`control/snapshot.rs`) carries the replica's persistent
//! state — term, vote, commit index and the log tail — next to the plane
//! snapshot, under per-replica subdirectories so co-located replicas never
//! clobber each other's checkpoints. Format and failover semantics:
//! `docs/CONTROL_PLANE.md`.

pub mod fabric;
pub mod live;
pub mod replica;

pub use fabric::{FabricStats, ReplGroup, SimFabric};
pub use live::LiveReplica;
pub use replica::{ReplMsg, Replica, ReplicaConfig, Role};

use crate::control::catalog::{AppCatalog, AppSpec};
use crate::topo::TopoEvent;
use crate::util::json::Json;

/// One command in the replicated catalog log. Everything that bumps the
/// control-plane epoch is representable, so the log is a complete churn
/// history.
#[derive(Clone, Debug, PartialEq)]
pub enum ReplCommand {
    /// Register a new application (degrades to update if the id exists).
    Register(AppSpec),
    /// Update a registered application (degrades to register if missing).
    Update(AppSpec),
    /// Stop an app's traffic, keeping its φ rows to drain in-flight work.
    Drain(String),
    /// Remove an app entirely.
    Remove(String),
    /// A scripted topology event (link flap / region outage).
    Topo(TopoEvent),
    /// A snapshot barrier: no state change, but its commit index marks a
    /// consistent point every replica may checkpoint at.
    SnapshotBarrier,
}

impl ReplCommand {
    /// Stable operation tag (wire format, digests, reports).
    pub fn op(&self) -> &'static str {
        match self {
            ReplCommand::Register(_) => "register",
            ReplCommand::Update(_) => "update",
            ReplCommand::Drain(_) => "drain",
            ReplCommand::Remove(_) => "remove",
            ReplCommand::Topo(_) => "topo",
            ReplCommand::SnapshotBarrier => "barrier",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("op", Json::Str(self.op().to_string()))];
        match self {
            ReplCommand::Register(spec) | ReplCommand::Update(spec) => {
                pairs.push(("spec", spec.to_json()));
            }
            ReplCommand::Drain(id) | ReplCommand::Remove(id) => {
                pairs.push(("id", Json::Str(id.clone())));
            }
            ReplCommand::Topo(event) => pairs.push(("event", event.to_json())),
            ReplCommand::SnapshotBarrier => {}
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<ReplCommand> {
        let op = v
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("command has no 'op'"))?;
        let spec = |v: &Json| -> anyhow::Result<AppSpec> {
            AppSpec::from_json(
                v.get("spec")
                    .ok_or_else(|| anyhow::anyhow!("'{op}' command has no 'spec'"))?,
            )
        };
        let id = |v: &Json| -> anyhow::Result<String> {
            Ok(v.get("id")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("'{op}' command has no 'id'"))?
                .to_string())
        };
        Ok(match op {
            "register" => ReplCommand::Register(spec(v)?),
            "update" => ReplCommand::Update(spec(v)?),
            "drain" => ReplCommand::Drain(id(v)?),
            "remove" => ReplCommand::Remove(id(v)?),
            "topo" => ReplCommand::Topo(TopoEvent::from_json(
                v.get("event")
                    .ok_or_else(|| anyhow::anyhow!("'topo' command has no 'event'"))?,
            )?),
            "barrier" => ReplCommand::SnapshotBarrier,
            other => anyhow::bail!("unknown command op '{other}'"),
        })
    }
}

/// One entry in the replicated log: the ballot (term) it was accepted
/// under, plus the command.
#[derive(Clone, Debug, PartialEq)]
pub struct LogEntry {
    pub term: u64,
    pub cmd: ReplCommand,
}

impl LogEntry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("term", Json::from_u64(self.term)),
            ("cmd", self.cmd.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<LogEntry> {
        Ok(LogEntry {
            term: v
                .get("term")
                .and_then(Json::as_u64_lossless)
                .ok_or_else(|| anyhow::anyhow!("log entry has no 'term'"))?,
            cmd: ReplCommand::from_json(
                v.get("cmd")
                    .ok_or_else(|| anyhow::anyhow!("log entry has no 'cmd'"))?,
            )?,
        })
    }
}

/// Apply one committed command to a bare [`AppCatalog`], tolerantly: a
/// register of an existing id becomes an update, an update of a missing id
/// becomes a register, drain/remove of a missing id is a no-op, and
/// topology events / barriers don't touch the catalog. This is the single
/// place catalog-level apply semantics live — the linearization property
/// test replays the committed order through it and compares against live
/// replicas, so any divergence between replicas is a test failure, not a
/// silent fork.
pub fn apply_to_catalog(cat: &mut AppCatalog, cmd: &ReplCommand) -> anyhow::Result<()> {
    match cmd {
        ReplCommand::Register(spec) | ReplCommand::Update(spec) => {
            if cat.get(&spec.id).is_some() {
                cat.update(spec.clone())
            } else {
                cat.register(spec.clone())
            }
        }
        ReplCommand::Drain(id) => {
            if cat.get(id).is_some() {
                cat.drain(id)
            } else {
                Ok(())
            }
        }
        ReplCommand::Remove(id) => {
            if cat.get(id).is_some() {
                cat.remove(id)
            } else {
                Ok(())
            }
        }
        ReplCommand::Topo(_) | ReplCommand::SnapshotBarrier => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::catalog::AppStatus;

    fn app(id: &str) -> AppSpec {
        AppSpec {
            id: id.to_string(),
            dest: 1,
            num_tasks: 2,
            packet_sizes: vec![10.0, 5.0, 1.0],
            rates: vec![(0, 0.3)],
            status: AppStatus::Active,
        }
    }

    #[test]
    fn commands_round_trip_json() {
        let cmds = vec![
            ReplCommand::Register(app("a")),
            ReplCommand::Update(app("a")),
            ReplCommand::Drain("a".to_string()),
            ReplCommand::Remove("a".to_string()),
            ReplCommand::SnapshotBarrier,
        ];
        for cmd in cmds {
            let text = cmd.to_json().to_string_pretty();
            let back = ReplCommand::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, cmd);
        }
        let entry = LogEntry {
            term: 3,
            cmd: ReplCommand::Drain("x".to_string()),
        };
        let back =
            LogEntry::from_json(&Json::parse(&entry.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, entry);
        assert!(ReplCommand::from_json(&Json::parse(r#"{"op": "warp"}"#).unwrap()).is_err());
    }

    #[test]
    fn tolerant_apply_converges_on_duplicates() {
        let mut a = AppCatalog::new();
        let mut b = AppCatalog::new();
        // b sees the register twice (client retry after failover)
        let cmds_a = [
            ReplCommand::Register(app("a")),
            ReplCommand::Drain("a".to_string()),
        ];
        let cmds_b = [
            ReplCommand::Register(app("a")),
            ReplCommand::Register(app("a")),
            ReplCommand::Drain("a".to_string()),
            ReplCommand::Drain("a".to_string()),
            ReplCommand::Remove("ghost".to_string()),
        ];
        for c in &cmds_a {
            apply_to_catalog(&mut a, c).unwrap();
        }
        for c in &cmds_b {
            apply_to_catalog(&mut b, c).unwrap();
        }
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}
