//! Deterministic simulated message fabric + the [`ReplGroup`] harness.
//!
//! [`SimFabric`] re-implements the exact fault pipeline of
//! [`crate::distributed::SimNetTransport`] for [`ReplMsg`] traffic —
//! partition check, then drop, then duplication, then per-copy delay
//! jitter, with per-sender fault RNGs forked from the spec seed and
//! delivery ordered by `(sent_at, from, seq)` — so the declarative
//! [`FaultSpec`] presets (clean / lossy / partition) drive consensus
//! unmodified and a run is a pure function of `(seed, spec)`.
//!
//! [`ReplGroup`] steps a whole replica set through the fabric in virtual
//! time: one [`ReplGroup::step`] delivers due messages, ticks every live
//! replica, and drains outboxes back into the fabric, all in replica-id
//! order. `kill` silences a replica (its queued traffic is discarded at
//! delivery time), which is how the chaos and `ha` layers script leader
//! failures.

use crate::distributed::FaultSpec;
use crate::util::rng::Rng;

use super::replica::{ReplMsg, Replica, ReplicaConfig};
use super::ReplCommand;

/// Fabric-level delivery accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct FabricStats {
    pub sent: u64,
    pub delivered: u64,
    pub dropped_fault: u64,
    pub dropped_partition: u64,
    pub dropped_dead: u64,
    pub duplicated: u64,
}

struct Pending {
    deliver_at: u64,
    sent_at: u64,
    from: usize,
    seq: u64,
    to: usize,
    msg: ReplMsg,
}

/// The simulated network between replicas.
pub struct SimFabric {
    spec: FaultSpec,
    n: usize,
    rngs: Vec<Rng>,
    seqs: Vec<u64>,
    queue: Vec<Pending>,
    pub stats: FabricStats,
}

impl SimFabric {
    pub fn new(n: usize, spec: FaultSpec) -> SimFabric {
        // per-sender fault RNGs, same fork scheme as SimNetTransport
        let rngs = (0..n)
            .map(|i| Rng::new(spec.seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15)))
            .collect();
        SimFabric {
            spec,
            n,
            rngs,
            seqs: vec![0; n],
            queue: Vec::new(),
            stats: FabricStats::default(),
        }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Submit one message at virtual time `now`, applying the fault
    /// pipeline in the transport's order: partition, drop, duplication,
    /// per-copy delay.
    pub fn send(&mut self, now: u64, from: usize, to: usize, msg: ReplMsg) {
        self.stats.sent += 1;
        if self
            .spec
            .partitions
            .iter()
            .any(|p| p.cuts(now, from, to, self.n))
        {
            self.stats.dropped_partition += 1;
            return;
        }
        let rng = &mut self.rngs[from];
        if rng.bool(self.spec.drop) {
            self.stats.dropped_fault += 1;
            return;
        }
        let copies = if rng.bool(self.spec.dup) {
            self.stats.duplicated += 1;
            2
        } else {
            1
        };
        for _ in 0..copies {
            let delay = if self.spec.max_delay > self.spec.min_delay {
                self.spec.min_delay
                    + self.rngs[from]
                        .usize((self.spec.max_delay - self.spec.min_delay + 1) as usize)
                        as u64
            } else {
                self.spec.min_delay
            };
            let seq = self.seqs[from];
            self.seqs[from] += 1;
            self.queue.push(Pending {
                deliver_at: now + delay.max(1),
                sent_at: now,
                from,
                seq,
                to,
                msg: msg.clone(),
            });
        }
    }

    /// Every message due for `to` at `now`, ordered by
    /// `(sent_at, from, seq)` — deterministic for any queue insertion
    /// order.
    pub fn take_due(&mut self, now: u64, to: usize) -> Vec<ReplMsg> {
        let mut due: Vec<Pending> = Vec::new();
        let mut rest: Vec<Pending> = Vec::with_capacity(self.queue.len());
        for p in self.queue.drain(..) {
            if p.to == to && p.deliver_at <= now {
                due.push(p);
            } else {
                rest.push(p);
            }
        }
        self.queue = rest;
        due.sort_by_key(|p| (p.sent_at, p.from, p.seq));
        self.stats.delivered += due.len() as u64;
        due.into_iter().map(|p| p.msg).collect()
    }

    /// Discard everything queued for `to` (the replica died).
    fn discard_for(&mut self, to: usize) {
        let before = self.queue.len();
        self.queue.retain(|p| p.to != to);
        self.stats.dropped_dead += (before - self.queue.len()) as u64;
    }
}

/// A replica set on a [`SimFabric`]: the test/scenario harness for
/// elections, replication and scripted failovers in virtual time.
pub struct ReplGroup {
    pub replicas: Vec<Replica>,
    pub alive: Vec<bool>,
    fabric: SimFabric,
    now: u64,
}

impl ReplGroup {
    /// Build `n` replicas wired through `faults`. The consensus timeout
    /// RNGs take the replication seed; the fabric's fault RNGs take the
    /// spec's own seed, exactly as the distributed runtime does.
    pub fn new(n: usize, seed: u64, faults: FaultSpec) -> ReplGroup {
        let replicas = (0..n)
            .map(|id| Replica::new(ReplicaConfig::new(id, n, seed)))
            .collect();
        ReplGroup {
            replicas,
            alive: vec![true; n],
            fabric: SimFabric::new(n, faults),
            now: 0,
        }
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    pub fn stats(&self) -> FabricStats {
        self.fabric.stats
    }

    pub fn spec(&self) -> &FaultSpec {
        self.fabric.spec()
    }

    /// Advance one virtual tick: deliver due messages and tick every live
    /// replica (in id order), then drain outboxes into the fabric (in id
    /// order). Dead replicas neither receive nor send.
    pub fn step(&mut self) {
        self.now += 1;
        for id in 0..self.replicas.len() {
            if !self.alive[id] {
                self.fabric.discard_for(id);
                continue;
            }
            for msg in self.fabric.take_due(self.now, id) {
                self.replicas[id].recv(self.now, msg);
            }
            self.replicas[id].tick(self.now);
        }
        for id in 0..self.replicas.len() {
            if !self.alive[id] {
                self.replicas[id].take_outbox();
                continue;
            }
            for (to, msg) in self.replicas[id].take_outbox() {
                self.fabric.send(self.now, id, to, msg);
            }
        }
    }

    /// Silence a replica: it stops ticking, sending and receiving. Queued
    /// traffic to it is discarded.
    pub fn kill(&mut self, id: usize) {
        self.alive[id] = false;
        self.fabric.discard_for(id);
    }

    /// The live leader, if any: highest term wins (stale leaders on the
    /// minority side of a partition still report `Leader` until they hear
    /// the new term), ties broken by lowest id.
    pub fn leader(&self) -> Option<usize> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(id, r)| self.alive[*id] && r.is_leader())
            .max_by_key(|(id, r)| (r.term(), std::cmp::Reverse(*id)))
            .map(|(id, _)| id)
    }

    /// Step until a live leader emerges; returns the ticks taken, or
    /// `None` after `max_ticks`.
    pub fn run_until_leader(&mut self, max_ticks: u64) -> Option<u64> {
        let start = self.now;
        while self.leader().is_none() {
            if self.now - start >= max_ticks {
                return None;
            }
            self.step();
        }
        Some(self.now - start)
    }

    /// Propose on the current leader; returns `(leader, index)` when a
    /// live leader accepted it.
    pub fn propose(&mut self, cmd: ReplCommand) -> Option<(usize, u64)> {
        let leader = self.leader()?;
        let index = self.replicas[leader].propose(cmd)?;
        Some((leader, index))
    }

    /// Step until every live replica has committed (not merely received)
    /// `index`, or `max_ticks` elapse. Returns the ticks taken.
    pub fn run_until_committed(&mut self, index: u64, max_ticks: u64) -> Option<u64> {
        let start = self.now;
        loop {
            let all = self
                .replicas
                .iter()
                .enumerate()
                .filter(|(id, _)| self.alive[*id])
                .all(|(_, r)| r.commit_index() >= index);
            if all {
                return Some(self.now - start);
            }
            if self.now - start >= max_ticks {
                return None;
            }
            self.step();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(id: &str) -> ReplCommand {
        ReplCommand::Drain(id.to_string())
    }

    #[test]
    fn clean_group_elects_and_replicates() {
        let mut g = ReplGroup::new(3, 42, FaultSpec::clean(42));
        let ticks = g.run_until_leader(500).expect("clean election stalls");
        assert!(ticks > 0);
        let (_, idx) = g.propose(drain("a")).unwrap();
        g.run_until_committed(idx, 200).expect("commit stalls");
        for r in g.replicas.iter_mut() {
            assert_eq!(r.take_committed(), vec![(1, drain("a"))]);
        }
    }

    #[test]
    fn lossy_group_still_commits() {
        let mut g = ReplGroup::new(3, 7, FaultSpec::lossy(7));
        g.run_until_leader(2000).expect("lossy election stalls");
        let (_, idx) = g.propose(drain("a")).unwrap();
        g.run_until_committed(idx, 2000).expect("lossy commit stalls");
        assert!(g.stats().dropped_fault > 0, "lossy spec never dropped");
    }

    #[test]
    fn leader_kill_loses_no_committed_entry() {
        let mut g = ReplGroup::new(3, 9, FaultSpec::clean(9));
        g.run_until_leader(500).unwrap();
        for name in ["a", "b"] {
            let (_, idx) = g.propose(drain(name)).unwrap();
            g.run_until_committed(idx, 200).unwrap();
        }
        let old = g.leader().unwrap();
        g.kill(old);
        g.run_until_leader(2000).expect("failover stalls");
        let new = g.leader().unwrap();
        assert_ne!(new, old);
        assert_eq!(g.replicas[new].commit_index(), 2);
        assert_eq!(g.replicas[new].log_entry(1).unwrap().cmd, drain("a"));
        assert_eq!(g.replicas[new].log_entry(2).unwrap().cmd, drain("b"));
    }

    #[test]
    fn runs_are_bit_identical_per_seed_and_spec() {
        let transcript = |seed: u64| -> String {
            let mut g = ReplGroup::new(3, seed, FaultSpec::lossy(seed));
            g.run_until_leader(2000).unwrap();
            let (_, idx) = g.propose(drain("a")).unwrap();
            g.run_until_committed(idx, 2000).unwrap();
            let s = g.stats();
            format!(
                "now={} leader={:?} sent={} delivered={} dropped={} dup={}",
                g.now(),
                g.leader(),
                s.sent,
                s.delivered,
                s.dropped_fault,
                s.duplicated
            )
        };
        assert_eq!(transcript(3), transcript(3));
        assert_ne!(transcript(3), transcript(4), "seed must matter");
    }

    #[test]
    fn partition_heals_and_group_recovers() {
        let mut g = ReplGroup::new(3, 5, FaultSpec::partition(5));
        // the scripted window cuts {0} from {1, 2} during ticks 40..160;
        // a majority always exists, so a leader emerges well before heal
        g.run_until_leader(2000).expect("partitioned election stalls");
        g.propose(drain("a")).unwrap();
        let heal = g.spec().last_partition_end();
        // client-style retry: if a leadership change orphaned the
        // proposal, re-propose on the current leader
        while g.now() < heal + 400 {
            g.step();
            if let Some(l) = g.leader() {
                if g.replicas[l].log_len() == 0 {
                    g.propose(drain("a")).unwrap();
                }
            }
        }
        for (id, r) in g.replicas.iter().enumerate() {
            assert!(
                r.commit_index() >= 1,
                "replica {id} never caught up after heal"
            );
        }
    }
}
