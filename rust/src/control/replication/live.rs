//! The live replication driver: [`ReplMsg`]s over the ops HTTP surface.
//!
//! [`LiveReplica`] wraps the sans-IO [`Replica`] for a real multi-process
//! deployment: every replica runs `scfo serve --replica I --peers A,B,C`,
//! consensus messages travel as JSON over `POST /raftish/msg` on the same
//! [`crate::control::http::OpsServer`] that serves the ops API, and the
//! leader replicates synchronously inside the `POST /apps` handler
//! ([`LiveReplica::replicate`]): propose, push appends to every peer,
//! feed their acks back into the state machine, and return once the
//! command's index commits (majority) — so an HTTP 200 means the epoch
//! survives any single-replica crash.
//!
//! Live deployments bootstrap replica 0 as the leader
//! ([`Replica::bootstrap_leader`]) instead of running timeout-driven
//! elections — the loopback drivers have no background ticker, and the
//! election/failover machinery is exercised exhaustively (and
//! deterministically) by the simulated layer (`fabric`, the `ha` tier,
//! `rust/tests/repl_chaos.rs`). After a leader crash, followers keep
//! serving reads (`GET /status`) from replicated state; CI's control-smoke
//! job pins exactly that.
//!
//! A *restarted* replica does not start from scratch: the serve path
//! loads the persistent consensus state (term, vote, commit, log tail)
//! from its snapshot-v3 checkpoint ([`LiveReplica::load_persistent`]) and
//! replica 0 then re-asserts leadership via [`LiveReplica::rebootstrap`],
//! which re-leads in a term strictly above the restored one — so its
//! appends truncate any suffix a follower accepted under the old term
//! rather than silently coexisting with it. Entries committed after the
//! last checkpoint are the restart's durability horizon: checkpoint
//! often (`--checkpoint-every`, `POST /checkpoint`) in replicated
//! deployments.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::util::json::Json;

use super::replica::{ReplMsg, Replica, ReplicaConfig};
use super::ReplCommand;

/// Per-request socket timeout for peer calls; a dead peer costs at most
/// this per round.
const PEER_TIMEOUT: Duration = Duration::from_millis(500);

/// Replication rounds before a propose is declared quorum-less.
const MAX_ROUNDS: usize = 10;

/// A replica embedded in a serving process, with its peers' ops
/// addresses.
pub struct LiveReplica {
    replica: Replica,
    /// Ops address per replica id (`peers[self.id()]` is this process).
    peers: Vec<String>,
    now: u64,
    /// Outbound messages [`LiveReplica::handle_msg`] produced that were
    /// not the direct reply to the sender (e.g. the append fan-out of a
    /// leadership change). Delivered on the next [`LiveReplica::replicate`]
    /// round instead of being dropped.
    pending: Vec<(usize, ReplMsg)>,
}

impl LiveReplica {
    /// `peers` lists every replica's ops address in id order; `id` is this
    /// process's slot. Replica 0 bootstraps as leader.
    pub fn new(id: usize, peers: Vec<String>, seed: u64) -> anyhow::Result<LiveReplica> {
        anyhow::ensure!(
            id < peers.len(),
            "replica id {id} out of range for {} peers",
            peers.len()
        );
        anyhow::ensure!(peers.len() >= 2, "a replica group needs >= 2 peers");
        let mut replica = Replica::new(ReplicaConfig::new(id, peers.len(), seed));
        if id == 0 {
            replica.bootstrap_leader();
            // leadership is asserted lazily on the first replicate — peers
            // may not be listening yet at construction time
            replica.take_outbox();
        }
        Ok(LiveReplica {
            replica,
            peers,
            now: 0,
            pending: Vec::new(),
        })
    }

    pub fn id(&self) -> usize {
        self.replica.id()
    }

    /// Number of replicas in the group (valid sender ids are `0..n`).
    pub fn group_size(&self) -> usize {
        self.peers.len()
    }

    pub fn is_leader(&self) -> bool {
        self.replica.is_leader()
    }

    /// Current term / commit index, for the obs gauges.
    pub fn term(&self) -> u64 {
        self.replica.term()
    }

    pub fn commit_index(&self) -> u64 {
        self.replica.commit_index()
    }

    /// The believed leader's ops address (redirect target for followers).
    pub fn leader_addr(&self) -> Option<&str> {
        self.replica
            .leader_hint()
            .and_then(|l| self.peers.get(l))
            .map(String::as_str)
    }

    /// `GET /raftish` document: replica status plus the peer table.
    pub fn status_json(&self) -> Json {
        let mut doc = match self.replica.status_json() {
            Json::Obj(o) => o,
            _ => unreachable!("replica status serializes to an object"),
        };
        doc.insert(
            "peers".into(),
            Json::Arr(self.peers.iter().map(|p| Json::Str(p.clone())).collect()),
        );
        doc.insert(
            "leader_addr".into(),
            match self.leader_addr() {
                Some(a) => Json::Str(a.to_string()),
                None => Json::Null,
            },
        );
        Json::Obj(doc)
    }

    /// Handle one inbound consensus message (`POST /raftish/msg`):
    /// returns the reply to send back, plus any commands that just
    /// committed here and must be applied to the local plane.
    pub fn handle_msg(&mut self, msg: ReplMsg) -> (Option<ReplMsg>, Vec<ReplCommand>) {
        self.now += 1;
        let sender = msg.from();
        self.replica.recv(self.now, msg);
        // first message back to the sender rides the HTTP response; any
        // other outbound traffic (a fan-out to third parties) is queued
        // for the next replicate round rather than silently dropped
        let mut reply = None;
        for (to, m) in self.replica.take_outbox() {
            if reply.is_none() && to == sender {
                reply = Some(m);
            } else {
                self.pending.push((to, m));
            }
        }
        let committed = self
            .replica
            .take_committed()
            .into_iter()
            .map(|(_, c)| c)
            .collect();
        (reply, committed)
    }

    /// Commands committed here since the last call (leader side: commits
    /// discovered while replicating a *different* client's command).
    pub fn take_committed(&mut self) -> Vec<ReplCommand> {
        self.replica
            .take_committed()
            .into_iter()
            .map(|(_, c)| c)
            .collect()
    }

    /// Leader-side synchronous replication: propose `cmd`, push appends
    /// to every reachable peer and feed their acks back until the
    /// command's index commits. Returns every newly committed command in
    /// log order (ending with `cmd`); errors when no quorum acknowledges
    /// within [`MAX_ROUNDS`].
    pub fn replicate(&mut self, cmd: ReplCommand) -> anyhow::Result<Vec<ReplCommand>> {
        let index = self
            .replica
            .propose(cmd)
            .ok_or_else(|| anyhow::anyhow!("not the leader"))?;
        for _round in 0..MAX_ROUNDS {
            let mut outbound = std::mem::take(&mut self.pending);
            outbound.extend(self.replica.take_outbox());
            for (to, msg) in outbound {
                let addr = self.peers[to].clone();
                match self.exchange(&addr, &msg) {
                    Ok(Some(reply)) => {
                        self.now += 1;
                        let now = self.now;
                        self.replica.recv(now, reply);
                    }
                    Ok(None) => {}
                    Err(e) => {
                        crate::log_warn!("replication peer {addr} unreachable: {e}");
                    }
                }
            }
            if self.replica.commit_index() >= index {
                return Ok(self.take_committed());
            }
            // retrigger appends (heartbeat) for the next round
            self.now += self.replica.config().heartbeat_every;
            let now = self.now;
            self.replica.tick(now);
        }
        anyhow::bail!(
            "no quorum: entry {index} not committed after {MAX_ROUNDS} rounds \
             (term {}, commit {})",
            self.replica.term(),
            self.replica.commit_index()
        )
    }

    /// POST one consensus message to a peer and parse the reply (if the
    /// peer returned one).
    fn exchange(&self, addr: &str, msg: &ReplMsg) -> anyhow::Result<Option<ReplMsg>> {
        let body = post_json(addr, "/raftish/msg", &msg.to_json().to_string())?;
        let v = Json::parse(&body).map_err(|e| anyhow::anyhow!("bad peer reply: {e}"))?;
        if v == Json::Null {
            return Ok(None);
        }
        Ok(Some(ReplMsg::from_json(&v)?))
    }

    /// Persistent consensus state for snapshot v3.
    pub fn persistent_json(&self) -> Json {
        self.replica.persistent_json()
    }

    /// Restore persistent consensus state (resumes as follower; replica 0
    /// re-bootstraps leadership via [`LiveReplica::rebootstrap`] once its
    /// log is loaded).
    pub fn load_persistent(&mut self, v: &Json) -> anyhow::Result<()> {
        self.replica.load_persistent(v)
    }

    /// Re-assert bootstrap leadership after a restore (replica 0 only by
    /// convention). Leads in a term strictly above the restored one (see
    /// [`Replica::bootstrap_leader`]), so stale same-term suffixes on
    /// followers are truncated by the first append instead of silently
    /// diverging. Leadership is asserted lazily — peers may not be
    /// listening yet, so the bootstrap fan-out is discarded like
    /// [`LiveReplica::new`]'s.
    pub fn rebootstrap(&mut self) {
        self.replica.bootstrap_leader();
        self.replica.take_outbox();
    }
}

/// Minimal blocking HTTP/1.1 POST returning the response body. Std-only,
/// mirror image of the ops server's reader.
pub fn post_json(addr: &str, path: &str, body: &str) -> anyhow::Result<String> {
    let sock = addr
        .to_socket_addrs()
        .map_err(|e| anyhow::anyhow!("bad peer address '{addr}': {e}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("peer address '{addr}' resolves to nothing"))?;
    let mut stream = TcpStream::connect_timeout(&sock, PEER_TIMEOUT)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    stream.set_read_timeout(Some(PEER_TIMEOUT))?;
    stream.set_write_timeout(Some(PEER_TIMEOUT))?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut response = Vec::new();
    stream.read_to_end(&mut response)?;
    let text = String::from_utf8(response).map_err(|_| anyhow::anyhow!("non-UTF8 response"))?;
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow::anyhow!("malformed HTTP response from {addr}"))?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("malformed status line from {addr}"))?;
    anyhow::ensure!(status == 200, "peer {addr} returned {status}: {body}");
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_zero_bootstraps_leader() {
        let peers = vec!["127.0.0.1:1".to_string(), "127.0.0.1:2".to_string()];
        let r0 = LiveReplica::new(0, peers.clone(), 7).unwrap();
        assert!(r0.is_leader());
        assert_eq!(r0.term(), 1);
        let r1 = LiveReplica::new(1, peers, 7).unwrap();
        assert!(!r1.is_leader());
        assert_eq!(r1.leader_addr(), None, "follower learns the leader from appends");
        assert!(LiveReplica::new(5, vec!["a".into()], 7).is_err());
    }

    #[test]
    fn append_teaches_follower_the_leader_and_commits() {
        let peers = vec![
            "127.0.0.1:1".to_string(),
            "127.0.0.1:2".to_string(),
            "127.0.0.1:3".to_string(),
        ];
        let mut leader = LiveReplica::new(0, peers.clone(), 7).unwrap();
        let mut follower = LiveReplica::new(1, peers, 7).unwrap();
        // hand-carry the append instead of going through sockets
        let _ = leader.replica.propose(ReplCommand::SnapshotBarrier).unwrap();
        let outbound = leader.replica.take_outbox();
        let (_, append) = outbound
            .iter()
            .find(|(to, _)| *to == 1)
            .cloned()
            .expect("append addressed to follower 1");
        let (reply, committed) = follower.handle_msg(append);
        assert!(committed.is_empty(), "commit needs the leader's ack round");
        assert_eq!(follower.leader_addr(), Some("127.0.0.1:1"));
        let ack = reply.expect("follower acks the append");
        let now = leader.now + 1;
        leader.now = now;
        leader.replica.recv(now, ack);
        assert_eq!(leader.commit_index(), 1, "one ack + self is a majority of 3");
        assert_eq!(leader.take_committed(), vec![ReplCommand::SnapshotBarrier]);
    }

    /// A recv that fans out beyond the direct reply (here: a granted vote
    /// turning the replica into a leader, which pushes appends to every
    /// peer) must queue the extra messages for the next replicate round,
    /// not drop them.
    #[test]
    fn handle_msg_queues_non_reply_fanout() {
        let peers = vec![
            "127.0.0.1:1".to_string(),
            "127.0.0.1:2".to_string(),
            "127.0.0.1:3".to_string(),
        ];
        let mut r1 = LiveReplica::new(1, peers, 7).unwrap();
        // force an election so a vote can arrive (live mode never does
        // this on its own; the scenario is the future-proofing target)
        r1.replica.tick(100);
        assert_eq!(r1.replica.role(), super::super::Role::Candidate);
        r1.replica.take_outbox(); // discard the vote requests
        let (reply, committed) = r1.handle_msg(ReplMsg::Vote {
            term: r1.term(),
            from: 0,
            granted: true,
        });
        assert!(r1.is_leader(), "majority of 3 is the candidate plus one vote");
        assert!(committed.is_empty());
        // the append to the voter rides the reply; the append to peer 2
        // waits in the pending queue instead of vanishing
        assert!(matches!(reply, Some(ReplMsg::Append { .. })));
        assert_eq!(r1.pending.len(), 1);
        assert_eq!(r1.pending[0].0, 2);
        assert!(matches!(r1.pending[0].1, ReplMsg::Append { .. }));
    }

    #[test]
    fn rebootstrap_after_restore_leads_in_a_fresh_term() {
        let peers = vec![
            "127.0.0.1:1".to_string(),
            "127.0.0.1:2".to_string(),
            "127.0.0.1:3".to_string(),
        ];
        let mut r0 = LiveReplica::new(0, peers.clone(), 7).unwrap();
        let _ = r0.replica.propose(ReplCommand::SnapshotBarrier).unwrap();
        let state = r0.persistent_json();
        let mut restarted = LiveReplica::new(0, peers, 7).unwrap();
        restarted
            .load_persistent(&Json::parse(&state.to_string()).unwrap())
            .unwrap();
        restarted.rebootstrap();
        assert!(restarted.is_leader());
        assert_eq!(restarted.term(), 2, "restart must not reuse the old term");
        // the restored entry survives, plus the new-term barrier that
        // will carry the restored-but-uncommitted tail to commit
        assert_eq!(restarted.replica.log_len(), 2);
        assert_eq!(restarted.replica.log_entry(2).unwrap().term, 2);
    }

    #[test]
    fn status_json_carries_peer_table() {
        let peers = vec!["a:1".to_string(), "b:2".to_string(), "c:3".to_string()];
        let s = LiveReplica::new(0, peers, 7).unwrap().status_json();
        assert_eq!(s.get("role").and_then(Json::as_str), Some("leader"));
        assert_eq!(s.get("peers").and_then(Json::as_arr).map(|a| a.len()), Some(3));
        assert_eq!(s.get("leader_addr").and_then(Json::as_str), Some("a:1"));
    }
}
