//! The sans-IO consensus replica: a pure state machine over virtual ticks.
//!
//! Multipaxos in its raft-shaped presentation: a term is a ballot, the
//! vote round is phase-1 prepare (the new leader's log is at least as
//! up-to-date as any majority member's, so every committed entry survives),
//! the append round is phase-2 accept, and the commit index advances once a
//! majority has accepted an entry *from the current term*. Election
//! timeouts are randomized to break ties but drawn from a seeded
//! [`Rng`] forked per replica id, so elections — including split votes and
//! re-elections under partitions — replay bit-identically for a given
//! `(seed, fault spec)`.
//!
//! The replica never touches a clock or a socket: [`Replica::tick`]
//! advances virtual time, [`Replica::recv`] consumes one inbound message,
//! and everything outbound accumulates in the outbox until the driver
//! (simulated [`super::fabric::SimFabric`] or live HTTP
//! [`super::live::LiveReplica`]) drains it with [`Replica::take_outbox`].
//! Committed-but-unapplied commands surface through
//! [`Replica::take_committed`] in log order — exactly once per replica.

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{LogEntry, ReplCommand};

/// Consensus role.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Follower,
    Candidate,
    Leader,
}

impl Role {
    pub fn name(&self) -> &'static str {
        match self {
            Role::Follower => "follower",
            Role::Candidate => "candidate",
            Role::Leader => "leader",
        }
    }
}

/// Static replica configuration. Timeouts are in virtual ticks; the
/// defaults (election 10–20, heartbeat every 3) keep elections an order of
/// magnitude slower than heartbeats so a live leader is never deposed by
/// jitter alone, while the fault presets' delay ranges (1–4 ticks) still
/// fit several retries inside one election window.
#[derive(Clone, Debug)]
pub struct ReplicaConfig {
    /// This replica's id in `0..n`.
    pub id: usize,
    /// Group size (3 or 5 in every shipped configuration).
    pub n: usize,
    /// Seeds the election-timeout RNG (forked per id, same scheme as the
    /// transport's per-sender fault RNGs).
    pub seed: u64,
    /// Minimum election timeout in ticks.
    pub election_min: u64,
    /// Maximum election timeout in ticks (inclusive).
    pub election_max: u64,
    /// Leader heartbeat period in ticks.
    pub heartbeat_every: u64,
}

impl ReplicaConfig {
    pub fn new(id: usize, n: usize, seed: u64) -> ReplicaConfig {
        ReplicaConfig {
            id,
            n,
            seed,
            election_min: 10,
            election_max: 20,
            heartbeat_every: 3,
        }
    }
}

/// A consensus message. `from` is always the sender's replica id; the
/// fabric routes on an explicit `(to, msg)` pair, so the message itself
/// never names its destination.
#[derive(Clone, Debug, PartialEq)]
pub enum ReplMsg {
    /// Phase-1 prepare: a candidate asks for a vote in `term`.
    RequestVote {
        term: u64,
        from: usize,
        last_log_index: u64,
        last_log_term: u64,
    },
    /// Phase-1 promise (or refusal).
    Vote { term: u64, from: usize, granted: bool },
    /// Phase-2 accept: log entries after (`prev_index`, `prev_term`), plus
    /// the leader's commit index. Empty `entries` is a heartbeat.
    Append {
        term: u64,
        from: usize,
        prev_index: u64,
        prev_term: u64,
        entries: Vec<LogEntry>,
        leader_commit: u64,
    },
    /// Phase-2 accepted/rejected; `match_index` is the highest log index
    /// known replicated on the sender when `ok`.
    AppendAck {
        term: u64,
        from: usize,
        ok: bool,
        match_index: u64,
    },
}

impl ReplMsg {
    /// The message's term (every variant carries one).
    pub fn term(&self) -> u64 {
        match self {
            ReplMsg::RequestVote { term, .. }
            | ReplMsg::Vote { term, .. }
            | ReplMsg::Append { term, .. }
            | ReplMsg::AppendAck { term, .. } => *term,
        }
    }

    /// The sender's replica id.
    pub fn from(&self) -> usize {
        match self {
            ReplMsg::RequestVote { from, .. }
            | ReplMsg::Vote { from, .. }
            | ReplMsg::Append { from, .. }
            | ReplMsg::AppendAck { from, .. } => *from,
        }
    }

    pub fn kind(&self) -> &'static str {
        match self {
            ReplMsg::RequestVote { .. } => "request-vote",
            ReplMsg::Vote { .. } => "vote",
            ReplMsg::Append { .. } => "append",
            ReplMsg::AppendAck { .. } => "append-ack",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ReplMsg::RequestVote {
                term,
                from,
                last_log_index,
                last_log_term,
            } => Json::obj(vec![
                ("kind", Json::Str("request-vote".into())),
                ("term", Json::from_u64(*term)),
                ("from", Json::Num(*from as f64)),
                ("last_log_index", Json::from_u64(*last_log_index)),
                ("last_log_term", Json::from_u64(*last_log_term)),
            ]),
            ReplMsg::Vote { term, from, granted } => Json::obj(vec![
                ("kind", Json::Str("vote".into())),
                ("term", Json::from_u64(*term)),
                ("from", Json::Num(*from as f64)),
                ("granted", Json::Bool(*granted)),
            ]),
            ReplMsg::Append {
                term,
                from,
                prev_index,
                prev_term,
                entries,
                leader_commit,
            } => Json::obj(vec![
                ("kind", Json::Str("append".into())),
                ("term", Json::from_u64(*term)),
                ("from", Json::Num(*from as f64)),
                ("prev_index", Json::from_u64(*prev_index)),
                ("prev_term", Json::from_u64(*prev_term)),
                (
                    "entries",
                    Json::Arr(entries.iter().map(LogEntry::to_json).collect()),
                ),
                ("leader_commit", Json::from_u64(*leader_commit)),
            ]),
            ReplMsg::AppendAck {
                term,
                from,
                ok,
                match_index,
            } => Json::obj(vec![
                ("kind", Json::Str("append-ack".into())),
                ("term", Json::from_u64(*term)),
                ("from", Json::Num(*from as f64)),
                ("ok", Json::Bool(*ok)),
                ("match_index", Json::from_u64(*match_index)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> anyhow::Result<ReplMsg> {
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("message has no 'kind'"))?;
        let u64f = |key: &str| -> anyhow::Result<u64> {
            v.get(key)
                .and_then(Json::as_u64_lossless)
                .ok_or_else(|| anyhow::anyhow!("'{kind}' message has no '{key}'"))
        };
        let term = u64f("term")?;
        let from = u64f("from")? as usize;
        Ok(match kind {
            "request-vote" => ReplMsg::RequestVote {
                term,
                from,
                last_log_index: u64f("last_log_index")?,
                last_log_term: u64f("last_log_term")?,
            },
            "vote" => ReplMsg::Vote {
                term,
                from,
                granted: v
                    .get("granted")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| anyhow::anyhow!("'vote' message has no 'granted'"))?,
            },
            "append" => ReplMsg::Append {
                term,
                from,
                prev_index: u64f("prev_index")?,
                prev_term: u64f("prev_term")?,
                entries: v
                    .get("entries")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("'append' message has no 'entries'"))?
                    .iter()
                    .map(LogEntry::from_json)
                    .collect::<anyhow::Result<Vec<_>>>()?,
                leader_commit: u64f("leader_commit")?,
            },
            "append-ack" => ReplMsg::AppendAck {
                term,
                from,
                ok: v
                    .get("ok")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| anyhow::anyhow!("'append-ack' message has no 'ok'"))?,
                match_index: u64f("match_index")?,
            },
            other => anyhow::bail!("unknown message kind '{other}'"),
        })
    }
}

/// The sans-IO replica. Log indices are 1-based (`log[0]` holds index 1,
/// index 0 means "before the log"); `commit` and `applied` are the highest
/// committed / locally-applied indices.
pub struct Replica {
    cfg: ReplicaConfig,
    role: Role,
    term: u64,
    voted_for: Option<usize>,
    log: Vec<LogEntry>,
    commit: u64,
    applied: u64,
    next_index: Vec<u64>,
    match_index: Vec<u64>,
    votes: Vec<bool>,
    election_deadline: u64,
    heartbeat_due: u64,
    leader_hint: Option<usize>,
    elections_started: u64,
    outbox: Vec<(usize, ReplMsg)>,
    rng: Rng,
}

impl Replica {
    pub fn new(cfg: ReplicaConfig) -> Replica {
        // same per-actor fork scheme as the transport's per-sender fault
        // RNGs, so replica i's timeout stream is independent of n
        let mut rng = Rng::new(cfg.seed ^ (cfg.id as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let n = cfg.n;
        let first_deadline = cfg.election_min
            + rng.usize((cfg.election_max - cfg.election_min + 1) as usize) as u64;
        Replica {
            cfg,
            role: Role::Follower,
            term: 0,
            voted_for: None,
            log: Vec::new(),
            commit: 0,
            applied: 0,
            next_index: vec![1; n],
            match_index: vec![0; n],
            votes: vec![false; n],
            election_deadline: first_deadline,
            heartbeat_due: 0,
            leader_hint: None,
            elections_started: 0,
            outbox: Vec::new(),
            rng,
        }
    }

    pub fn id(&self) -> usize {
        self.cfg.id
    }

    pub fn config(&self) -> &ReplicaConfig {
        &self.cfg
    }

    pub fn role(&self) -> Role {
        self.role
    }

    pub fn is_leader(&self) -> bool {
        self.role == Role::Leader
    }

    pub fn term(&self) -> u64 {
        self.term
    }

    pub fn commit_index(&self) -> u64 {
        self.commit
    }

    pub fn applied_index(&self) -> u64 {
        self.applied
    }

    pub fn log_len(&self) -> u64 {
        self.log.len() as u64
    }

    /// The entry at 1-based `index`, if present.
    pub fn log_entry(&self, index: u64) -> Option<&LogEntry> {
        if index == 0 {
            return None;
        }
        self.log.get(index as usize - 1)
    }

    /// Who this replica believes leads (itself when leader, else the last
    /// leader it heard an append from).
    pub fn leader_hint(&self) -> Option<usize> {
        if self.role == Role::Leader {
            Some(self.cfg.id)
        } else {
            self.leader_hint
        }
    }

    /// Elections this replica has started (re-elections under faults show
    /// up here; reported by the `ha` tier).
    pub fn elections_started(&self) -> u64 {
        self.elections_started
    }

    /// Drain the outbox: `(to, msg)` pairs in send order.
    pub fn take_outbox(&mut self) -> Vec<(usize, ReplMsg)> {
        std::mem::take(&mut self.outbox)
    }

    /// Advance `applied` to `commit`, returning the newly committed
    /// `(index, command)` pairs in log order — each exactly once.
    pub fn take_committed(&mut self) -> Vec<(u64, ReplCommand)> {
        let mut out = Vec::new();
        while self.applied < self.commit {
            self.applied += 1;
            out.push((self.applied, self.log[self.applied as usize - 1].cmd.clone()));
        }
        out
    }

    /// Force this replica to lead without an election. Live deployments
    /// bootstrap replica 0 this way (the loopback drivers run no
    /// background ticker to elect with); the simulated layer never needs
    /// it but tests use it for brevity.
    ///
    /// The bootstrap term is strictly above anything this replica has
    /// seen (`max(term, last log term) + 1`, so term 1 on a fresh
    /// replica). A replica restarted from a persisted snapshot therefore
    /// re-leads in a *new* term: its appends conflict with — and truncate
    /// — any same-index suffix a follower accepted under the old term,
    /// instead of silently coexisting with it at the same term.
    pub fn bootstrap_leader(&mut self) {
        self.term = self.term.max(self.last_log_term()) + 1;
        self.voted_for = Some(self.cfg.id);
        self.become_leader(0);
    }

    // ---- time --------------------------------------------------------------

    /// Advance virtual time: leaders heartbeat, everyone else counts down
    /// to an election.
    pub fn tick(&mut self, now: u64) {
        if self.role == Role::Leader {
            if now >= self.heartbeat_due {
                self.heartbeat_due = now + self.cfg.heartbeat_every;
                for peer in self.peers() {
                    self.send_append(peer);
                }
            }
        } else if now >= self.election_deadline {
            self.start_election(now);
        }
    }

    fn peers(&self) -> Vec<usize> {
        (0..self.cfg.n).filter(|&p| p != self.cfg.id).collect()
    }

    fn reset_election_deadline(&mut self, now: u64) {
        let span = (self.cfg.election_max - self.cfg.election_min + 1) as usize;
        self.election_deadline = now + self.cfg.election_min + self.rng.usize(span) as u64;
    }

    fn last_log_term(&self) -> u64 {
        self.log.last().map(|e| e.term).unwrap_or(0)
    }

    fn start_election(&mut self, now: u64) {
        self.term += 1;
        self.role = Role::Candidate;
        self.voted_for = Some(self.cfg.id);
        self.votes = vec![false; self.cfg.n];
        self.votes[self.cfg.id] = true;
        self.leader_hint = None;
        self.elections_started += 1;
        self.reset_election_deadline(now);
        let msg = ReplMsg::RequestVote {
            term: self.term,
            from: self.cfg.id,
            last_log_index: self.log_len(),
            last_log_term: self.last_log_term(),
        };
        for peer in self.peers() {
            self.outbox.push((peer, msg.clone()));
        }
        // single-replica groups elect themselves instantly
        if self.majority(1) {
            self.become_leader(now);
        }
    }

    fn majority(&self, count: usize) -> bool {
        count >= self.cfg.n / 2 + 1
    }

    fn become_leader(&mut self, now: u64) {
        self.role = Role::Leader;
        self.leader_hint = Some(self.cfg.id);
        let next = self.log_len() + 1;
        self.next_index = vec![next; self.cfg.n];
        self.match_index = vec![0; self.cfg.n];
        // a leader only counts commits for entries of its own term, so a
        // prior-term tail would sit uncommitted until the next client
        // proposal; a no-op barrier in the new term carries it to commit
        // promptly, no matter which driver runs the failover
        if self.cfg.n > 1 && self.log_len() > self.commit {
            self.log.push(LogEntry {
                term: self.term,
                cmd: ReplCommand::SnapshotBarrier,
            });
        }
        self.heartbeat_due = now + self.cfg.heartbeat_every;
        // assert leadership immediately; also settles commit for n = 1
        for peer in self.peers() {
            self.send_append(peer);
        }
        self.advance_commit();
    }

    fn step_down(&mut self, term: u64) {
        self.term = term;
        self.role = Role::Follower;
        self.voted_for = None;
    }

    fn send_append(&mut self, to: usize) {
        let prev_index = self.next_index[to] - 1;
        let prev_term = if prev_index == 0 {
            0
        } else {
            self.log[prev_index as usize - 1].term
        };
        let entries = self.log[prev_index as usize..].to_vec();
        self.outbox.push((
            to,
            ReplMsg::Append {
                term: self.term,
                from: self.cfg.id,
                prev_index,
                prev_term,
                entries,
                leader_commit: self.commit,
            },
        ));
    }

    // ---- client interface --------------------------------------------------

    /// Append a command to the leader's log and ship it to every peer.
    /// Returns the entry's log index, or `None` when this replica does not
    /// lead (the caller should redirect via [`Replica::leader_hint`]).
    pub fn propose(&mut self, cmd: ReplCommand) -> Option<u64> {
        if self.role != Role::Leader {
            return None;
        }
        self.log.push(LogEntry {
            term: self.term,
            cmd,
        });
        for peer in self.peers() {
            self.send_append(peer);
        }
        self.advance_commit(); // n = 1 commits instantly
        Some(self.log_len())
    }

    // ---- message handling --------------------------------------------------

    /// Consume one inbound message; replies and follow-ups land in the
    /// outbox.
    ///
    /// Messages whose sender id is outside `0..n` (or equal to this
    /// replica's own id) are ignored outright: `from` indexes the
    /// vote/match tables, and in live mode it arrives over an open HTTP
    /// port — a forged or corrupt id must degrade to a no-op, never an
    /// out-of-bounds panic on the serving thread.
    pub fn recv(&mut self, now: u64, msg: ReplMsg) {
        if msg.from() >= self.cfg.n || msg.from() == self.cfg.id {
            return;
        }
        if msg.term() > self.term {
            self.step_down(msg.term());
        }
        match msg {
            ReplMsg::RequestVote {
                term,
                from,
                last_log_index,
                last_log_term,
            } => {
                let up_to_date = last_log_term > self.last_log_term()
                    || (last_log_term == self.last_log_term()
                        && last_log_index >= self.log_len());
                let granted = term == self.term
                    && self.role == Role::Follower
                    && up_to_date
                    && (self.voted_for.is_none() || self.voted_for == Some(from));
                if granted {
                    self.voted_for = Some(from);
                    self.reset_election_deadline(now);
                }
                self.outbox.push((
                    from,
                    ReplMsg::Vote {
                        term: self.term,
                        from: self.cfg.id,
                        granted,
                    },
                ));
            }
            ReplMsg::Vote { term, from, granted } => {
                if self.role == Role::Candidate && term == self.term && granted {
                    self.votes[from] = true;
                    let count = self.votes.iter().filter(|&&v| v).count();
                    if self.majority(count) {
                        self.become_leader(now);
                    }
                }
            }
            ReplMsg::Append {
                term,
                from,
                prev_index,
                prev_term,
                entries,
                leader_commit,
            } => {
                if term < self.term {
                    self.outbox.push((
                        from,
                        ReplMsg::AppendAck {
                            term: self.term,
                            from: self.cfg.id,
                            ok: false,
                            match_index: 0,
                        },
                    ));
                    return;
                }
                // live leader in our term: follow it
                self.role = Role::Follower;
                self.leader_hint = Some(from);
                self.reset_election_deadline(now);
                let consistent = prev_index == 0
                    || (prev_index <= self.log_len()
                        && self.log[prev_index as usize - 1].term == prev_term);
                if !consistent {
                    self.outbox.push((
                        from,
                        ReplMsg::AppendAck {
                            term: self.term,
                            from: self.cfg.id,
                            ok: false,
                            match_index: 0,
                        },
                    ));
                    return;
                }
                for (k, entry) in entries.iter().enumerate() {
                    let index = prev_index + 1 + k as u64;
                    if let Some(existing) = self.log_entry(index) {
                        if existing.term != entry.term {
                            // conflicting suffix: ours is uncommitted by
                            // definition, drop it
                            self.log.truncate(index as usize - 1);
                        }
                    }
                    if index > self.log_len() {
                        self.log.push(entry.clone());
                    }
                }
                let match_index = prev_index + entries.len() as u64;
                if leader_commit > self.commit {
                    self.commit = leader_commit.min(self.log_len());
                }
                self.outbox.push((
                    from,
                    ReplMsg::AppendAck {
                        term: self.term,
                        from: self.cfg.id,
                        ok: true,
                        match_index,
                    },
                ));
            }
            ReplMsg::AppendAck {
                term,
                from,
                ok,
                match_index,
            } => {
                if self.role != Role::Leader || term != self.term {
                    return;
                }
                if ok {
                    if match_index > self.match_index[from] {
                        self.match_index[from] = match_index;
                    }
                    self.next_index[from] = self.match_index[from] + 1;
                    self.advance_commit();
                } else {
                    // walk prev_index back one entry and retry
                    self.next_index[from] = self.next_index[from].saturating_sub(1).max(1);
                    self.send_append(from);
                }
            }
        }
    }

    /// Advance the leader's commit index to the highest log index a
    /// majority holds — counting only entries from the current term (the
    /// standard guard against resurrecting an old-term entry that a newer
    /// leader may overwrite).
    fn advance_commit(&mut self) {
        for index in ((self.commit + 1)..=self.log_len()).rev() {
            if self.log[index as usize - 1].term != self.term {
                continue;
            }
            let count = 1 + self
                .peers()
                .iter()
                .filter(|&&p| self.match_index[p] >= index)
                .count();
            if self.majority(count) {
                self.commit = index;
                return;
            }
        }
    }

    // ---- introspection / persistence ---------------------------------------

    /// Replica status document (served by `GET /raftish`).
    pub fn status_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.cfg.id as f64)),
            ("n", Json::Num(self.cfg.n as f64)),
            ("role", Json::Str(self.role.name().to_string())),
            ("term", Json::from_u64(self.term)),
            ("commit", Json::from_u64(self.commit)),
            ("applied", Json::from_u64(self.applied)),
            ("log_len", Json::from_u64(self.log_len())),
            (
                "leader_hint",
                match self.leader_hint() {
                    Some(l) => Json::Num(l as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Persistent consensus state for snapshot v3: term, vote, commit and
    /// the log tail. Volatile leader state (next/match indices, outbox) is
    /// rebuilt after restart.
    pub fn persistent_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.cfg.id as f64)),
            ("term", Json::from_u64(self.term)),
            (
                "voted_for",
                match self.voted_for {
                    Some(v) => Json::Num(v as f64),
                    None => Json::Null,
                },
            ),
            ("commit", Json::from_u64(self.commit)),
            ("applied", Json::from_u64(self.applied)),
            (
                "log",
                Json::Arr(self.log.iter().map(LogEntry::to_json).collect()),
            ),
        ])
    }

    /// Restore persistent state written by [`Replica::persistent_json`].
    /// The replica resumes as a follower; an election (or the live
    /// bootstrap) re-establishes leadership.
    pub fn load_persistent(&mut self, v: &Json) -> anyhow::Result<()> {
        let u64f = |key: &str| -> anyhow::Result<u64> {
            v.get(key)
                .and_then(Json::as_u64_lossless)
                .ok_or_else(|| anyhow::anyhow!("replication state has no '{key}'"))
        };
        self.term = u64f("term")?;
        self.voted_for = match v.get("voted_for") {
            Some(Json::Null) | None => None,
            Some(x) => Some(
                x.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("bad 'voted_for'"))?,
            ),
        };
        self.commit = u64f("commit")?;
        self.applied = u64f("applied")?;
        self.log = v
            .get("log")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("replication state has no 'log'"))?
            .iter()
            .map(LogEntry::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(
            self.applied <= self.commit && self.commit <= self.log_len(),
            "replication state is inconsistent: applied {} / commit {} / log {}",
            self.applied,
            self.commit,
            self.log_len()
        );
        self.role = Role::Follower;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(id: &str) -> ReplCommand {
        ReplCommand::Drain(id.to_string())
    }

    /// Deliver every outbound message instantly until quiescent — a
    /// zero-fault, zero-delay fabric for unit-testing protocol logic.
    fn settle(replicas: &mut [Replica], now: u64) {
        loop {
            let mut moved = false;
            for i in 0..replicas.len() {
                for (to, msg) in replicas[i].take_outbox() {
                    replicas[to].recv(now, msg);
                    moved = true;
                }
            }
            if !moved {
                return;
            }
        }
    }

    fn group(n: usize, seed: u64) -> Vec<Replica> {
        (0..n)
            .map(|id| Replica::new(ReplicaConfig::new(id, n, seed)))
            .collect()
    }

    #[test]
    fn first_timeout_wins_a_clean_election() {
        let mut rs = group(3, 11);
        let mut now = 0;
        while !rs.iter().any(|r| r.is_leader()) {
            now += 1;
            assert!(now < 100, "no leader after 100 clean ticks");
            for r in rs.iter_mut() {
                r.tick(now);
            }
            settle(&mut rs, now);
        }
        assert_eq!(rs.iter().filter(|r| r.is_leader()).count(), 1);
        let leader = rs.iter().position(|r| r.is_leader()).unwrap();
        for r in &rs {
            assert_eq!(r.leader_hint(), Some(leader));
        }
    }

    #[test]
    fn propose_commits_and_applies_on_every_replica() {
        let mut rs = group(3, 12);
        rs[0].bootstrap_leader();
        settle(&mut rs, 0);
        let idx = rs[0].propose(drain("a")).unwrap();
        assert_eq!(idx, 1);
        settle(&mut rs, 0);
        for r in rs.iter_mut() {
            assert_eq!(r.commit_index(), 1, "replica {}", r.id());
            let applied = r.take_committed();
            assert_eq!(applied, vec![(1, drain("a"))]);
            assert!(r.take_committed().is_empty(), "exactly-once apply");
        }
        assert!(rs[1].propose(drain("b")).is_none(), "followers refuse");
    }

    #[test]
    fn new_leader_preserves_committed_entries() {
        let mut rs = group(3, 13);
        rs[0].bootstrap_leader();
        settle(&mut rs, 0);
        for name in ["a", "b", "c"] {
            rs[0].propose(drain(name));
        }
        settle(&mut rs, 0);
        assert_eq!(rs[0].commit_index(), 3);
        // kill the leader: drive only 1 and 2 until one of them leads
        let mut now = 0;
        while !rs[1..].iter().any(|r| r.is_leader()) {
            now += 1;
            assert!(now < 200, "no failover leader after 200 ticks");
            for r in rs[1..].iter_mut() {
                r.tick(now);
            }
            // settle between the survivors only
            loop {
                let mut moved = false;
                for i in 1..3 {
                    for (to, msg) in rs[i].take_outbox() {
                        if to != 0 {
                            rs[to].recv(now, msg);
                            moved = true;
                        }
                    }
                }
                if !moved {
                    break;
                }
            }
        }
        let new_leader = rs[1..].iter().position(|r| r.is_leader()).unwrap() + 1;
        assert!(rs[new_leader].term() > 1);
        // committed prefix survives on the new leader
        for (i, name) in ["a", "b", "c"].iter().enumerate() {
            assert_eq!(
                rs[new_leader].log_entry(i as u64 + 1).unwrap().cmd,
                drain(name)
            );
        }
    }

    #[test]
    fn stale_candidate_with_short_log_is_refused() {
        let mut rs = group(3, 14);
        rs[0].bootstrap_leader();
        settle(&mut rs, 0);
        rs[0].propose(drain("a"));
        settle(&mut rs, 0);
        // replica 2 asks for a vote with an empty log at a higher term
        let msg = ReplMsg::RequestVote {
            term: 5,
            from: 2,
            last_log_index: 0,
            last_log_term: 0,
        };
        rs[1].recv(0, msg);
        let out = rs[1].take_outbox();
        assert_eq!(out.len(), 1);
        match &out[0].1 {
            ReplMsg::Vote { granted, .. } => assert!(!granted, "stale log must not win"),
            other => panic!("expected a vote, got {other:?}"),
        }
    }

    /// A forged/corrupt sender id (here an append-ack with `from: 999`
    /// aimed at a leader, which would index `match_index[999]`) must be a
    /// no-op, not an index-out-of-bounds panic — in live mode this
    /// message arrives over an open HTTP port.
    #[test]
    fn out_of_range_sender_is_ignored() {
        let mut rs = group(3, 21);
        rs[0].bootstrap_leader();
        settle(&mut rs, 0);
        rs[0].propose(drain("a"));
        let before = rs[0].take_outbox().len(); // drain so the check below is exact
        assert!(before > 0);
        for msg in [
            ReplMsg::AppendAck {
                term: 1,
                from: 999,
                ok: true,
                match_index: 1,
            },
            ReplMsg::RequestVote {
                term: 9,
                from: 3,
                last_log_index: 0,
                last_log_term: 0,
            },
            ReplMsg::Vote {
                term: 1,
                from: 0, // the replica's own id is equally bogus
                granted: true,
            },
        ] {
            rs[0].recv(0, msg);
        }
        assert!(rs[0].is_leader(), "bogus senders must not depose the leader");
        assert_eq!(rs[0].term(), 1, "bogus high terms must not stick");
        assert!(rs[0].take_outbox().is_empty(), "no replies to bogus senders");
    }

    /// The consensus core itself guarantees liveness across failover: a
    /// new leader holding a committed-on-the-old-leader but
    /// not-yet-propagated tail commits it via its own no-op barrier,
    /// without waiting for a client proposal.
    #[test]
    fn new_leader_commits_prior_term_tail_without_client_proposals() {
        let mut rs = group(3, 22);
        rs[0].bootstrap_leader();
        settle(&mut rs, 0);
        rs[0].propose(drain("a"));
        // deliver the appends to the followers but drop their acks: the
        // entry is replicated everywhere yet committed nowhere
        for (to, msg) in rs[0].take_outbox() {
            rs[to].recv(0, msg);
            rs[to].take_outbox();
        }
        assert!(rs.iter().all(|r| r.commit_index() == 0));
        // kill the leader; drive the survivors (no further proposals)
        let mut now = 0;
        while rs[1..].iter().all(|r| r.commit_index() < 1) {
            now += 1;
            assert!(now < 500, "prior-term tail never committed after failover");
            for r in rs[1..].iter_mut() {
                r.tick(now);
            }
            loop {
                let mut moved = false;
                for i in 1..3 {
                    for (to, msg) in rs[i].take_outbox() {
                        if to != 0 {
                            rs[to].recv(now, msg);
                            moved = true;
                        }
                    }
                }
                if !moved {
                    break;
                }
            }
        }
        let leader = rs[1..].iter().position(|r| r.is_leader()).unwrap() + 1;
        assert_eq!(rs[leader].log_entry(1).unwrap().cmd, drain("a"));
        // the barrier the new leader appended in its own term is what
        // carried the tail to commit
        let barrier = rs[leader].log_entry(2).expect("barrier appended");
        assert_eq!(barrier.cmd, ReplCommand::SnapshotBarrier);
        assert_eq!(barrier.term, rs[leader].term());
        assert!(rs[leader].commit_index() >= 1);
    }

    /// A replica restarted from persisted state re-bootstraps in a term
    /// strictly above the restored one, so its appends truncate a stale
    /// same-index suffix on followers instead of leaving two diverged
    /// logs that both believe they are term-1 (the silent-fork hazard).
    #[test]
    fn rebootstrap_after_restore_bumps_term_and_truncates_stale_suffixes() {
        let mut rs = group(3, 23);
        rs[0].bootstrap_leader();
        settle(&mut rs, 0);
        rs[0].propose(drain("a"));
        settle(&mut rs, 0);
        let state = rs[0].persistent_json();
        rs[0].propose(drain("b")); // never persisted: lost by the restart
        settle(&mut rs, 0);
        assert_eq!(rs[1].log_len(), 2);
        // restart replica 0 from the persisted (pre-"b") state
        let mut restarted = Replica::new(ReplicaConfig::new(0, 3, 23));
        restarted
            .load_persistent(&Json::parse(&state.to_string()).unwrap())
            .unwrap();
        restarted.bootstrap_leader();
        assert_eq!(restarted.term(), 2, "bootstrap must leave the restored term");
        rs[0] = restarted;
        // the restarted leader proposes in term 2; followers must drop
        // the stale term-1 "b" at index 2 and converge on the new log
        rs[0].propose(drain("c"));
        settle(&mut rs, 0);
        for r in &rs {
            assert_eq!(r.log_entry(1).unwrap().cmd, drain("a"), "replica {}", r.id());
            assert_eq!(r.log_entry(2).unwrap().cmd, drain("c"), "replica {}", r.id());
            assert_eq!(r.log_entry(2).unwrap().term, 2, "replica {}", r.id());
            assert_eq!(r.log_len(), 2, "replica {}", r.id());
        }
    }

    #[test]
    fn messages_round_trip_json() {
        let msgs = vec![
            ReplMsg::RequestVote {
                term: 2,
                from: 1,
                last_log_index: 7,
                last_log_term: 1,
            },
            ReplMsg::Vote {
                term: 2,
                from: 0,
                granted: true,
            },
            ReplMsg::Append {
                term: 2,
                from: 1,
                prev_index: 3,
                prev_term: 1,
                entries: vec![LogEntry {
                    term: 2,
                    cmd: drain("a"),
                }],
                leader_commit: 3,
            },
            ReplMsg::AppendAck {
                term: 2,
                from: 0,
                ok: true,
                match_index: 4,
            },
        ];
        for msg in msgs {
            let text = msg.to_json().to_string_pretty();
            let back = ReplMsg::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn persistent_state_round_trips() {
        let mut rs = group(3, 15);
        rs[0].bootstrap_leader();
        settle(&mut rs, 0);
        rs[0].propose(drain("a"));
        rs[0].propose(drain("b"));
        settle(&mut rs, 0);
        rs[1].take_committed();
        let state = rs[1].persistent_json();
        let mut fresh = Replica::new(ReplicaConfig::new(1, 3, 15));
        fresh
            .load_persistent(&Json::parse(&state.to_string()).unwrap())
            .unwrap();
        assert_eq!(fresh.term(), rs[1].term());
        assert_eq!(fresh.commit_index(), rs[1].commit_index());
        assert_eq!(fresh.applied_index(), rs[1].applied_index());
        assert_eq!(fresh.log_len(), rs[1].log_len());
        assert_eq!(fresh.role(), Role::Follower);
    }
}
