//! Multi-tenant control plane: application lifecycle, admission control,
//! checkpoint/restore, and the HTTP ops API.
//!
//! The serving loop ([`crate::serving::OnlineServer`]) optimizes a *fixed*
//! application set; the paper's claim that the algorithm "adapts to changes
//! in input rates … as an online algorithm" extends naturally to whole
//! applications arriving and departing. This module owns that fleet view:
//!
//! * [`catalog`] — [`AppCatalog`]: register / update / drain / remove of
//!   [`AppSpec`]s at runtime, and the epoch-versioned network rebuild;
//! * [`admission`] — [`AdmissionController`]: before a register/update
//!   commits, probe the candidate operating point and require every
//!   link/CPU utilization strictly under a capacity headroom and the
//!   predicted cost delta within budget;
//! * [`snapshot`] — versioned, atomically-written checkpoints;
//!   `scfo serve --checkpoint DIR --restore` resumes bit-identically;
//! * [`http`] — a std-only HTTP/1.1 ops server (`scfo serve --http ADDR`):
//!   `GET /healthz|/status|/metrics`, `POST /apps`, `DELETE /apps/{id}`,
//!   `POST /checkpoint` — the system's first network-facing surface.
//!
//! ## Epoch rebuilds and warm starts
//!
//! Every fleet change bumps the control plane's *epoch*: the [`Network`] is
//! re-assembled from the catalog on the current topology (same graph, same
//! CSR arena), and the live optimizer is re-bound through
//! [`crate::serving::Optimizer::rebind`] with a warm strategy —
//! [`warm_strategy`] copies each surviving app's φ rows per stage through
//! the [`StageRegistry`](crate::app::StageRegistry) remap and seeds rows
//! for new apps by min-hop shortest path. Accepted admissions go one step
//! further: the admission probe's already-reconverged strategy seeds the
//! commit, and a temporary step-size boost (via
//! [`crate::serving::Optimizer::scale_step`]) accelerates the residual
//! reconvergence. `rust/tests/control.rs` pins that this warm path takes
//! measurably fewer optimizer iterations than a cold restart; BENCH.json v5
//! reports both counts.
//!
//! ## Topology epochs
//!
//! Topology churn composes with app churn through the same commit path. A
//! [`TopologyState`] tracks the removed link pairs and their pending repair
//! schedule against the epoch-0 base graph;
//! [`ControlPlane::apply_topo_event`] / [`ControlPlane::remove_link_pair`] /
//! [`ControlPlane::apply_due_repairs`] mutate it and trigger a *topology
//! commit*: the network is re-assembled from the catalog on the pruned (or
//! repaired) graph — a **new CSR arena** — and the live strategy is
//! slot-remapped onto it by [`Strategy::rebind_topology`] before the shared
//! optimizer-rebind/boost/serving-rebind sequence runs. The churn state
//! rides in every checkpoint (snapshot key `topology`), so a run restored
//! mid-flap rebuilds the same pruned arena and repairs on the same slot as
//! an uninterrupted one.

pub mod admission;
pub mod catalog;
pub mod http;
pub mod replication;
pub mod snapshot;

pub use admission::{AdmissionController, AdmissionDecision, AdmissionOptions};
pub use catalog::{AppCatalog, AppSpec, AppStatus};
pub use http::OpsServer;
pub use replication::{LiveReplica, ReplCommand, ReplGroup, ReplMsg, Replica};
pub use snapshot::{SNAPSHOT_FILE, SNAPSHOT_VERSION};

use std::path::{Path, PathBuf};

use crate::algo::gp::{GpOptions, GradientProjection};
use crate::app::Network;
use crate::config::Scenario;
use crate::flow::FlowState;
use crate::graph::{topologies, Graph};
use crate::metrics::{
    prometheus_histogram_family, prometheus_line, Histogram, PromHistogram, Registry,
};
use crate::serving::{
    AdaptationController, ControllerOptions, OnlineServer, Optimizer, ServerOptions, SlotMetrics,
    SLOT_PHASES,
};
use crate::strategy::Strategy;
use crate::topo::{TopoAction, TopologyState};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{Workload, WorkloadSpec};

/// Control-plane configuration.
#[derive(Clone, Debug)]
pub struct ControlOptions {
    pub server: ServerOptions,
    pub admission: AdmissionOptions,
    /// Adaptation-controller options (used when `adapt` is set).
    pub controller: ControllerOptions,
    /// Attach the change-point [`AdaptationController`] to the serving loop.
    pub adapt: bool,
    /// Step-size boost applied at each epoch rebuild, rescheduled back
    /// after `boost_slots` served slots. 1.0 disables boosting.
    pub boost: f64,
    pub boost_slots: usize,
    /// Nonstationary traffic spec; `None` = stationary Poisson at the
    /// catalog's registered rates. Trace workloads cannot be checkpointed.
    pub workload: Option<WorkloadSpec>,
}

impl Default for ControlOptions {
    fn default() -> Self {
        ControlOptions {
            server: ServerOptions::default(),
            admission: AdmissionOptions::default(),
            controller: ControllerOptions::default(),
            adapt: false,
            boost: 3.0,
            boost_slots: 10,
            workload: None,
        }
    }
}

/// Operational counters exposed by `/metrics`.
#[derive(Debug)]
pub struct ControlStats {
    /// Wall-clock seconds per admission evaluation (probe included):
    /// recency-window reservoir for BENCH columns and the checkpoint.
    pub admission_latency: Histogram,
    pub admission_accepted: u64,
    pub admission_rejected: u64,
    /// Bucketed admission-latency histogram for `/metrics`
    /// (`scfo_admission_latency_seconds`). Process-lifetime only — bucket
    /// counts are not checkpointed.
    pub admission_hist: PromHistogram,
    /// Bucketed epoch-rebind (optimizer rebind + serving-state rebind)
    /// latency for `/metrics` (`scfo_rebind_latency_seconds`).
    pub rebind_hist: PromHistogram,
    /// Per-phase slot wall time (`scfo_slot_phase_seconds{phase=…}`),
    /// indexed like [`SLOT_PHASES`].
    pub slot_phase: [PromHistogram; 4],
    /// HTTP request counters (`scfo_http_requests_total` etc.).
    pub http: Registry,
    /// Metrics of the most recent served slot.
    pub last: Option<SlotMetrics>,
}

/// Latency bucket shape shared by the control plane's `/metrics`
/// histograms: 1 µs × 4ⁿ, 12 buckets (tops out at ~4.2 s before `+Inf`).
fn latency_buckets() -> PromHistogram {
    PromHistogram::exponential(1e-6, 4.0, 12)
}

impl Default for ControlStats {
    fn default() -> Self {
        ControlStats {
            admission_latency: Histogram::new(1024),
            admission_accepted: 0,
            admission_rejected: 0,
            admission_hist: latency_buckets(),
            rebind_hist: latency_buckets(),
            slot_phase: [
                latency_buckets(),
                latency_buckets(),
                latency_buckets(),
                latency_buckets(),
            ],
            http: Registry::new(),
            last: None,
        }
    }
}

/// The multi-tenant control plane: owns a running
/// `OnlineServer<Box<dyn Optimizer>>` and manages the application fleet on
/// it. See the module docs for the architecture.
pub struct ControlPlane {
    /// Topology + cost scaffold. Its app-generation fields seeded the
    /// initial fleet (imported into the catalog at construction) and are
    /// unused afterwards; the catalog is authoritative.
    pub scenario: Scenario,
    /// The epoch-0 base topology (full link set).
    graph: Graph,
    /// Link-churn bookkeeping: currently-removed pairs, the pending repair
    /// schedule, and the topology epoch. Only its graph-level operations
    /// are used here — the base network it wraps may carry a stale app
    /// list, which is irrelevant (the catalog is authoritative for apps).
    topo: TopologyState,
    /// The current (possibly degraded) topology every epoch rebuilds on:
    /// `graph` minus the removed pairs. Cached from
    /// [`TopologyState::current_graph`] at each topology commit.
    cur_graph: Graph,
    pub catalog: AppCatalog,
    pub admission: AdmissionController,
    pub server: OnlineServer<Box<dyn Optimizer>>,
    pub opts: ControlOptions,
    epoch: u64,
    /// Slots until the rebuild boost is scaled back (0 = no boost active).
    boost_left: usize,
    /// Latest replication (term, commit index) when this plane serves as a
    /// replica (`scfo serve --replica`); surfaced as `scfo_repl_*` gauges.
    pub repl_gauges: Option<(u64, u64)>,
    pub stats: ControlStats,
}

impl ControlPlane {
    /// Build a control plane from a scenario: the scenario's generated
    /// applications become the initial catalog (`app-0` …), served by a
    /// centralized GP optimizer from the min-hop initial strategy.
    pub fn new(scenario: Scenario, opts: ControlOptions) -> anyhow::Result<ControlPlane> {
        let mut rng = Rng::new(scenario.seed);
        let net = scenario.build(&mut rng)?;
        let graph = net.graph.clone();
        let catalog = AppCatalog::import_network(&net);
        let phi0 = Strategy::shortest_path_to_dest(&net);
        let gp = GradientProjection::with_strategy(&net, phi0, GpOptions::default());
        Self::assemble(scenario, graph, catalog, Box::new(gp), net, opts)
    }

    /// Like [`ControlPlane::new`] but serving through a caller-built
    /// optimizer (e.g. [`crate::distributed::DistributedOptimizer`], which
    /// must be constructed on the same initial network).
    pub fn with_optimizer(
        scenario: Scenario,
        optimizer: Box<dyn Optimizer>,
        opts: ControlOptions,
    ) -> anyhow::Result<ControlPlane> {
        let mut rng = Rng::new(scenario.seed);
        let net = scenario.build(&mut rng)?;
        let graph = net.graph.clone();
        let catalog = AppCatalog::import_network(&net);
        Self::assemble(scenario, graph, catalog, optimizer, net, opts)
    }

    fn assemble(
        scenario: Scenario,
        graph: Graph,
        catalog: AppCatalog,
        optimizer: Box<dyn Optimizer>,
        net: Network,
        opts: ControlOptions,
    ) -> anyhow::Result<ControlPlane> {
        let mut sopts = opts.server.clone();
        sopts.seed = scenario.seed;
        let workload = match &opts.workload {
            Some(spec) => Workload::from_spec(spec, &net, sopts.slot_secs, scenario.seed)?,
            None => Workload::stationary(&net, sopts.slot_secs, scenario.seed),
        };
        // the serving net is the current-graph build; constructors pass the
        // full-graph build, and restore() swaps in the checkpointed churn
        // state right after assembly
        let topo = TopologyState::new(net.clone());
        let cur_graph = net.graph.clone();
        let mut server = OnlineServer::with_workload(net, optimizer, workload, sopts);
        if opts.adapt {
            server.attach_controller(AdaptationController::new(opts.controller.clone()));
        }
        Ok(ControlPlane {
            scenario,
            graph,
            topo,
            cur_graph,
            catalog,
            admission: AdmissionController::new(opts.admission.clone()),
            server,
            opts,
            epoch: 0,
            boost_left: 0,
            repl_gauges: None,
            stats: ControlStats::default(),
        })
    }

    /// The current rebuild epoch (bumped by every committed fleet change).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Serving slots completed.
    pub fn slots_served(&self) -> usize {
        self.server.slots_served()
    }

    /// The epoch-0 base topology (full link set).
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The current (possibly degraded) topology.
    pub fn current_graph(&self) -> &Graph {
        &self.cur_graph
    }

    /// The link-churn state: removed pairs, pending repairs, topology epoch.
    pub fn topology(&self) -> &TopologyState {
        &self.topo
    }

    /// Serve one slot; manages the epoch-rebuild boost expiry.
    pub fn run_slot(&mut self) -> anyhow::Result<SlotMetrics> {
        crate::obs::set_control_epoch(self.epoch);
        crate::obs::set_topo_epoch(self.topo.epoch());
        let m = self.server.run_slot()?;
        if self.boost_left > 0 {
            self.boost_left -= 1;
            if self.boost_left == 0 && self.opts.boost > 1.0 {
                self.server.optimizer.scale_step(1.0 / self.opts.boost);
            }
        }
        for (h, secs) in self.stats.slot_phase.iter().zip(m.phase_secs) {
            h.observe(secs);
        }
        self.stats.last = Some(m.clone());
        Ok(m)
    }

    /// Aggregate cost of the live strategy at the workload's current true
    /// rates (the admission cost-budget baseline and `/status` cost).
    pub fn current_cost(&self) -> f64 {
        let mut truth = self.server.net.clone();
        self.server.workload.apply_true_rates(&mut truth);
        match FlowState::solve(&truth, self.server.optimizer.strategy()) {
            Ok(fs) => fs.total_cost,
            Err(_) => f64::INFINITY,
        }
    }

    /// Register a new application. Admission-checked: the decision is
    /// returned either way, and only accepts mutate the fleet.
    pub fn register(&mut self, spec: AppSpec) -> anyhow::Result<AdmissionDecision> {
        spec.validate(self.graph.n())?;
        anyhow::ensure!(
            self.catalog.get(&spec.id).is_none(),
            "app '{}' already registered",
            spec.id
        );
        self.admit_and_commit(spec, false)
    }

    /// Update a registered application (rates, chain, destination).
    /// Admission-checked like a register.
    pub fn update(&mut self, spec: AppSpec) -> anyhow::Result<AdmissionDecision> {
        spec.validate(self.graph.n())?;
        anyhow::ensure!(
            self.catalog.get(&spec.id).is_some(),
            "app '{}' is not registered",
            spec.id
        );
        self.admit_and_commit(spec, true)
    }

    fn admit_and_commit(
        &mut self,
        spec: AppSpec,
        is_update: bool,
    ) -> anyhow::Result<AdmissionDecision> {
        let _span = crate::obs_span!("control", "admission");
        let t0 = std::time::Instant::now();
        let mut cand = self.catalog.clone();
        if is_update {
            cand.update(spec)?;
        } else {
            cand.register(spec)?;
        }
        let net = cand.build_network(&self.scenario, &self.cur_graph)?;
        let remap = cand.remap(&self.catalog.ids());
        let warm = warm_strategy(
            &self.server.net,
            self.server.optimizer.strategy(),
            &net,
            &remap,
        );
        let decision = self.admission.evaluate(&net, &warm, self.current_cost());
        let admission_secs = t0.elapsed().as_secs_f64();
        self.stats.admission_latency.record(admission_secs);
        self.stats.admission_hist.observe(admission_secs);
        match &decision {
            AdmissionDecision::Accepted { probe, .. } => {
                self.stats.admission_accepted += 1;
                // commit with the candidate assembly already built for the
                // probe — no second build_network/remap on the accept path
                let probe = probe.clone();
                self.commit(cand, net, &remap, probe);
            }
            AdmissionDecision::Rejected { .. } => self.stats.admission_rejected += 1,
        }
        Ok(decision)
    }

    /// Stop an app's traffic; its φ rows stay so in-flight work drains.
    /// Load only decreases, so no admission check.
    pub fn drain(&mut self, id: &str) -> anyhow::Result<()> {
        let mut cand = self.catalog.clone();
        cand.drain(id)?;
        self.rebuild_and_commit(cand)
    }

    /// Remove an app entirely (usually after a drain).
    pub fn remove(&mut self, id: &str) -> anyhow::Result<()> {
        let mut cand = self.catalog.clone();
        cand.remove(id)?;
        self.rebuild_and_commit(cand)
    }

    /// Assemble the candidate network + warm strategy for an
    /// unconditionally-admitted lifecycle change (drain/remove), then
    /// commit it.
    fn rebuild_and_commit(&mut self, catalog: AppCatalog) -> anyhow::Result<()> {
        let net = catalog.build_network(&self.scenario, &self.cur_graph)?;
        let remap = catalog.remap(&self.catalog.ids());
        let phi = warm_strategy(
            &self.server.net,
            self.server.optimizer.strategy(),
            &net,
            &remap,
        );
        self.commit(catalog, net, &remap, phi);
        Ok(())
    }

    /// Commit a fleet change whose network, remap and warm strategy are
    /// already assembled: rebind the optimizer (+ reconvergence boost) and
    /// the serving state, adopt the catalog, bump the epoch.
    fn commit(&mut self, catalog: AppCatalog, net: Network, remap: &[Option<usize>], phi: Strategy) {
        let _span = crate::obs_span!("control", "commit");
        let t0 = std::time::Instant::now();
        self.server.optimizer.rebind(&net, &phi);
        if self.opts.boost > 1.0 {
            if self.boost_left == 0 {
                self.server.optimizer.scale_step(self.opts.boost);
            }
            self.boost_left = self.opts.boost_slots; // extend an active boost
        }
        self.server.rebind_network(net, remap);
        self.catalog = catalog;
        self.epoch += 1;
        self.stats.rebind_hist.observe(t0.elapsed().as_secs_f64());
        crate::obs::set_control_epoch(self.epoch);
        crate::obs::set_topo_epoch(self.topo.epoch());
    }

    // ---- topology churn ----------------------------------------------------

    /// Apply one scripted topology event at the current serving slot:
    /// remove the picked link pairs and schedule their repair. Returns the
    /// pairs actually removed (possibly fewer than scripted — the
    /// connectivity filter skips cut links); commits an epoch rebuild when
    /// anything changed. Composes with app churn: the same serving state,
    /// catalog and checkpoint machinery carry through.
    pub fn apply_topo_event(
        &mut self,
        action: &TopoAction,
        rng: &mut Rng,
    ) -> anyhow::Result<Vec<(usize, usize)>> {
        let at_slot = self.slots_served();
        let picked = self.topo.apply_event(at_slot, action, rng);
        if !picked.is_empty() {
            self.commit_topology()?;
        }
        Ok(picked)
    }

    /// Remove one link pair now, scheduled to repair at serving slot `due`.
    /// Errors if the pair is not a present base link or if removing it
    /// would disconnect the graph.
    pub fn remove_link_pair(&mut self, i: usize, j: usize, due: usize) -> anyhow::Result<()> {
        self.topo.remove_pair(i, j, due)?;
        self.commit_topology()
    }

    /// Restore one removed link pair immediately (dropping its pending
    /// repair). Returns whether it was removed.
    pub fn restore_link_pair(&mut self, i: usize, j: usize) -> anyhow::Result<bool> {
        if !self.topo.restore_pair(i, j) {
            return Ok(false);
        }
        self.commit_topology()?;
        Ok(true)
    }

    /// Restore every link pair whose repair is due at or before `slot`
    /// (typically called with [`ControlPlane::slots_served`] each slot).
    /// Returns the restored pairs; commits one epoch rebuild if any.
    pub fn apply_due_repairs(&mut self, slot: usize) -> anyhow::Result<Vec<(usize, usize)>> {
        let restored = self.topo.due_repairs(slot);
        if !restored.is_empty() {
            self.commit_topology()?;
        }
        Ok(restored)
    }

    /// Epoch rebuild for a topology change: same fleet, new CSR arena. The
    /// network is re-assembled from the catalog on the pruned/repaired
    /// graph, φ slot-remaps onto the new arena
    /// ([`Strategy::rebind_topology`]), and the commit path (optimizer
    /// rebind + boost + serving-state rebind with an identity app remap)
    /// is shared with app churn.
    fn commit_topology(&mut self) -> anyhow::Result<()> {
        self.cur_graph = self.topo.current_graph();
        let catalog = self.catalog.clone();
        let net = catalog.build_network(&self.scenario, &self.cur_graph)?;
        let phi = self.server.optimizer.strategy().rebind_topology(&net);
        let remap: Vec<Option<usize>> = (0..catalog.len()).map(Some).collect();
        self.commit(catalog, net, &remap, phi);
        Ok(())
    }

    // ---- replication -------------------------------------------------------

    /// Apply one *committed* replicated command ([`replication`]) to this
    /// plane. The dispatch is tolerant, mirroring
    /// [`replication::apply_to_catalog`]: a register of an existing id
    /// degrades to an update, an update of a missing id to a register, a
    /// drain/remove of a missing id is a no-op, and a snapshot barrier
    /// changes nothing. Tolerance is what makes client re-proposals after
    /// a failover safe — every replica applies the same committed
    /// sequence, including any duplicates, and converges to the same
    /// state. Admission runs inside the apply and is deterministic given
    /// the plane state, so identical replicas reach identical decisions.
    ///
    /// Returns a small outcome document: `{op, applied, epoch}` plus
    /// `accepted` for admission-checked commands.
    pub fn apply_committed(&mut self, cmd: &ReplCommand) -> anyhow::Result<Json> {
        let _span = crate::obs_span!("repl", "apply-committed");
        let mut accepted = Json::Null;
        let applied = match cmd {
            ReplCommand::Register(spec) | ReplCommand::Update(spec) => {
                let decision = if self.catalog.get(&spec.id).is_some() {
                    self.update(spec.clone())?
                } else {
                    self.register(spec.clone())?
                };
                accepted = Json::Bool(decision.accepted());
                decision.accepted()
            }
            ReplCommand::Drain(id) => {
                if self.catalog.get(id).is_some() {
                    self.drain(id)?;
                    true
                } else {
                    false
                }
            }
            ReplCommand::Remove(id) => {
                if self.catalog.get(id).is_some() {
                    self.remove(id)?;
                    true
                } else {
                    false
                }
            }
            ReplCommand::Topo(event) => {
                // every replica derives the same pick-RNG from replicated
                // state (scenario seed + the event's scripted slot), so
                // the flap picks the same link pairs everywhere
                let mut rng =
                    Rng::new(self.scenario.seed ^ (event.at_slot as u64) ^ 0x4A50_C0DE);
                !self.apply_topo_event(&event.action, &mut rng)?.is_empty()
            }
            ReplCommand::SnapshotBarrier => false,
        };
        Ok(Json::obj(vec![
            ("op", Json::Str(cmd.op().to_string())),
            ("applied", Json::Bool(applied)),
            ("accepted", accepted),
            ("epoch", Json::Num(self.epoch as f64)),
        ]))
    }

    // ---- checkpoint / restore ---------------------------------------------

    /// Snapshot the full control-plane state as one JSON document (see
    /// [`snapshot`] for the format and guarantees).
    pub fn snapshot_json(&self) -> anyhow::Result<Json> {
        Ok(Json::obj(vec![
            ("version", Json::Num(SNAPSHOT_VERSION as f64)),
            ("epoch", Json::Num(self.epoch as f64)),
            ("scenario", self.scenario.to_json()),
            ("catalog", self.catalog.to_json()),
            ("phi", self.server.optimizer.strategy().to_json()),
            (
                "alpha",
                match self.server.optimizer.step_size() {
                    Some(a) => Json::Num(a),
                    None => Json::Null,
                },
            ),
            ("boost_left", Json::Num(self.boost_left as f64)),
            ("topology", self.topo.state_json()),
            ("server", self.server.state_json()?),
            (
                "admission_accepted",
                Json::Num(self.stats.admission_accepted as f64),
            ),
            (
                "admission_rejected",
                Json::Num(self.stats.admission_rejected as f64),
            ),
            (
                "admission_latency",
                self.stats.admission_latency.state_json(),
            ),
        ]))
    }

    /// Write an atomic checkpoint into `dir`; returns the snapshot path.
    pub fn checkpoint(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        snapshot::write_atomic(dir, &self.snapshot_json()?)
    }

    /// Replicated-mode checkpoint: writes into the replica's private
    /// subdirectory of the shared `--checkpoint` `base_dir`
    /// ([`snapshot::replica_dir`], so co-located replicas never clobber
    /// each other's `snapshot.json`) and embeds the replica's persistent
    /// consensus state under the snapshot-v3 `replication` key. Every
    /// checkpoint a replicated deployment takes — periodic, final, and
    /// `POST /checkpoint` — goes through here; the serve path restores
    /// from the same per-replica directory.
    pub fn checkpoint_replicated(
        &self,
        base_dir: &Path,
        repl: &LiveReplica,
    ) -> anyhow::Result<PathBuf> {
        let mut doc = match self.snapshot_json()? {
            Json::Obj(o) => o,
            _ => unreachable!("snapshot serializes to an object"),
        };
        doc.insert("replication".into(), repl.persistent_json());
        snapshot::write_atomic(
            &snapshot::replica_dir(base_dir, repl.id()),
            &Json::Obj(doc),
        )
    }

    /// Resume from the checkpoint in `dir`. The base topology rebuilds
    /// deterministically from the scenario seed and the checkpointed
    /// link-churn state (removed pairs + pending repair schedule) replays
    /// on top of it; catalog, φ (parsed against the pruned arena), step
    /// size, estimates, workload (model + RNG state) and controller state
    /// restore exactly, so the serving loop continues bit-identically with
    /// an uninterrupted run (pinned by `rust/tests/control.rs`).
    pub fn restore(dir: &Path, opts: ControlOptions) -> anyhow::Result<ControlPlane> {
        Self::restore_from_doc(&snapshot::load(dir)?, opts)
    }

    /// [`ControlPlane::restore`] on an already-loaded snapshot document.
    /// The replicated serve path loads the document once and reuses it for
    /// the `replication` key ([`LiveReplica::load_persistent`]).
    pub fn restore_from_doc(doc: &Json, opts: ControlOptions) -> anyhow::Result<ControlPlane> {
        let scenario = Scenario::from_json(
            doc.get("scenario")
                .ok_or_else(|| anyhow::anyhow!("snapshot: missing 'scenario'"))?,
        )?;
        let mut rng = Rng::new(scenario.seed);
        let graph = topologies::by_name(&scenario.topology, &mut rng)?;
        let catalog = AppCatalog::from_json(
            doc.get("catalog")
                .ok_or_else(|| anyhow::anyhow!("snapshot: missing 'catalog'"))?,
        )?;
        // replay the checkpointed link-churn state (removed pairs + pending
        // repair schedule) onto the freshly-built base BEFORE parsing φ:
        // a snapshot taken mid-flap stored φ on the pruned arena, so it
        // must be parsed against the same pruned graph
        let mut topo = TopologyState::new(catalog.build_network(&scenario, &graph)?);
        if let Some(t) = doc.get("topology") {
            topo.load_state_json(t)?;
        }
        let cur_graph = topo.current_graph();
        let net = catalog.build_network(&scenario, &cur_graph)?;
        let phi = Strategy::from_json(
            &net.graph,
            doc.get("phi")
                .ok_or_else(|| anyhow::anyhow!("snapshot: missing 'phi'"))?,
        )?;
        phi.validate(&net)
            .map_err(|e| anyhow::anyhow!("snapshot phi invalid for the rebuilt network: {e}"))?;
        let alpha = doc
            .get("alpha")
            .and_then(Json::as_f64)
            .unwrap_or_else(|| GpOptions::default().alpha);
        let gp = GradientProjection::with_strategy(
            &net,
            phi,
            GpOptions {
                alpha,
                ..GpOptions::default()
            },
        );
        let mut plane = Self::assemble(scenario, graph, catalog, Box::new(gp), net, opts)?;
        plane.topo = topo;
        plane.cur_graph = cur_graph;
        plane.server.load_state_json(
            doc.get("server")
                .ok_or_else(|| anyhow::anyhow!("snapshot: missing 'server'"))?,
        )?;
        plane.epoch = doc.get("epoch").and_then(Json::as_usize).unwrap_or(0) as u64;
        plane.boost_left = doc.get("boost_left").and_then(Json::as_usize).unwrap_or(0);
        plane.stats.admission_accepted = doc
            .get("admission_accepted")
            .and_then(Json::as_usize)
            .unwrap_or(0) as u64;
        plane.stats.admission_rejected = doc
            .get("admission_rejected")
            .and_then(Json::as_usize)
            .unwrap_or(0) as u64;
        if let Some(h) = doc.get("admission_latency") {
            plane.stats.admission_latency = Histogram::from_state_json(h)?;
        }
        Ok(plane)
    }

    // ---- ops surfaces ------------------------------------------------------

    /// The `GET /status` document: epoch, slot, fleet, cost, per-link and
    /// per-CPU utilization at the current true rates.
    pub fn status_json(&self) -> Json {
        let mut truth = self.server.net.clone();
        self.server.workload.apply_true_rates(&mut truth);
        let phi = self.server.optimizer.strategy();
        let (cost, link_util, cpu_util) = match FlowState::solve(&truth, phi) {
            Ok(fs) => {
                let link: Vec<f64> = (0..truth.m())
                    .map(|e| match truth.link_cost[e].capacity() {
                        Some(cap) => fs.link_flow[e] / cap,
                        None => 0.0,
                    })
                    .collect();
                let cpu: Vec<f64> = (0..truth.n())
                    .map(|i| match truth.comp_cost[i].capacity() {
                        Some(cap) => fs.workload[i] / cap,
                        None => 0.0,
                    })
                    .collect();
                (fs.total_cost, link, cpu)
            }
            Err(_) => (f64::INFINITY, Vec::new(), Vec::new()),
        };
        let apps = self
            .catalog
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("id", Json::Str(a.id.clone())),
                    ("status", Json::Str(a.status.name().into())),
                    ("dest", Json::Num(a.dest as f64)),
                    ("num_tasks", Json::Num(a.num_tasks as f64)),
                    ("total_rate", Json::Num(a.total_rate())),
                ])
            })
            .collect();
        let max = |xs: &[f64]| xs.iter().cloned().fold(0.0, f64::max);
        Json::obj(vec![
            ("epoch", Json::Num(self.epoch as f64)),
            ("slot", Json::Num(self.slots_served() as f64)),
            ("cost", Json::Num(cost)),
            ("apps", Json::Arr(apps)),
            (
                "utilization",
                Json::obj(vec![
                    ("link_max", Json::Num(max(&link_util))),
                    ("cpu_max", Json::Num(max(&cpu_util))),
                    ("links", Json::arr_f64(&link_util)),
                    ("cpus", Json::arr_f64(&cpu_util)),
                ]),
            ),
            (
                "admission",
                Json::obj(vec![
                    ("accepted", Json::Num(self.stats.admission_accepted as f64)),
                    ("rejected", Json::Num(self.stats.admission_rejected as f64)),
                ]),
            ),
        ])
    }

    /// The `GET /metrics` document (Prometheus text exposition format,
    /// rendered through [`crate::metrics`]): fleet/serving gauges, the
    /// admission/rebind/per-phase latency histogram families, distributed-
    /// runtime gauges (sharded optimizer only) and the HTTP registry.
    pub fn metrics_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&prometheus_line(
            "scfo_epoch",
            "gauge",
            "control-plane catalog epoch",
            self.epoch as f64,
        ));
        out.push_str(&prometheus_line(
            "scfo_topo_epoch",
            "gauge",
            "topology churn epoch",
            self.topo.epoch() as f64,
        ));
        out.push_str(&prometheus_line(
            "scfo_slots_served_total",
            "counter",
            "serving slots completed",
            self.slots_served() as f64,
        ));
        out.push_str(&prometheus_line(
            "scfo_apps_total",
            "gauge",
            "registered applications",
            self.catalog.len() as f64,
        ));
        out.push_str(&prometheus_line(
            "scfo_apps_active",
            "gauge",
            "applications serving traffic",
            self.catalog
                .iter()
                .filter(|a| a.status == AppStatus::Active)
                .count() as f64,
        ));
        if let Some(last) = &self.stats.last {
            out.push_str(&prometheus_line(
                "scfo_cost",
                "gauge",
                "aggregate delay cost at true rates",
                last.cost,
            ));
            out.push_str(&prometheus_line(
                "scfo_expected_delay_seconds",
                "gauge",
                "expected per-packet delay (Little's law)",
                last.expected_delay,
            ));
            out.push_str(&prometheus_line(
                "scfo_optimizer_latency_seconds",
                "gauge",
                "optimizer wall time last slot",
                last.optimizer_latency,
            ));
        }
        out.push_str(&prometheus_line(
            "scfo_admission_accepted_total",
            "counter",
            "admission decisions accepted",
            self.stats.admission_accepted as f64,
        ));
        out.push_str(&prometheus_line(
            "scfo_admission_rejected_total",
            "counter",
            "admission decisions rejected",
            self.stats.admission_rejected as f64,
        ));
        if self.stats.admission_latency.count() > 0 {
            out.push_str(&prometheus_line(
                "scfo_admission_latency_seconds_mean",
                "gauge",
                "mean admission latency, recent window",
                self.stats.admission_latency.mean(),
            ));
            out.push_str(&prometheus_line(
                "scfo_admission_latency_seconds_p95",
                "gauge",
                "p95 admission latency, recent window",
                self.stats.admission_latency.percentile(95.0),
            ));
        }
        // bucketed latency families (always rendered so scrapers see the
        // bucket layout from the first scrape)
        out.push_str(&prometheus_histogram_family(
            "scfo_admission_latency_seconds",
            "admission evaluation wall time",
            &[("", &self.stats.admission_hist)],
        ));
        out.push_str(&prometheus_histogram_family(
            "scfo_rebind_latency_seconds",
            "epoch-rebuild (rebind) wall time",
            &[("", &self.stats.rebind_hist)],
        ));
        let phase_series: Vec<(String, &PromHistogram)> = SLOT_PHASES
            .iter()
            .zip(&self.stats.slot_phase)
            .map(|(name, h)| (format!("phase=\"{name}\","), h))
            .collect();
        let phase_refs: Vec<(&str, &PromHistogram)> = phase_series
            .iter()
            .map(|(l, h)| (l.as_str(), *h))
            .collect();
        out.push_str(&prometheus_histogram_family(
            "scfo_slot_phase_seconds",
            "serving-slot wall time by phase",
            &phase_refs,
        ));
        // distributed-runtime gauges (present when the optimizer is the
        // async sharded runtime)
        if let Some(rs) = self.server.optimizer.runtime_stats() {
            out.push_str(&prometheus_line(
                "scfo_dist_epochs",
                "gauge",
                "distributed broadcast epochs completed",
                rs.epochs as f64,
            ));
            out.push_str(&prometheus_line(
                "scfo_dist_messages_sent",
                "gauge",
                "transport messages sent",
                rs.transport.sent as f64,
            ));
            out.push_str(&prometheus_line(
                "scfo_dist_bytes_sent",
                "gauge",
                "transport payload bytes sent",
                rs.transport.bytes_sent as f64,
            ));
            out.push_str(&prometheus_line(
                "scfo_dist_queue_depth_max",
                "gauge",
                "deepest transport queue observed",
                rs.transport.max_queue_depth as f64,
            ));
            out.push_str(&prometheus_line(
                "scfo_dist_stale_reads",
                "gauge",
                "stale marginal reads tolerated",
                rs.stale_reads as f64,
            ));
        }
        // replication health (absent on unreplicated planes)
        if let Some((term, commit)) = self.repl_gauges {
            out.push_str(&prometheus_line(
                "scfo_repl_term",
                "gauge",
                "replication consensus term",
                term as f64,
            ));
            out.push_str(&prometheus_line(
                "scfo_repl_commit_index",
                "gauge",
                "replication commit index",
                commit as f64,
            ));
        }
        // flight-recorder health (zeros while tracing is disabled)
        let (_, spans_recorded, spans_dropped, _) = crate::obs::stats();
        out.push_str(&prometheus_line(
            "scfo_obs_spans_recorded_total",
            "counter",
            "spans recorded by the flight recorder",
            spans_recorded as f64,
        ));
        out.push_str(&prometheus_line(
            "scfo_obs_spans_dropped_total",
            "counter",
            "spans lost to flight-recorder ring overflow",
            spans_dropped as f64,
        ));
        out.push_str(&self.stats.http.prometheus_text());
        out
    }
}

/// Warm-start strategy for an epoch rebuild: start from the min-hop
/// strategy on the new network (which seeds every new app's rows), then
/// copy each surviving app's φ rows per stage through the stage-registry
/// remap — `remap[old_app] = Some(new_app)`. Apps whose destination or
/// chain length changed keep the min-hop seeding (their old rows are
/// shaped for different exit/offload constraints).
///
/// Rows copy verbatim only when the CSR arena is unchanged. When the edge
/// set differs (a topology commit), the whole strategy is slot-remapped
/// onto the new arena by [`Strategy::rebind_topology`] instead — the
/// control plane never changes the fleet and the topology in one commit,
/// so the stage sets match in that branch.
pub fn warm_strategy(
    old_net: &Network,
    old_phi: &Strategy,
    new_net: &Network,
    remap: &[Option<usize>],
) -> Strategy {
    if old_net.graph.edges() != new_net.graph.edges() {
        debug_assert_eq!(
            old_net.num_stages(),
            new_net.num_stages(),
            "topology and fleet changes must commit separately"
        );
        return old_phi.rebind_topology(new_net);
    }
    let mut phi = Strategy::shortest_path_to_dest(new_net);
    for (old_a, new_a) in remap.iter().enumerate() {
        let Some(na) = new_a else { continue };
        let old_app = &old_net.apps[old_a];
        let new_app = &new_net.apps[*na];
        if old_app.dest != new_app.dest || old_app.num_tasks != new_app.num_tasks {
            continue;
        }
        for k in 0..old_app.num_stages() {
            let so = old_net.stages.id(old_a, k);
            let sn = new_net.stages.id(*na, k);
            for i in 0..new_net.n() {
                phi.row_mut(sn, i).copy_from_slice(old_phi.row(so, i));
            }
        }
    }
    phi
}

/// GP iterations needed, starting from `phi0`, to bring the aggregate cost
/// within `rel_tol` (relative) of `target`; `max_iters` if never reached.
/// The warm-vs-cold reconvergence comparison of BENCH.json v5 (and the
/// acceptance test) runs this once from the control plane's warm strategy
/// and once from the min-hop cold start, against a shared target computed
/// by a long reference solve.
pub fn iters_to_reach(
    net: &Network,
    phi0: &Strategy,
    target: f64,
    rel_tol: f64,
    max_iters: usize,
) -> usize {
    let mut gp = GradientProjection::with_strategy(net, phi0.clone(), GpOptions::default());
    let bound = target * (1.0 + rel_tol);
    if gp.cost(net) <= bound {
        return 0;
    }
    for it in 1..=max_iters {
        if gp.step(net).cost <= bound {
            return it;
        }
    }
    max_iters
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::{Congestion, ScenarioSpec};

    fn small_plane() -> ControlPlane {
        // light congestion keeps the initial fleet comfortably inside the
        // admission headroom, so the lifecycle tests exercise accepts
        let spec = ScenarioSpec::named("abilene", Congestion::Light).unwrap();
        ControlPlane::new(spec.effective_base(), ControlOptions::default()).unwrap()
    }

    fn tiny_app(id: &str, n: usize) -> AppSpec {
        AppSpec {
            id: id.into(),
            dest: 2 % n,
            num_tasks: 2,
            packet_sizes: vec![10.0, 5.0, 1.0],
            rates: vec![(5 % n, 0.3)],
            status: AppStatus::Active,
        }
    }

    #[test]
    fn register_bumps_epoch_and_grows_fleet() {
        let mut plane = small_plane();
        plane.run_slot().unwrap();
        let apps0 = plane.catalog.len();
        let d = plane.register(tiny_app("svc-a", plane.graph().n())).unwrap();
        assert!(d.accepted(), "{d:?}");
        assert_eq!(plane.epoch(), 1);
        assert_eq!(plane.catalog.len(), apps0 + 1);
        assert_eq!(plane.server.net.apps.len(), apps0 + 1);
        assert_eq!(
            plane.server.optimizer.strategy().num_stages(),
            plane.server.net.num_stages()
        );
        // serving continues across the rebuild
        let m = plane.run_slot().unwrap();
        assert!(m.cost.is_finite());
        assert_eq!(plane.stats.admission_accepted, 1);
    }

    #[test]
    fn drain_then_remove_shrinks_the_fleet() {
        let mut plane = small_plane();
        let n = plane.graph().n();
        plane.register(tiny_app("svc-b", n)).unwrap();
        let apps = plane.catalog.len();
        plane.drain("svc-b").unwrap();
        assert_eq!(plane.catalog.get("svc-b").unwrap().status, AppStatus::Draining);
        assert_eq!(plane.catalog.len(), apps, "draining keeps the app");
        plane.run_slot().unwrap();
        plane.remove("svc-b").unwrap();
        assert_eq!(plane.catalog.len(), apps - 1);
        assert_eq!(plane.epoch(), 3);
        plane.run_slot().unwrap();
    }

    #[test]
    fn overloaded_register_is_rejected_and_fleet_untouched() {
        let mut plane = small_plane();
        let n = plane.graph().n();
        let mut monster = tiny_app("monster", n);
        monster.rates = vec![(0, 1e5)];
        let d = plane.register(monster).unwrap();
        assert!(!d.accepted());
        assert_eq!(plane.epoch(), 0, "rejected register must not bump the epoch");
        assert!(plane.catalog.get("monster").is_none());
        assert_eq!(plane.stats.admission_rejected, 1);
        plane.run_slot().unwrap();
    }

    #[test]
    fn warm_strategy_preserves_surviving_rows() {
        let plane = small_plane();
        let old_net = &plane.server.net;
        let old_phi = plane.server.optimizer.strategy();
        // identity remap: warm == old rows for every stage
        let remap: Vec<Option<usize>> = (0..old_net.apps.len()).map(Some).collect();
        let warm = warm_strategy(old_net, old_phi, old_net, &remap);
        assert_eq!(warm.max_diff(old_phi), 0.0);
    }

    #[test]
    fn topo_flap_rebuilds_arena_and_serving_continues() {
        let mut plane = small_plane();
        plane.run_slot().unwrap();
        let m0 = plane.server.net.m();
        plane.remove_link_pair(0, 1, 5).unwrap();
        assert_eq!(plane.epoch(), 1, "topology commit bumps the epoch");
        assert_eq!(plane.server.net.m(), m0 - 2, "pair removal drops both directions");
        assert_eq!(plane.topology().removed_pairs(), vec![(0, 1)]);
        assert_eq!(plane.current_graph().m(), m0 - 2);
        assert_eq!(plane.graph().m(), m0, "base graph untouched");
        // φ lives on the pruned arena and serving continues
        assert!(plane.run_slot().unwrap().cost.is_finite());
        while plane.slots_served() < 5 {
            plane.run_slot().unwrap();
        }
        let restored = plane.apply_due_repairs(plane.slots_served()).unwrap();
        assert_eq!(restored, vec![(0, 1)]);
        assert_eq!(plane.server.net.m(), m0);
        assert!(plane.run_slot().unwrap().cost.is_finite());
    }

    #[test]
    fn topology_and_app_churn_compose() {
        let mut plane = small_plane();
        let n = plane.graph().n();
        plane.remove_link_pair(0, 1, 100).unwrap();
        let d = plane.register(tiny_app("svc-t", n)).unwrap();
        assert!(d.accepted(), "{d:?}");
        // the arrival's admission probe and commit ran on the pruned graph
        assert_eq!(plane.server.net.m(), plane.current_graph().m());
        assert!(!plane.server.net.graph.has_edge(0, 1));
        plane.run_slot().unwrap();
        assert!(plane.restore_link_pair(0, 1).unwrap());
        assert!(!plane.restore_link_pair(0, 1).unwrap(), "second restore no-op");
        assert_eq!(plane.server.net.m(), plane.graph().m());
        assert_eq!(plane.server.net.apps.len(), plane.catalog.len());
        plane.run_slot().unwrap();
    }

    #[test]
    fn snapshot_round_trips_topology_state() {
        let mut plane = small_plane();
        plane.remove_link_pair(0, 1, 42).unwrap();
        plane.run_slot().unwrap();
        let dir = std::env::temp_dir().join(format!("scfo-ctl-topo-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        plane.checkpoint(&dir).unwrap();
        let re = ControlPlane::restore(&dir, ControlOptions::default()).unwrap();
        assert_eq!(re.topology().removed_pairs(), vec![(0, 1)]);
        assert_eq!(
            re.topology().pending_repairs(),
            plane.topology().pending_repairs()
        );
        assert_eq!(re.topology().epoch(), plane.topology().epoch());
        assert_eq!(re.server.net.m(), plane.server.net.m(), "pruned arena rebuilt");
        assert_eq!(
            re.server
                .optimizer
                .strategy()
                .max_diff(plane.server.optimizer.strategy()),
            0.0,
            "φ restored bit-exactly on the pruned arena"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn status_and_metrics_render() {
        let mut plane = small_plane();
        plane.run_slot().unwrap();
        let status = plane.status_json();
        assert!(status.get("epoch").is_some());
        assert!(status.get("utilization").unwrap().get("link_max").is_some());
        assert_eq!(
            status.get("apps").unwrap().as_arr().unwrap().len(),
            plane.catalog.len()
        );
        let metrics = plane.metrics_text();
        assert!(metrics.contains("scfo_epoch"));
        assert!(metrics.contains("scfo_slots_served_total 1"));
        assert!(metrics.contains("scfo_cost"));
    }
}
