//! Application catalog: the control plane's registry of service-chain
//! applications and their lifecycle.
//!
//! Each [`AppSpec`] is a declarative description of one [`Application`] —
//! destination, chain length, packet schedule, sparse per-node input rates —
//! keyed by a caller-chosen string id. The catalog supports
//! register / update / drain / remove at runtime; [`AppCatalog::build_network`]
//! assembles the current fleet into a concrete [`Network`] on the control
//! plane's fixed topology (one *epoch* per rebuild), and
//! [`AppCatalog::remap`] expresses how application indices moved between two
//! epochs so φ rows, rate-estimate rows and workload streams can follow
//! their app (see [`crate::control::warm_strategy`]).
//!
//! Catalog order is registration order: surviving apps keep their relative
//! position across rebuilds and new apps append, which keeps the remap a
//! simple order-preserving injection.

use crate::app::{Application, Network, StageRegistry};
use crate::config::Scenario;
use crate::graph::Graph;
use crate::util::json::Json;

/// Lifecycle state of a registered application.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppStatus {
    /// Serving traffic.
    Active,
    /// Kept in the network (φ rows intact, in-flight work finishes) but its
    /// exogenous input rates are forced to zero. A drained app can be
    /// removed or re-activated by an update.
    Draining,
}

impl AppStatus {
    pub fn name(&self) -> &'static str {
        match self {
            AppStatus::Active => "active",
            AppStatus::Draining => "draining",
        }
    }
}

/// Declarative description of one service-chain application.
#[derive(Clone, Debug, PartialEq)]
pub struct AppSpec {
    /// Caller-chosen unique id (HTTP: `POST /apps`, `DELETE /apps/{id}`).
    pub id: String,
    /// Result destination d_a.
    pub dest: usize,
    /// |𝒯_a| — chained tasks.
    pub num_tasks: usize,
    /// L_(a,k) per stage; len = num_tasks + 1.
    pub packet_sizes: Vec<f64>,
    /// Sparse exogenous input rates: (node, packets/sec).
    pub rates: Vec<(usize, f64)>,
    pub status: AppStatus,
}

impl AppSpec {
    /// Shape/range validation against an `n`-node topology.
    pub fn validate(&self, n: usize) -> anyhow::Result<()> {
        anyhow::ensure!(!self.id.is_empty(), "app id must be non-empty");
        let id_ok = self.id.len() <= 64
            && self
                .id
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
        anyhow::ensure!(
            id_ok,
            "app id '{}' must be <= 64 chars of [A-Za-z0-9._-]",
            self.id
        );
        anyhow::ensure!(
            self.dest < n,
            "app '{}': dest {} out of range (n={n})",
            self.id,
            self.dest
        );
        anyhow::ensure!(
            self.packet_sizes.len() == self.num_tasks + 1,
            "app '{}': {} packet sizes for {} tasks (need tasks + 1)",
            self.id,
            self.packet_sizes.len(),
            self.num_tasks
        );
        anyhow::ensure!(
            self.packet_sizes.iter().all(|&l| l > 0.0 && l.is_finite()),
            "app '{}': packet sizes must be positive and finite",
            self.id
        );
        anyhow::ensure!(
            !self.rates.is_empty(),
            "app '{}': needs at least one source",
            self.id
        );
        for &(node, rate) in &self.rates {
            anyhow::ensure!(
                node < n,
                "app '{}': source node {node} out of range",
                self.id
            );
            anyhow::ensure!(
                rate >= 0.0 && rate.is_finite(),
                "app '{}': rate at node {node} must be finite and >= 0",
                self.id
            );
        }
        let mut nodes: Vec<usize> = self.rates.iter().map(|&(i, _)| i).collect();
        nodes.sort_unstable();
        nodes.dedup();
        anyhow::ensure!(
            nodes.len() == self.rates.len(),
            "app '{}': duplicate source node",
            self.id
        );
        Ok(())
    }

    /// Densify into an [`Application`]; a draining app's rates are zeroed.
    pub fn application(&self, n: usize) -> Application {
        let mut input_rates = vec![0.0; n];
        if self.status == AppStatus::Active {
            for &(node, rate) in &self.rates {
                input_rates[node] = rate;
            }
        }
        Application {
            dest: self.dest,
            num_tasks: self.num_tasks,
            packet_sizes: self.packet_sizes.clone(),
            input_rates,
        }
    }

    /// Total offered input rate (zero while draining).
    pub fn total_rate(&self) -> f64 {
        if self.status == AppStatus::Active {
            self.rates.iter().map(|&(_, r)| r).sum()
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("dest", Json::Num(self.dest as f64)),
            ("num_tasks", Json::Num(self.num_tasks as f64)),
            ("packet_sizes", Json::arr_f64(&self.packet_sizes)),
            (
                "rates",
                Json::Arr(
                    self.rates
                        .iter()
                        .map(|&(i, r)| Json::Arr(vec![Json::Num(i as f64), Json::Num(r)]))
                        .collect(),
                ),
            ),
            ("status", Json::Str(self.status.name().into())),
        ])
    }

    /// Parse an app spec from JSON (the `POST /apps` body and the snapshot
    /// format). `rates` accepts `[[node, rate], ...]`; `packet_sizes`
    /// defaults to the Table-II schedule (10/5/1-style decay) when absent.
    pub fn from_json(v: &Json) -> anyhow::Result<AppSpec> {
        let id = v
            .get("id")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("app spec: missing 'id'"))?
            .to_string();
        let dest = v
            .get("dest")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("app spec '{id}': missing 'dest'"))?;
        let num_tasks = v.get("num_tasks").and_then(Json::as_usize).unwrap_or(2);
        let packet_sizes: Vec<f64> = match v.get("packet_sizes").and_then(Json::as_arr) {
            Some(arr) => arr.iter().filter_map(Json::as_f64).collect(),
            None => (0..=num_tasks)
                .map(|k| (10.0 - 5.0 * k as f64).max(1.0))
                .collect(),
        };
        let mut rates = Vec::new();
        for pair in v
            .get("rates")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("app spec '{id}': missing 'rates'"))?
        {
            let pair = pair
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| anyhow::anyhow!("app spec '{id}': rates entries are [node, rate]"))?;
            let node = pair[0]
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("app spec '{id}': bad source node"))?;
            let rate = pair[1]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("app spec '{id}': bad source rate"))?;
            rates.push((node, rate));
        }
        let status = match v.get("status").and_then(Json::as_str) {
            Some("draining") => AppStatus::Draining,
            _ => AppStatus::Active,
        };
        Ok(AppSpec {
            id,
            dest,
            num_tasks,
            packet_sizes,
            rates,
            status,
        })
    }
}

/// The registry of applications currently on (or draining from) the system.
#[derive(Clone, Debug, Default)]
pub struct AppCatalog {
    /// Registration order — application index order in the built network.
    apps: Vec<AppSpec>,
}

impl AppCatalog {
    pub fn new() -> AppCatalog {
        AppCatalog::default()
    }

    /// Seed a catalog from an already-built network's applications, ids
    /// `app-0` … `app-{k-1}` (the control plane's bootstrap import: the
    /// catalog rebuild then reproduces the network it was imported from).
    pub fn import_network(net: &Network) -> AppCatalog {
        let apps = net
            .apps
            .iter()
            .enumerate()
            .map(|(a, app)| AppSpec {
                id: format!("app-{a}"),
                dest: app.dest,
                num_tasks: app.num_tasks,
                packet_sizes: app.packet_sizes.clone(),
                rates: app
                    .input_rates
                    .iter()
                    .enumerate()
                    .filter(|&(_, &r)| r > 0.0)
                    .map(|(i, &r)| (i, r))
                    .collect(),
                status: AppStatus::Active,
            })
            .collect();
        AppCatalog { apps }
    }

    pub fn len(&self) -> usize {
        self.apps.len()
    }
    pub fn is_empty(&self) -> bool {
        self.apps.is_empty()
    }
    pub fn iter(&self) -> impl Iterator<Item = &AppSpec> {
        self.apps.iter()
    }
    pub fn get(&self, id: &str) -> Option<&AppSpec> {
        self.apps.iter().find(|a| a.id == id)
    }
    /// Current ids in application-index order.
    pub fn ids(&self) -> Vec<String> {
        self.apps.iter().map(|a| a.id.clone()).collect()
    }

    /// Register a new application (id must be unused).
    pub fn register(&mut self, spec: AppSpec) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.get(&spec.id).is_none(),
            "app '{}' already registered",
            spec.id
        );
        self.apps.push(spec);
        Ok(())
    }

    /// Replace an existing application's spec in place (same index).
    pub fn update(&mut self, spec: AppSpec) -> anyhow::Result<()> {
        let slot = self
            .apps
            .iter_mut()
            .find(|a| a.id == spec.id)
            .ok_or_else(|| anyhow::anyhow!("app '{}' is not registered", spec.id))?;
        *slot = spec;
        Ok(())
    }

    /// Stop an app's traffic (rates forced to zero) while keeping it in the
    /// network so in-flight work drains through its φ rows.
    pub fn drain(&mut self, id: &str) -> anyhow::Result<()> {
        let app = self
            .apps
            .iter_mut()
            .find(|a| a.id == id)
            .ok_or_else(|| anyhow::anyhow!("app '{id}' is not registered"))?;
        app.status = AppStatus::Draining;
        Ok(())
    }

    /// Remove an app entirely (its φ rows disappear at the next rebuild).
    pub fn remove(&mut self, id: &str) -> anyhow::Result<()> {
        let before = self.apps.len();
        self.apps.retain(|a| a.id != id);
        anyhow::ensure!(self.apps.len() < before, "app '{id}' is not registered");
        Ok(())
    }

    /// Densify the fleet in catalog order.
    pub fn applications(&self, n: usize) -> Vec<Application> {
        self.apps.iter().map(|a| a.application(n)).collect()
    }

    /// Assemble the current fleet into a network on the control plane's
    /// fixed topology. Cost functions and computation weights follow the
    /// scenario's recipe (w_i(a,k) = comp_weight · L_(a,k)), so a catalog
    /// imported from a scenario build reproduces that network exactly.
    pub fn build_network(&self, sc: &Scenario, graph: &Graph) -> anyhow::Result<Network> {
        let n = graph.n();
        for app in &self.apps {
            app.validate(n)?;
        }
        let apps = self.applications(n);
        let stages = StageRegistry::new(&apps);
        let comp_weight = stages
            .iter()
            .map(|(_s, (a, k))| {
                let w = if k < apps[a].num_tasks {
                    sc.comp_weight * apps[a].packet_sizes[k]
                } else {
                    0.0
                };
                vec![w; n]
            })
            .collect();
        let link_cost = (0..graph.m())
            .map(|_| sc.link_kind.instantiate(sc.link_param))
            .collect();
        let comp_cost = (0..n).map(|_| sc.comp_kind.instantiate(sc.comp_param)).collect();
        Network::new(graph.clone(), apps, link_cost, comp_cost, comp_weight)
    }

    /// For each id in `old_ids` (a previous epoch's application order), the
    /// app's index in THIS catalog, or `None` if it was removed.
    pub fn remap(&self, old_ids: &[String]) -> Vec<Option<usize>> {
        old_ids
            .iter()
            .map(|id| self.apps.iter().position(|a| &a.id == id))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.apps.iter().map(AppSpec::to_json).collect())
    }

    pub fn from_json(v: &Json) -> anyhow::Result<AppCatalog> {
        let mut catalog = AppCatalog::new();
        for av in v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("catalog: expected an array of app specs"))?
        {
            catalog.register(AppSpec::from_json(av)?)?;
        }
        Ok(catalog)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::util::rng::Rng;

    fn scaffold() -> (Scenario, Graph, AppCatalog) {
        let sc = Scenario::table2("abilene").unwrap();
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng).unwrap();
        let graph = net.graph.clone();
        let catalog = AppCatalog::import_network(&net);
        (sc, graph, catalog)
    }

    #[test]
    fn import_then_rebuild_reproduces_the_network() {
        let sc = Scenario::table2("abilene").unwrap();
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng).unwrap();
        let catalog = AppCatalog::import_network(&net);
        let rebuilt = catalog.build_network(&sc, &net.graph).unwrap();
        assert_eq!(rebuilt.num_stages(), net.num_stages());
        for (a, b) in net.apps.iter().zip(&rebuilt.apps) {
            assert_eq!(a.dest, b.dest);
            assert_eq!(a.input_rates, b.input_rates);
            assert_eq!(a.packet_sizes, b.packet_sizes);
        }
        assert_eq!(net.comp_weight, rebuilt.comp_weight);
    }

    #[test]
    fn lifecycle_register_drain_remove() {
        let (sc, graph, mut catalog) = scaffold();
        let k = catalog.len();
        let spec = AppSpec {
            id: "video".into(),
            dest: 3,
            num_tasks: 2,
            packet_sizes: vec![10.0, 5.0, 1.0],
            rates: vec![(0, 0.4), (7, 0.2)],
            status: AppStatus::Active,
        };
        catalog.register(spec.clone()).unwrap();
        assert!(catalog.register(spec).is_err(), "duplicate id rejected");
        assert_eq!(catalog.len(), k + 1);
        let net = catalog.build_network(&sc, &graph).unwrap();
        assert_eq!(net.apps.len(), k + 1);
        assert_eq!(net.apps[k].input_rates[0], 0.4);

        catalog.drain("video").unwrap();
        let net = catalog.build_network(&sc, &graph).unwrap();
        assert_eq!(net.apps.len(), k + 1, "draining apps stay in the network");
        assert!(net.apps[k].input_rates.iter().all(|&r| r == 0.0));

        catalog.remove("video").unwrap();
        assert_eq!(catalog.len(), k);
        assert!(catalog.drain("video").is_err());
        assert!(catalog.remove("video").is_err());
    }

    #[test]
    fn remap_tracks_surviving_apps() {
        let (_sc, _graph, mut catalog) = scaffold();
        let old_ids = catalog.ids();
        catalog.remove(&old_ids[1]).unwrap();
        catalog
            .register(AppSpec {
                id: "late".into(),
                dest: 0,
                num_tasks: 1,
                packet_sizes: vec![4.0, 1.0],
                rates: vec![(5, 0.1)],
                status: AppStatus::Active,
            })
            .unwrap();
        let remap = catalog.remap(&old_ids);
        assert_eq!(remap[0], Some(0));
        assert_eq!(remap[1], None, "removed app has no new index");
        assert_eq!(remap[2], Some(1), "later apps shift down");
        assert_eq!(catalog.get("late").map(|_| ()), Some(()));
    }

    #[test]
    fn spec_validation_rejects_bad_shapes() {
        let ok = AppSpec {
            id: "x".into(),
            dest: 2,
            num_tasks: 1,
            packet_sizes: vec![2.0, 1.0],
            rates: vec![(0, 1.0)],
            status: AppStatus::Active,
        };
        ok.validate(5).unwrap();
        let mut bad = ok.clone();
        bad.dest = 9;
        assert!(bad.validate(5).is_err());
        let mut bad = ok.clone();
        bad.packet_sizes = vec![2.0];
        assert!(bad.validate(5).is_err());
        let mut bad = ok.clone();
        bad.rates = vec![(0, 1.0), (0, 2.0)];
        assert!(bad.validate(5).is_err(), "duplicate source");
        let mut bad = ok.clone();
        bad.rates = vec![(0, -1.0)];
        assert!(bad.validate(5).is_err());
        let mut bad = ok;
        bad.id = "spaces not ok".into();
        assert!(bad.validate(5).is_err());
    }

    #[test]
    fn catalog_json_roundtrip() {
        let (_sc, _graph, mut catalog) = scaffold();
        catalog.drain(&catalog.ids()[0]).unwrap();
        let text = catalog.to_json().to_string_pretty();
        let re = AppCatalog::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(re.len(), catalog.len());
        for (a, b) in catalog.iter().zip(re.iter()) {
            assert_eq!(a, b);
        }
        assert_eq!(re.iter().next().unwrap().status, AppStatus::Draining);
    }
}
