//! Versioned checkpoint/restore for the control plane.
//!
//! A snapshot is one JSON document capturing everything the serving loop
//! needs to resume **bit-identically** (pinned by `rust/tests/control.rs`):
//!
//! * the network spec — the base [`crate::config::Scenario`] scaffold
//!   (topology + cost families; its graph rebuilds deterministically from
//!   the seed) and the [`crate::control::AppCatalog`] fleet with lifecycle
//!   states,
//! * the live strategy φ (CSR arena rows; f64 values round-trip losslessly
//!   through [`crate::util::json`]) and the optimizer step size,
//! * the serving state — rate estimates, slot counter, delay histogram,
//!   full workload state (per-stream model parameters + evolution state
//!   such as the MMPP phase, and raw RNG words), and the adaptation
//!   controller's EWMA/CUSUM/oracle state when attached,
//! * the link-churn state (version 2) — removed link pairs and the pending
//!   repair schedule, so a run restored mid-flap rebuilds the same pruned
//!   CSR arena and repairs on the same slot,
//! * the control-plane epoch and admission counters.
//!
//! Writes are atomic: the document lands in `snapshot.json.tmp` and is
//! renamed over `snapshot.json`, so a crash mid-write never corrupts the
//! last good checkpoint. Readers accept exactly the versions they know
//! ([`SNAPSHOT_VERSION`]) and reject anything newer — the same policy as
//! the trace format (`docs/WORKLOADS.md`).

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Current snapshot format version. Version 2 added the optional
/// `topology` key (link-churn state); version-1 snapshots still load.
pub const SNAPSHOT_VERSION: u64 = 2;

/// File name of the live snapshot inside a checkpoint directory.
pub const SNAPSHOT_FILE: &str = "snapshot.json";

/// Path of the snapshot document inside `dir`.
pub fn snapshot_path(dir: &Path) -> PathBuf {
    dir.join(SNAPSHOT_FILE)
}

/// Atomically persist a snapshot document into `dir` (created if missing):
/// write `snapshot.json.tmp`, fsync-free rename over `snapshot.json`.
/// Returns the final path.
pub fn write_atomic(dir: &Path, doc: &Json) -> anyhow::Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("checkpoint dir {}: {e}", dir.display()))?;
    let final_path = snapshot_path(dir);
    let tmp = dir.join(format!("{SNAPSHOT_FILE}.tmp"));
    std::fs::write(&tmp, doc.to_string_pretty())
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &final_path)
        .map_err(|e| anyhow::anyhow!("renaming {} into place: {e}", tmp.display()))?;
    Ok(final_path)
}

/// Load and version-check the snapshot document from `dir`.
pub fn load(dir: &Path) -> anyhow::Result<Json> {
    let path = snapshot_path(dir);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let version = doc
        .get("version")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("{}: missing 'version'", path.display()))? as u64;
    anyhow::ensure!(
        version <= SNAPSHOT_VERSION,
        "{}: snapshot version {version} is newer than this binary understands ({SNAPSHOT_VERSION})",
        path.display()
    );
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("scfo-snap-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn write_is_atomic_and_loads_back() {
        let dir = tmp_dir("atomic");
        let doc = Json::obj(vec![
            ("version", Json::Num(SNAPSHOT_VERSION as f64)),
            ("epoch", Json::Num(3.0)),
        ]);
        let path = write_atomic(&dir, &doc).unwrap();
        assert!(path.ends_with(SNAPSHOT_FILE));
        assert!(!dir.join("snapshot.json.tmp").exists(), "tmp file renamed away");
        let re = load(&dir).unwrap();
        assert_eq!(re.get("epoch").unwrap().as_usize(), Some(3));
        // overwrite in place (the periodic checkpoint path)
        let doc2 = Json::obj(vec![
            ("version", Json::Num(SNAPSHOT_VERSION as f64)),
            ("epoch", Json::Num(4.0)),
        ]);
        write_atomic(&dir, &doc2).unwrap();
        assert_eq!(load(&dir).unwrap().get("epoch").unwrap().as_usize(), Some(4));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn newer_versions_are_rejected() {
        let dir = tmp_dir("version");
        let doc = Json::obj(vec![(
            "version",
            Json::Num((SNAPSHOT_VERSION + 1) as f64),
        )]);
        write_atomic(&dir, &doc).unwrap();
        let err = load(&dir).unwrap_err().to_string();
        assert!(err.contains("newer"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_snapshot_is_a_clean_error() {
        let dir = tmp_dir("missing");
        assert!(load(&dir).is_err());
    }
}
