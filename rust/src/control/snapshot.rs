//! Versioned checkpoint/restore for the control plane.
//!
//! A snapshot is one JSON document capturing everything the serving loop
//! needs to resume **bit-identically** (pinned by `rust/tests/control.rs`):
//!
//! * the network spec — the base [`crate::config::Scenario`] scaffold
//!   (topology + cost families; its graph rebuilds deterministically from
//!   the seed) and the [`crate::control::AppCatalog`] fleet with lifecycle
//!   states,
//! * the live strategy φ (CSR arena rows; f64 values round-trip losslessly
//!   through [`crate::util::json`]) and the optimizer step size,
//! * the serving state — rate estimates, slot counter, delay histogram,
//!   full workload state (per-stream model parameters + evolution state
//!   such as the MMPP phase, and raw RNG words), and the adaptation
//!   controller's EWMA/CUSUM/oracle state when attached,
//! * the link-churn state (version 2) — removed link pairs and the pending
//!   repair schedule, so a run restored mid-flap rebuilds the same pruned
//!   CSR arena and repairs on the same slot,
//! * the replication state (version 3) — the replica's persistent
//!   consensus state (term, vote, commit index, log tail; see
//!   [`crate::control::replication`]), so a restarted replica rejoins the
//!   group without re-fetching the whole log,
//! * the control-plane epoch and admission counters.
//!
//! Writes are atomic: the document lands in a uniquely named temp file
//! (pid + process-wide counter, so co-located replicas checkpointing into
//! the same directory never interleave halves of two documents) and is
//! renamed over `snapshot.json` — a crash mid-write never corrupts the
//! last good checkpoint. Replicated deployments go one step further and
//! give each replica its own subdirectory ([`replica_dir`]), keeping the
//! checkpoints themselves independent. Readers accept exactly the versions
//! they know ([`SNAPSHOT_VERSION`]) and reject anything newer — the same
//! policy as the trace format (`docs/WORKLOADS.md`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Current snapshot format version. Version 2 added the optional
/// `topology` key (link-churn state); version 3 the optional `replication`
/// key (persistent consensus state). Older snapshots still load.
pub const SNAPSHOT_VERSION: u64 = 3;

/// File name of the live snapshot inside a checkpoint directory.
pub const SNAPSHOT_FILE: &str = "snapshot.json";

/// Monotone process-wide suffix for temp files: two threads (or two
/// replicas in one test process) writing into the same directory get
/// distinct temp names.
static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Path of the snapshot document inside `dir`.
pub fn snapshot_path(dir: &Path) -> PathBuf {
    dir.join(SNAPSHOT_FILE)
}

/// Replica `id`'s private checkpoint directory under a shared
/// `--checkpoint DIR`: `DIR/replica-<id>`. Co-located replicas must not
/// share a snapshot file — their logs/terms genuinely differ.
pub fn replica_dir(dir: &Path, id: usize) -> PathBuf {
    dir.join(format!("replica-{id}"))
}

/// Atomically persist a snapshot document into `dir` (created if missing):
/// write a uniquely named `snapshot.json.<pid>.<k>.tmp`, rename over
/// `snapshot.json`. Returns the final path.
pub fn write_atomic(dir: &Path, doc: &Json) -> anyhow::Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .map_err(|e| anyhow::anyhow!("checkpoint dir {}: {e}", dir.display()))?;
    let final_path = snapshot_path(dir);
    let k = TMP_COUNTER.fetch_add(1, Ordering::Relaxed);
    let tmp = dir.join(format!(
        "{SNAPSHOT_FILE}.{}.{k}.tmp",
        std::process::id()
    ));
    std::fs::write(&tmp, doc.to_string_pretty())
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, &final_path)
        .map_err(|e| anyhow::anyhow!("renaming {} into place: {e}", tmp.display()))?;
    Ok(final_path)
}

/// Load the snapshot document from `dir`, accepting versions up to
/// `max_version`.
pub fn load_with_limit(dir: &Path, max_version: u64) -> anyhow::Result<Json> {
    let path = snapshot_path(dir);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    let doc = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let version = doc
        .get("version")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("{}: missing 'version'", path.display()))? as u64;
    anyhow::ensure!(
        version <= max_version,
        "{}: snapshot version {version} is newer than this binary understands ({max_version})",
        path.display()
    );
    Ok(doc)
}

/// Load and version-check the snapshot document from `dir`.
pub fn load(dir: &Path) -> anyhow::Result<Json> {
    load_with_limit(dir, SNAPSHOT_VERSION)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("scfo-snap-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn write_is_atomic_and_loads_back() {
        let dir = tmp_dir("atomic");
        let doc = Json::obj(vec![
            ("version", Json::Num(SNAPSHOT_VERSION as f64)),
            ("epoch", Json::Num(3.0)),
        ]);
        let path = write_atomic(&dir, &doc).unwrap();
        assert!(path.ends_with(SNAPSHOT_FILE));
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "tmp files renamed away: {leftovers:?}");
        let re = load(&dir).unwrap();
        assert_eq!(re.get("epoch").unwrap().as_usize(), Some(3));
        // overwrite in place (the periodic checkpoint path)
        let doc2 = Json::obj(vec![
            ("version", Json::Num(SNAPSHOT_VERSION as f64)),
            ("epoch", Json::Num(4.0)),
        ]);
        write_atomic(&dir, &doc2).unwrap();
        assert_eq!(load(&dir).unwrap().get("epoch").unwrap().as_usize(), Some(4));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn newer_versions_are_rejected() {
        let dir = tmp_dir("version");
        let doc = Json::obj(vec![(
            "version",
            Json::Num((SNAPSHOT_VERSION + 1) as f64),
        )]);
        write_atomic(&dir, &doc).unwrap();
        let err = load(&dir).unwrap_err().to_string();
        assert!(err.contains("newer"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A v2-era reader (max_version 2) must reject today's v3 documents —
    /// the forward-compatibility contract the version bump relies on.
    #[test]
    fn v2_readers_reject_v3_snapshots() {
        let dir = tmp_dir("v2-reject");
        let doc = Json::obj(vec![
            ("version", Json::Num(3.0)),
            ("replication", Json::obj(vec![("term", Json::Num(1.0))])),
        ]);
        write_atomic(&dir, &doc).unwrap();
        let err = load_with_limit(&dir, 2).unwrap_err().to_string();
        assert!(err.contains("newer"), "{err}");
        assert!(load_with_limit(&dir, 3).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_snapshot_is_a_clean_error() {
        let dir = tmp_dir("missing");
        assert!(load(&dir).is_err());
    }

    /// Two writers hammering the same directory concurrently: every load
    /// observes one complete, parseable document (never an interleaving of
    /// two), and no temp files survive.
    #[test]
    fn concurrent_writers_never_clobber_each_other() {
        let dir = tmp_dir("concurrent");
        std::fs::create_dir_all(&dir).unwrap();
        let payload = |writer: usize, k: usize| {
            Json::obj(vec![
                ("version", Json::Num(SNAPSHOT_VERSION as f64)),
                ("writer", Json::Num(writer as f64)),
                ("k", Json::Num(k as f64)),
                // bulk so a torn write would be visible as a parse error
                ("bulk", Json::arr_f64(&vec![writer as f64; 512])),
            ])
        };
        std::thread::scope(|s| {
            for writer in 0..2 {
                let dir = dir.clone();
                s.spawn(move || {
                    for k in 0..40 {
                        write_atomic(&dir, &payload(writer, k)).unwrap();
                        let doc = load(&dir).unwrap();
                        let w = doc.get("writer").unwrap().as_usize().unwrap();
                        let bulk = doc.get("bulk").unwrap().as_arr().unwrap();
                        assert_eq!(bulk.len(), 512);
                        assert!(bulk.iter().all(|b| b.as_f64() == Some(w as f64)));
                    }
                });
            }
        });
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Per-replica subdirectories round-trip independently: replica 0's
    /// checkpoint never shows through replica 1's.
    #[test]
    fn replica_dirs_round_trip_independently() {
        let base = tmp_dir("replica-dirs");
        for id in 0..3usize {
            let doc = Json::obj(vec![
                ("version", Json::Num(SNAPSHOT_VERSION as f64)),
                ("epoch", Json::Num(id as f64 + 10.0)),
            ]);
            write_atomic(&replica_dir(&base, id), &doc).unwrap();
        }
        for id in 0..3usize {
            let doc = load(&replica_dir(&base, id)).unwrap();
            assert_eq!(doc.get("epoch").unwrap().as_usize(), Some(id + 10));
        }
        assert_ne!(replica_dir(&base, 0), replica_dir(&base, 1));
        let _ = std::fs::remove_dir_all(&base);
    }
}
