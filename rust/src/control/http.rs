//! Std-only HTTP/1.1 ops API — the system's first network-facing surface.
//!
//! A deliberately small server on [`std::net::TcpListener`] (no new
//! crates): the listener is non-blocking and the control plane polls it
//! *between serving slots* ([`OpsServer::poll`]), so every handler runs on
//! the serving thread with exclusive `&mut ControlPlane` access — no locks,
//! no handler/optimizer races, and request effects are ordered with slot
//! boundaries. Connections are `Connection: close`; bodies are bounded.
//!
//! | Method & path      | Effect                                              |
//! |--------------------|-----------------------------------------------------|
//! | `GET /healthz`     | liveness: `{"ok":true,"epoch":E,"slot":S}`          |
//! | `GET /status`      | epoch, fleet, cost, per-link/CPU utilization        |
//! | `GET /metrics`     | Prometheus text format ([`crate::metrics`])         |
//! | `GET /profile`     | flight-recorder snapshot as Chrome trace JSON       |
//! | `POST /apps`       | register (or update, if the id exists) an app spec; |
//! |                    | admission-checked — 200 accept / 409 reject         |
//! | `DELETE /apps/{id}`| drain an active app; a draining app is removed      |
//! | `POST /checkpoint` | atomic snapshot into the configured directory       |
//! | `GET /raftish`     | replica status (replicated deployments only)        |
//! | `POST /raftish/msg`| consensus message exchange between replicas         |
//!
//! Replicated deployments (`scfo serve --replica I --peers A,B,C`) poll
//! through [`OpsServer::poll_repl`]: mutating requests on the leader
//! replicate through the command log before they apply (an HTTP 200 means
//! the epoch is majority-committed), mutating requests on a follower
//! answer `307 Temporary Redirect` with a `Location` pointing at the
//! believed leader (`503` while no leader is known), and reads keep being
//! served locally from replicated state — which is exactly what lets a
//! follower keep answering `/status` after the leader dies.
//!
//! See `docs/CONTROL_PLANE.md` for the API reference with examples.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::time::Duration;

use crate::control::replication::{LiveReplica, ReplCommand, ReplMsg};
use crate::control::{AppStatus, ControlPlane};
use crate::util::json::Json;

/// Upper bound on request head + body we are willing to buffer.
const MAX_REQUEST_BYTES: usize = 1 << 20;

/// Per-connection socket timeout: a stalled client cannot stall serving
/// for longer than this.
const IO_TIMEOUT: Duration = Duration::from_millis(500);

/// The ops listener. Bind once, then [`OpsServer::poll`] between slots.
pub struct OpsServer {
    listener: TcpListener,
    addr: SocketAddr,
}

/// A parsed request.
struct Request {
    method: String,
    path: String,
    body: String,
}

impl OpsServer {
    /// Bind the ops API (e.g. `127.0.0.1:8080`; port 0 picks a free port).
    pub fn bind(addr: &str) -> anyhow::Result<OpsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind ops API on {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow::anyhow!("ops API listener: {e}"))?;
        let addr = listener.local_addr()?;
        Ok(OpsServer { listener, addr })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept and serve every connection currently queued; returns the
    /// number handled. Never blocks beyond the per-connection IO timeout.
    pub fn poll(
        &self,
        plane: &mut ControlPlane,
        checkpoint_dir: Option<&Path>,
    ) -> usize {
        self.poll_repl(plane, checkpoint_dir, None)
    }

    /// [`OpsServer::poll`] for a replicated deployment: consensus routes
    /// are live and mutating routes go through the command log (leader)
    /// or redirect to it (follower). With `repl = None` this is exactly
    /// `poll`.
    pub fn poll_repl(
        &self,
        plane: &mut ControlPlane,
        checkpoint_dir: Option<&Path>,
        mut repl: Option<&mut LiveReplica>,
    ) -> usize {
        if let Some(r) = repl.as_deref_mut() {
            plane.repl_gauges = Some((r.term(), r.commit_index()));
        }
        let mut handled = 0;
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    handled += 1;
                    plane.stats.http.counter("scfo_http_requests_total").inc();
                    if let Err(e) =
                        handle_connection(stream, plane, checkpoint_dir, repl.as_deref_mut())
                    {
                        plane.stats.http.counter("scfo_http_errors_total").inc();
                        crate::log_warn!("ops API connection error: {e}");
                    }
                    if let Some(r) = repl.as_deref_mut() {
                        plane.repl_gauges = Some((r.term(), r.commit_index()));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    crate::log_warn!("ops API accept error: {e}");
                    break;
                }
            }
        }
        handled
    }
}

fn handle_connection(
    mut stream: TcpStream,
    plane: &mut ControlPlane,
    checkpoint_dir: Option<&Path>,
    repl: Option<&mut LiveReplica>,
) -> anyhow::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let body = Json::obj(vec![("error", Json::Str(e.to_string()))]).to_string();
            let _ = respond(&mut stream, 400, "application/json", &body, None);
            return Ok(());
        }
    };
    let (code, content_type, body, location) = route(&req, plane, checkpoint_dir, repl);
    respond(&mut stream, code, content_type, &body, location.as_deref())
}

/// Parse one HTTP/1.1 request off the stream: request line, headers (only
/// `Content-Length` matters), body.
fn read_request(stream: &mut TcpStream) -> anyhow::Result<Request> {
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    let header_end = loop {
        let k = stream.read(&mut chunk)?;
        anyhow::ensure!(k > 0, "connection closed mid-request");
        buf.extend_from_slice(&chunk[..k]);
        anyhow::ensure!(buf.len() <= MAX_REQUEST_BYTES, "request too large");
        if let Some(pos) = find_header_end(&buf) {
            break pos;
        }
    };
    let head = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| anyhow::anyhow!("non-UTF8 request head"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request line"))?
        .to_ascii_uppercase();
    let path = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request line has no path"))?
        .to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap_or(0);
            }
        }
    }
    anyhow::ensure!(
        header_end + 4 + content_length <= MAX_REQUEST_BYTES,
        "request body too large"
    );
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let k = stream.read(&mut chunk)?;
        anyhow::ensure!(k > 0, "connection closed mid-body");
        body.extend_from_slice(&chunk[..k]);
    }
    body.truncate(content_length);
    Ok(Request {
        method,
        path,
        body: String::from_utf8(body).map_err(|_| anyhow::anyhow!("non-UTF8 body"))?,
    })
}

fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Dispatch a request against the control plane. Returns
/// (status, content type, body, optional Location header value).
fn route(
    req: &Request,
    plane: &mut ControlPlane,
    checkpoint_dir: Option<&Path>,
    mut repl: Option<&mut LiveReplica>,
) -> (u16, &'static str, String, Option<String>) {
    let json = |code: u16, v: Json| (code, "application/json", v.to_string_pretty(), None);
    let err = |code: u16, msg: String| {
        (
            code,
            "application/json",
            Json::obj(vec![("error", Json::Str(msg))]).to_string_pretty(),
            None,
        )
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => json(
            200,
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("version", Json::Str(crate::version().to_string())),
                ("epoch", Json::Num(plane.epoch() as f64)),
                ("slot", Json::Num(plane.slots_served() as f64)),
            ]),
        ),
        ("GET", "/status") => json(200, plane.status_json()),
        ("GET", "/metrics") => (
            200,
            "text/plain; version=0.0.4",
            plane.metrics_text(),
            None,
        ),
        // flight-recorder snapshot as Chrome trace-event JSON; an empty
        // array while tracing is disabled (still a valid trace document)
        ("GET", "/profile") => (
            200,
            "application/json",
            crate::obs::chrome_trace_json().to_string_pretty(),
            None,
        ),
        // replica status; 404 on an unreplicated plane so probes can tell
        // the deployments apart
        ("GET", "/raftish") => match repl {
            Some(r) => json(200, r.status_json()),
            None => err(404, "replication disabled (scfo serve --replica)".into()),
        },
        // consensus message exchange: feed the message into the state
        // machine, apply anything that committed, return the reply (JSON
        // null when the message produced none)
        ("POST", "/raftish/msg") => match repl {
            Some(r) => {
                let msg = match Json::parse(&req.body)
                    .map_err(|e| anyhow::anyhow!("{e}"))
                    .and_then(|v| ReplMsg::from_json(&v))
                {
                    Ok(m) => m,
                    Err(e) => return err(400, format!("bad consensus message: {e}")),
                };
                // a forged/corrupt sender id would index per-replica
                // tables; reject it at the edge instead of relying on the
                // state machine's own guard
                if msg.from() >= r.group_size() {
                    return err(
                        400,
                        format!(
                            "bad consensus message: sender id {} out of range for {} replicas",
                            msg.from(),
                            r.group_size()
                        ),
                    );
                }
                let (reply, committed) = r.handle_msg(msg);
                for cmd in &committed {
                    if let Err(e) = plane.apply_committed(cmd) {
                        crate::log_warn!("applying committed {} failed: {e}", cmd.op());
                    }
                }
                json(200, reply.map(|m| m.to_json()).unwrap_or(Json::Null))
            }
            None => err(404, "replication disabled (scfo serve --replica)".into()),
        },
        ("POST", "/apps") => {
            let spec = match Json::parse(&req.body)
                .map_err(|e| anyhow::anyhow!("{e}"))
                .and_then(|v| crate::control::AppSpec::from_json(&v))
            {
                Ok(s) => s,
                Err(e) => return err(400, format!("bad app spec: {e}")),
            };
            // replicated: the command must majority-commit before it
            // applies; followers redirect to the leader
            if let Some(r) = repl.as_deref_mut() {
                if !r.is_leader() {
                    return redirect_to_leader(r, "/apps");
                }
                let exists = plane.catalog.get(&spec.id).is_some();
                let (cmd, action) = if exists {
                    (ReplCommand::Update(spec), "update")
                } else {
                    (ReplCommand::Register(spec), "register")
                };
                return apply_replicated(r, plane, cmd, action);
            }
            let exists = plane.catalog.get(&spec.id).is_some();
            let outcome = if exists {
                plane.update(spec)
            } else {
                plane.register(spec)
            };
            match outcome {
                Ok(decision) => {
                    let code = if decision.accepted() { 200 } else { 409 };
                    let mut doc = match decision.to_json() {
                        Json::Obj(o) => o,
                        _ => unreachable!("decision serializes to an object"),
                    };
                    doc.insert("epoch".into(), Json::Num(plane.epoch() as f64));
                    doc.insert(
                        "action".into(),
                        Json::Str(if exists { "update" } else { "register" }.into()),
                    );
                    json(code, Json::Obj(doc))
                }
                Err(e) => err(400, e.to_string()),
            }
        }
        ("POST", "/checkpoint") => match checkpoint_dir {
            Some(dir) => {
                // a replica checkpoints into its own subdirectory and the
                // document carries its persistent consensus state (v3)
                let outcome = match repl.as_deref() {
                    Some(r) => plane.checkpoint_replicated(dir, r),
                    None => plane.checkpoint(dir),
                };
                match outcome {
                    Ok(path) => json(
                        200,
                        Json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("path", Json::Str(path.display().to_string())),
                            ("epoch", Json::Num(plane.epoch() as f64)),
                            ("slot", Json::Num(plane.slots_served() as f64)),
                        ]),
                    ),
                    Err(e) => err(500, format!("checkpoint failed: {e}")),
                }
            }
            None => err(
                409,
                "no checkpoint directory configured (scfo serve --checkpoint DIR)".into(),
            ),
        },
        ("DELETE", path) if path.starts_with("/apps/") => {
            let id = &path["/apps/".len()..];
            if let Some(r) = repl.as_deref_mut() {
                if !r.is_leader() {
                    return redirect_to_leader(r, path);
                }
                let Some(app) = plane.catalog.get(id) else {
                    return err(404, format!("app '{id}' is not registered"));
                };
                let (cmd, action) = if app.status == AppStatus::Active {
                    (ReplCommand::Drain(id.to_string()), "draining")
                } else {
                    (ReplCommand::Remove(id.to_string()), "removed")
                };
                return apply_replicated(r, plane, cmd, action);
            }
            let Some(app) = plane.catalog.get(id) else {
                return err(404, format!("app '{id}' is not registered"));
            };
            // two-step teardown: an active app drains first; deleting a
            // draining app removes it
            let outcome = if app.status == AppStatus::Active {
                plane.drain(id).map(|()| "draining")
            } else {
                plane.remove(id).map(|()| "removed")
            };
            match outcome {
                Ok(state) => json(
                    200,
                    Json::obj(vec![
                        ("id", Json::Str(id.to_string())),
                        ("state", Json::Str(state.into())),
                        ("epoch", Json::Num(plane.epoch() as f64)),
                    ]),
                ),
                Err(e) => err(500, e.to_string()),
            }
        }
        ("GET", _) | ("POST", _) | ("DELETE", _) => err(404, format!("no route {} {}", req.method, req.path)),
        _ => err(405, format!("method {} not allowed", req.method)),
    }
}

/// Follower answer for a mutating request: `307` + `Location` at the
/// believed leader, or `503` while no leader is known.
fn redirect_to_leader(
    r: &LiveReplica,
    path: &str,
) -> (u16, &'static str, String, Option<String>) {
    match r.leader_addr() {
        Some(addr) => (
            307,
            "application/json",
            Json::obj(vec![
                ("error", Json::Str("not the leader".into())),
                ("leader", Json::Str(addr.to_string())),
            ])
            .to_string_pretty(),
            Some(format!("http://{addr}{path}")),
        ),
        None => (
            503,
            "application/json",
            Json::obj(vec![(
                "error",
                Json::Str("no known leader for this replica group".into()),
            )])
            .to_string_pretty(),
            None,
        ),
    }
}

/// Leader side of a mutating request: replicate `cmd` through the log,
/// apply everything that committed, and answer from the outcome of the
/// last committed command (ours). `503` when no quorum acknowledges.
fn apply_replicated(
    r: &mut LiveReplica,
    plane: &mut ControlPlane,
    cmd: ReplCommand,
    action: &str,
) -> (u16, &'static str, String, Option<String>) {
    let op = cmd.op();
    match r.replicate(cmd) {
        Ok(committed) => {
            let mut outcome = Json::Null;
            for c in &committed {
                match plane.apply_committed(c) {
                    Ok(doc) => outcome = doc,
                    Err(e) => {
                        return (
                            500,
                            "application/json",
                            Json::obj(vec![(
                                "error",
                                Json::Str(format!("committed '{op}' failed to apply: {e}")),
                            )])
                            .to_string_pretty(),
                            None,
                        )
                    }
                }
            }
            let code = match outcome.get("accepted").and_then(Json::as_bool) {
                Some(false) => 409,
                _ => 200,
            };
            let mut doc = match outcome {
                Json::Obj(o) => o,
                _ => std::collections::BTreeMap::new(),
            };
            doc.insert("action".into(), Json::Str(action.to_string()));
            doc.insert("term".into(), Json::from_u64(r.term()));
            doc.insert("commit".into(), Json::from_u64(r.commit_index()));
            (
                code,
                "application/json",
                Json::Obj(doc).to_string_pretty(),
                None,
            )
        }
        Err(e) => (
            503,
            "application/json",
            Json::obj(vec![(
                "error",
                Json::Str(format!("replication failed: {e}")),
            )])
            .to_string_pretty(),
            None,
        ),
    }
}

fn respond(
    stream: &mut TcpStream,
    code: u16,
    content_type: &str,
    body: &str,
    location: Option<&str>,
) -> anyhow::Result<()> {
    let reason = match code {
        200 => "OK",
        307 => "Temporary Redirect",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    let location_header = match location {
        Some(l) => format!("Location: {l}\r\n"),
        None => String::new(),
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n{location_header}Connection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}
