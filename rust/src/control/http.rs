//! Std-only HTTP/1.1 ops API — the system's first network-facing surface.
//!
//! A deliberately small server on [`std::net::TcpListener`] (no new
//! crates): the listener is non-blocking and the control plane polls it
//! *between serving slots* ([`OpsServer::poll`]), so every handler runs on
//! the serving thread with exclusive `&mut ControlPlane` access — no locks,
//! no handler/optimizer races, and request effects are ordered with slot
//! boundaries. Connections are `Connection: close`; bodies are bounded.
//!
//! | Method & path      | Effect                                              |
//! |--------------------|-----------------------------------------------------|
//! | `GET /healthz`     | liveness: `{"ok":true,"epoch":E,"slot":S}`          |
//! | `GET /status`      | epoch, fleet, cost, per-link/CPU utilization        |
//! | `GET /metrics`     | Prometheus text format ([`crate::metrics`])         |
//! | `GET /profile`     | flight-recorder snapshot as Chrome trace JSON       |
//! | `POST /apps`       | register (or update, if the id exists) an app spec; |
//! |                    | admission-checked — 200 accept / 409 reject         |
//! | `DELETE /apps/{id}`| drain an active app; a draining app is removed      |
//! | `POST /checkpoint` | atomic snapshot into the configured directory       |
//!
//! See `docs/CONTROL_PLANE.md` for the API reference with examples.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::time::Duration;

use crate::control::{AppStatus, ControlPlane};
use crate::util::json::Json;

/// Upper bound on request head + body we are willing to buffer.
const MAX_REQUEST_BYTES: usize = 1 << 20;

/// Per-connection socket timeout: a stalled client cannot stall serving
/// for longer than this.
const IO_TIMEOUT: Duration = Duration::from_millis(500);

/// The ops listener. Bind once, then [`OpsServer::poll`] between slots.
pub struct OpsServer {
    listener: TcpListener,
    addr: SocketAddr,
}

/// A parsed request.
struct Request {
    method: String,
    path: String,
    body: String,
}

impl OpsServer {
    /// Bind the ops API (e.g. `127.0.0.1:8080`; port 0 picks a free port).
    pub fn bind(addr: &str) -> anyhow::Result<OpsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind ops API on {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| anyhow::anyhow!("ops API listener: {e}"))?;
        let addr = listener.local_addr()?;
        Ok(OpsServer { listener, addr })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept and serve every connection currently queued; returns the
    /// number handled. Never blocks beyond the per-connection IO timeout.
    pub fn poll(
        &self,
        plane: &mut ControlPlane,
        checkpoint_dir: Option<&Path>,
    ) -> usize {
        let mut handled = 0;
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    handled += 1;
                    plane.stats.http.counter("scfo_http_requests_total").inc();
                    if let Err(e) = handle_connection(stream, plane, checkpoint_dir) {
                        plane.stats.http.counter("scfo_http_errors_total").inc();
                        crate::log_warn!("ops API connection error: {e}");
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => {
                    crate::log_warn!("ops API accept error: {e}");
                    break;
                }
            }
        }
        handled
    }
}

fn handle_connection(
    mut stream: TcpStream,
    plane: &mut ControlPlane,
    checkpoint_dir: Option<&Path>,
) -> anyhow::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let req = match read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            let body = Json::obj(vec![("error", Json::Str(e.to_string()))]).to_string();
            let _ = respond(&mut stream, 400, "application/json", &body);
            return Ok(());
        }
    };
    let (code, content_type, body) = route(&req, plane, checkpoint_dir);
    respond(&mut stream, code, content_type, &body)
}

/// Parse one HTTP/1.1 request off the stream: request line, headers (only
/// `Content-Length` matters), body.
fn read_request(stream: &mut TcpStream) -> anyhow::Result<Request> {
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    let header_end = loop {
        let k = stream.read(&mut chunk)?;
        anyhow::ensure!(k > 0, "connection closed mid-request");
        buf.extend_from_slice(&chunk[..k]);
        anyhow::ensure!(buf.len() <= MAX_REQUEST_BYTES, "request too large");
        if let Some(pos) = find_header_end(&buf) {
            break pos;
        }
    };
    let head = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| anyhow::anyhow!("non-UTF8 request head"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request line"))?
        .to_ascii_uppercase();
    let path = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("request line has no path"))?
        .to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().unwrap_or(0);
            }
        }
    }
    anyhow::ensure!(
        header_end + 4 + content_length <= MAX_REQUEST_BYTES,
        "request body too large"
    );
    let mut body = buf[header_end + 4..].to_vec();
    while body.len() < content_length {
        let k = stream.read(&mut chunk)?;
        anyhow::ensure!(k > 0, "connection closed mid-body");
        body.extend_from_slice(&chunk[..k]);
    }
    body.truncate(content_length);
    Ok(Request {
        method,
        path,
        body: String::from_utf8(body).map_err(|_| anyhow::anyhow!("non-UTF8 body"))?,
    })
}

fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Dispatch a request against the control plane. Returns
/// (status, content type, body).
fn route(
    req: &Request,
    plane: &mut ControlPlane,
    checkpoint_dir: Option<&Path>,
) -> (u16, &'static str, String) {
    let json = |code: u16, v: Json| (code, "application/json", v.to_string_pretty());
    let err = |code: u16, msg: String| {
        (
            code,
            "application/json",
            Json::obj(vec![("error", Json::Str(msg))]).to_string_pretty(),
        )
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => json(
            200,
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("version", Json::Str(crate::version().to_string())),
                ("epoch", Json::Num(plane.epoch() as f64)),
                ("slot", Json::Num(plane.slots_served() as f64)),
            ]),
        ),
        ("GET", "/status") => json(200, plane.status_json()),
        ("GET", "/metrics") => (200, "text/plain; version=0.0.4", plane.metrics_text()),
        // flight-recorder snapshot as Chrome trace-event JSON; an empty
        // array while tracing is disabled (still a valid trace document)
        ("GET", "/profile") => (
            200,
            "application/json",
            crate::obs::chrome_trace_json().to_string_pretty(),
        ),
        ("POST", "/apps") => {
            let spec = match Json::parse(&req.body)
                .map_err(|e| anyhow::anyhow!("{e}"))
                .and_then(|v| crate::control::AppSpec::from_json(&v))
            {
                Ok(s) => s,
                Err(e) => return err(400, format!("bad app spec: {e}")),
            };
            let exists = plane.catalog.get(&spec.id).is_some();
            let outcome = if exists {
                plane.update(spec)
            } else {
                plane.register(spec)
            };
            match outcome {
                Ok(decision) => {
                    let code = if decision.accepted() { 200 } else { 409 };
                    let mut doc = match decision.to_json() {
                        Json::Obj(o) => o,
                        _ => unreachable!("decision serializes to an object"),
                    };
                    doc.insert("epoch".into(), Json::Num(plane.epoch() as f64));
                    doc.insert(
                        "action".into(),
                        Json::Str(if exists { "update" } else { "register" }.into()),
                    );
                    json(code, Json::Obj(doc))
                }
                Err(e) => err(400, e.to_string()),
            }
        }
        ("POST", "/checkpoint") => match checkpoint_dir {
            Some(dir) => match plane.checkpoint(dir) {
                Ok(path) => json(
                    200,
                    Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("path", Json::Str(path.display().to_string())),
                        ("epoch", Json::Num(plane.epoch() as f64)),
                        ("slot", Json::Num(plane.slots_served() as f64)),
                    ]),
                ),
                Err(e) => err(500, format!("checkpoint failed: {e}")),
            },
            None => err(
                409,
                "no checkpoint directory configured (scfo serve --checkpoint DIR)".into(),
            ),
        },
        ("DELETE", path) if path.starts_with("/apps/") => {
            let id = &path["/apps/".len()..];
            let Some(app) = plane.catalog.get(id) else {
                return err(404, format!("app '{id}' is not registered"));
            };
            // two-step teardown: an active app drains first; deleting a
            // draining app removes it
            let outcome = if app.status == AppStatus::Active {
                plane.drain(id).map(|()| "draining")
            } else {
                plane.remove(id).map(|()| "removed")
            };
            match outcome {
                Ok(state) => json(
                    200,
                    Json::obj(vec![
                        ("id", Json::Str(id.to_string())),
                        ("state", Json::Str(state.into())),
                        ("epoch", Json::Num(plane.epoch() as f64)),
                    ]),
                ),
                Err(e) => err(500, e.to_string()),
            }
        }
        ("GET", _) | ("POST", _) | ("DELETE", _) => err(404, format!("no route {} {}", req.method, req.path)),
        _ => err(405, format!("method {} not allowed", req.method)),
    }
}

fn respond(
    stream: &mut TcpStream,
    code: u16,
    content_type: &str,
    body: &str,
) -> anyhow::Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}
