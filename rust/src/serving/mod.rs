//! Online serving loop — the deployment-shaped wrapper around the optimizer.
//!
//! Requests (computation jobs) arrive as Poisson streams at the source
//! nodes; the server estimates per-(app, node) arrival rates with an EWMA,
//! feeds them to the optimizer every slot (the paper's online mode: GP needs
//! no prior knowledge of r_i(a)), and reports delay/throughput metrics. Both
//! the native optimizer and the PJRT-backed [`crate::runtime::XlaGp`] plug
//! in via [`Optimizer`].

use crate::app::Network;
use crate::flow::FlowState;
use crate::metrics::Histogram;
use crate::strategy::Strategy;
use crate::util::rng::Rng;

/// Anything that can advance a strategy by one slot on the current network.
pub trait Optimizer {
    /// One slot; returns the aggregate cost at the slot's operating point.
    fn slot(&mut self, net: &Network) -> anyhow::Result<f64>;
    /// Current strategy.
    fn strategy(&self) -> &Strategy;
}

impl Optimizer for crate::algo::gp::GradientProjection {
    fn slot(&mut self, net: &Network) -> anyhow::Result<f64> {
        Ok(self.step(net).cost)
    }
    fn strategy(&self) -> &Strategy {
        &self.phi
    }
}

impl Optimizer for crate::runtime::XlaGp {
    fn slot(&mut self, net: &Network) -> anyhow::Result<f64> {
        self.step(net)
    }
    fn strategy(&self) -> &Strategy {
        &self.phi
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Slot duration T in seconds (drives arrival counts per slot).
    pub slot_secs: f64,
    /// EWMA factor for rate estimation (weight of the newest slot).
    pub ewma: f64,
    pub seed: u64,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            slot_secs: 1.0,
            ewma: 0.3,
            seed: 7,
        }
    }
}

/// Per-slot serving metrics.
#[derive(Clone, Debug)]
pub struct SlotMetrics {
    pub slot: usize,
    /// requests that arrived this slot
    pub arrivals: usize,
    /// aggregate analytic cost (≙ total queued packets; delay = cost/λ)
    pub cost: f64,
    /// expected per-packet delay via Little's law (s)
    pub expected_delay: f64,
    /// wall-clock time the optimizer slot took (s) — the L3 hot-path latency
    pub optimizer_latency: f64,
}

/// The online server.
pub struct OnlineServer<O: Optimizer> {
    /// true (hidden) arrival rates used to draw traffic
    true_rates: Vec<Vec<f64>>,
    /// the rate estimates the optimizer sees (EWMA over observed counts)
    est_rates: Vec<Vec<f64>>,
    pub net: Network,
    pub optimizer: O,
    opts: ServerOptions,
    rng: Rng,
    pub delay_hist: Histogram,
    slot_no: usize,
}

impl<O: Optimizer> OnlineServer<O> {
    /// `net`'s input_rates are taken as the true arrival rates; the
    /// optimizer starts from zero knowledge (estimates at 0).
    pub fn new(net: Network, optimizer: O, opts: ServerOptions) -> Self {
        let true_rates: Vec<Vec<f64>> =
            net.apps.iter().map(|a| a.input_rates.clone()).collect();
        let est_rates = vec![vec![0.0; net.n()]; net.apps.len()];
        let rng = Rng::new(opts.seed);
        let mut srv = OnlineServer {
            true_rates,
            est_rates,
            net,
            optimizer,
            opts,
            rng,
            delay_hist: Histogram::new(4096),
            slot_no: 0,
        };
        // optimizer starts against zero estimated load
        for (a, est) in srv.est_rates.iter().enumerate() {
            srv.net.apps[a].input_rates.copy_from_slice(est);
        }
        srv
    }

    /// Change the hidden true rate (models demand shifts mid-run).
    pub fn set_true_rate(&mut self, app: usize, node: usize, rate: f64) {
        self.true_rates[app][node] = rate;
    }

    /// Run one serving slot: draw Poisson arrivals, update estimates, run
    /// the optimizer, report metrics.
    pub fn run_slot(&mut self) -> anyhow::Result<SlotMetrics> {
        self.slot_no += 1;
        // 1. arrivals this slot (Poisson counts, slot_secs horizon)
        let mut arrivals = 0usize;
        for (a, rates) in self.true_rates.iter().enumerate() {
            for (i, &r) in rates.iter().enumerate() {
                if r <= 0.0 {
                    self.est_rates[a][i] *= 1.0 - self.opts.ewma;
                    continue;
                }
                // sample Poisson(r * T) by thinning exponential gaps
                let mut count = 0usize;
                let mut t = self.rng.exp(r);
                while t < self.opts.slot_secs {
                    count += 1;
                    t += self.rng.exp(r);
                }
                arrivals += count;
                let observed = count as f64 / self.opts.slot_secs;
                self.est_rates[a][i] = (1.0 - self.opts.ewma) * self.est_rates[a][i]
                    + self.opts.ewma * observed;
            }
        }
        // 2. expose estimates to the optimizer
        for (a, est) in self.est_rates.iter().enumerate() {
            self.net.apps[a].input_rates.copy_from_slice(est);
        }
        // 3. optimizer slot (timed: this is the L3 hot path)
        let t0 = std::time::Instant::now();
        let _opt_cost = self.optimizer.slot(&self.net)?;
        let optimizer_latency = t0.elapsed().as_secs_f64();
        // 4. metrics at the TRUE rates (what users experience)
        let mut truth = self.net.clone();
        for (a, rates) in self.true_rates.iter().enumerate() {
            truth.apps[a].input_rates.copy_from_slice(rates);
        }
        let fs = FlowState::solve(&truth, self.optimizer.strategy())
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let lambda: f64 = self.true_rates.iter().flatten().sum();
        let expected_delay = if lambda > 0.0 {
            fs.total_cost / lambda
        } else {
            0.0
        };
        self.delay_hist.record(expected_delay);
        Ok(SlotMetrics {
            slot: self.slot_no,
            arrivals,
            cost: fs.total_cost,
            expected_delay,
            optimizer_latency,
        })
    }

    /// Run many slots, returning all metrics.
    pub fn run(&mut self, slots: usize) -> anyhow::Result<Vec<SlotMetrics>> {
        (0..slots).map(|_| self.run_slot()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::gp::{GpOptions, GradientProjection};
    use crate::testutil::small_net;

    #[test]
    fn server_learns_rates_and_converges() {
        let net = small_net(true);
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::new(net, gp, ServerOptions::default());
        let metrics = srv.run(80).unwrap();
        // estimates must approach the truth
        for (a, rates) in srv.true_rates.iter().enumerate() {
            for (i, &r) in rates.iter().enumerate() {
                if r > 0.0 {
                    let est = srv.est_rates[a][i];
                    assert!(
                        (est - r).abs() < 0.5 * r + 0.2,
                        "rate ({a},{i}): est {est} true {r}"
                    );
                }
            }
        }
        // cost at the end beats the beginning (optimizer adapted to load)
        let head = metrics[3].cost;
        let tail = metrics.last().unwrap().cost;
        assert!(
            tail < head * 1.05,
            "no improvement under serving: {head} -> {tail}"
        );
        assert!(metrics.iter().all(|m| m.expected_delay.is_finite()));
    }

    #[test]
    fn demand_shift_is_absorbed() {
        let net = small_net(true);
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::new(net, gp, ServerOptions::default());
        srv.run(40).unwrap();
        let before = srv.run(1).unwrap()[0].cost;
        srv.set_true_rate(0, 3, 2.4); // triple node 3's demand
        let spike = srv.run(1).unwrap()[0].cost;
        srv.run(120).unwrap();
        let after = srv.run(1).unwrap()[0].cost;
        assert!(spike > before, "no spike visible");
        // after re-adaptation, the served cost must be within 15% of a
        // clairvoyant GP solved directly on the new true rates
        let mut truth = srv.net.clone();
        for (a, rates) in srv.true_rates.iter().enumerate() {
            truth.apps[a].input_rates.copy_from_slice(rates);
        }
        let mut gp = GradientProjection::new(&truth, GpOptions::default());
        let opt = gp.run(&truth, 2000).final_cost;
        assert!(
            after <= opt * 1.15,
            "re-adapted cost {after} vs clairvoyant optimum {opt}"
        );
    }
}
