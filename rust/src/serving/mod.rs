//! Online serving loop — the deployment-shaped wrapper around the optimizer.
//!
//! Requests (computation jobs) arrive at the source nodes from a
//! [`Workload`] — any composition of the traffic models in
//! [`crate::workload`] (stationary Poisson, diurnal, MMPP bursts, flash
//! crowds, drift, or a recorded trace). The server estimates per-(app, node)
//! arrival rates with an EWMA (initialized from the first observed slot, so
//! early slots don't under-provision φ), feeds them to the optimizer every
//! slot (the paper's online mode: GP needs no prior knowledge of r_i(a)),
//! and reports delay/throughput metrics against the *true* rates.
//!
//! An optional [`AdaptationController`] watches the rate estimates for
//! change points and re-triggers optimization (warm-start step boost or cold
//! restart), while measuring per-slot cost regret against a warm omniscient
//! GP oracle and time-to-reconvergence per detection — see [`adapt`].
//!
//! Both the native optimizer and the PJRT-backed [`crate::runtime::XlaGp`]
//! plug in via [`Optimizer`].

pub mod adapt;

pub use adapt::{
    AdaptationController, AdaptationSummary, ControllerOptions, PolicyAction, ReconvergePolicy,
};

use crate::app::Network;
use crate::flow::FlowState;
use crate::metrics::Histogram;
use crate::strategy::Strategy;
use crate::workload::Workload;

/// Anything that can advance a strategy by one slot on the current network.
pub trait Optimizer {
    /// One slot; returns the aggregate cost at the slot's operating point.
    fn slot(&mut self, net: &Network) -> anyhow::Result<f64>;
    /// Current strategy.
    fn strategy(&self) -> &Strategy;
    /// Reset to a cold-start strategy for the current network (the
    /// [`ReconvergePolicy::ColdRestart`] hook; default: no-op).
    fn restart(&mut self, _net: &Network) {}
    /// Multiply the step size by `factor` (the warm-start boost hook;
    /// default: no-op).
    fn scale_step(&mut self, _factor: f64) {}
    /// Message/round counters for distributed optimizers (`None` for
    /// centralized ones). Lets report writers recover the async runtime's
    /// statistics through a `Box<dyn Optimizer>`.
    fn runtime_stats(&self) -> Option<crate::distributed::RuntimeStats> {
        None
    }
    /// Adopt a new network shape mid-run (the control plane's epoch rebuild
    /// after an application registers, drains or is removed), warm-starting
    /// from `phi` — already remapped to the new stage registry. The default
    /// falls back to a cold restart on the new network; centralized GP and
    /// the distributed runtime override it to reconverge incrementally.
    fn rebind(&mut self, net: &Network, _phi: &Strategy) {
        self.restart(net);
    }
    /// Current step size, for checkpointing (`None` when not meaningful —
    /// restore then falls back to the configured default).
    fn step_size(&self) -> Option<f64> {
        None
    }
}

/// Boxed optimizers serve too (lets callers pick the optimizer at runtime,
/// e.g. centralized vs distributed in the scenario runner's dynamic tier).
/// The reconvergence hooks delegate, so [`AdaptationController`] policies
/// reach the inner optimizer — including
/// [`crate::distributed::DistributedOptimizer`].
impl<T: Optimizer + ?Sized> Optimizer for Box<T> {
    fn slot(&mut self, net: &Network) -> anyhow::Result<f64> {
        (**self).slot(net)
    }
    fn strategy(&self) -> &Strategy {
        (**self).strategy()
    }
    fn restart(&mut self, net: &Network) {
        (**self).restart(net);
    }
    fn scale_step(&mut self, factor: f64) {
        (**self).scale_step(factor);
    }
    fn runtime_stats(&self) -> Option<crate::distributed::RuntimeStats> {
        (**self).runtime_stats()
    }
    fn rebind(&mut self, net: &Network, phi: &Strategy) {
        (**self).rebind(net, phi);
    }
    fn step_size(&self) -> Option<f64> {
        (**self).step_size()
    }
}

impl Optimizer for crate::algo::gp::GradientProjection {
    fn slot(&mut self, net: &Network) -> anyhow::Result<f64> {
        Ok(self.step(net).cost)
    }
    fn strategy(&self) -> &Strategy {
        &self.phi
    }
    fn restart(&mut self, net: &Network) {
        *self = crate::algo::gp::GradientProjection::new(net, self.opts.clone());
    }
    fn scale_step(&mut self, factor: f64) {
        self.opts.alpha *= factor;
    }
    fn rebind(&mut self, net: &Network, phi: &Strategy) {
        crate::algo::gp::GradientProjection::rebind(self, net, phi);
    }
    fn step_size(&self) -> Option<f64> {
        Some(self.opts.alpha)
    }
}

impl Optimizer for crate::runtime::XlaGp {
    fn slot(&mut self, net: &Network) -> anyhow::Result<f64> {
        self.step(net)
    }
    fn strategy(&self) -> &Strategy {
        &self.phi
    }
    fn restart(&mut self, net: &Network) {
        crate::runtime::XlaGp::restart(self, net);
    }
    fn scale_step(&mut self, factor: f64) {
        crate::runtime::XlaGp::scale_step(self, factor);
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Slot duration T in seconds (drives arrival counts per slot).
    pub slot_secs: f64,
    /// EWMA factor for rate estimation (weight of the newest slot).
    pub ewma: f64,
    pub seed: u64,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            slot_secs: 1.0,
            ewma: 0.3,
            seed: 7,
        }
    }
}

/// Per-slot serving metrics.
#[derive(Clone, Debug)]
pub struct SlotMetrics {
    pub slot: usize,
    /// requests that arrived this slot
    pub arrivals: usize,
    /// aggregate analytic cost (≙ total queued packets; delay = cost/λ)
    pub cost: f64,
    /// expected per-packet delay via Little's law (s)
    pub expected_delay: f64,
    /// wall-clock time the optimizer slot took (s) — the L3 hot-path latency
    pub optimizer_latency: f64,
    /// omniscient-GP cost this slot (controller attached only)
    pub oracle_cost: Option<f64>,
    /// served cost − oracle cost, clamped at 0 (controller attached only)
    pub regret: Option<f64>,
    /// true iff the controller detected a change point this slot
    pub detection: bool,
    /// wall-clock seconds per slot phase: sample, observe (estimation +
    /// detection), optimize, measure (truth metrics + regret). Fixed-size
    /// so the hot path stays allocation-free; fed to the control plane's
    /// per-phase latency histograms and the trace spans.
    pub phase_secs: [f64; 4],
}

/// Indices into [`SlotMetrics::phase_secs`], in slot execution order.
pub const SLOT_PHASES: [&str; 4] = ["sample", "observe", "optimize", "measure"];

/// The online server.
pub struct OnlineServer<O: Optimizer> {
    /// the arrival process (owns the hidden true rates)
    pub workload: Workload,
    /// the rate estimates the optimizer sees (EWMA over observed counts)
    est_rates: Vec<Vec<f64>>,
    /// whether (app, node) has observed its first slot yet
    est_seen: Vec<Vec<bool>>,
    /// flat per-stream observation column (this slot's counts / T),
    /// indexed by stream id and reused across slots — the detector scans
    /// it linearly without per-slot allocation
    obs_col: Vec<f64>,
    /// flat per-stream fast-EWMA estimate column, same indexing
    est_col: Vec<f64>,
    pub net: Network,
    pub optimizer: O,
    opts: ServerOptions,
    pub delay_hist: Histogram,
    slot_no: usize,
    /// change-point detection + regret accounting, when attached
    pub controller: Option<AdaptationController>,
}

impl<O: Optimizer> OnlineServer<O> {
    /// Stationary-Poisson serving: `net`'s input_rates become the hidden
    /// true rates (the legacy behavior). The optimizer starts from zero
    /// knowledge (estimates at 0 until the first slot is observed).
    pub fn new(net: Network, optimizer: O, opts: ServerOptions) -> Self {
        let workload = Workload::stationary(&net, opts.slot_secs, opts.seed);
        Self::with_workload(net, optimizer, workload, opts)
    }

    /// Serve an arbitrary [`Workload`] (nonstationary models, trace replay).
    /// The workload's `slot_secs` is authoritative: `opts.slot_secs` is
    /// overridden to match, so rate estimates (counts / T) can never be
    /// scaled by a different slot duration than the one that generated the
    /// counts. Batched SoA sampling is enabled when the workload supports
    /// it (bit-identical to the boxed path; trace replay stays boxed).
    pub fn with_workload(
        net: Network,
        optimizer: O,
        mut workload: Workload,
        mut opts: ServerOptions,
    ) -> Self {
        opts.slot_secs = workload.slot_secs;
        workload.enable_batching();
        let est_rates = vec![vec![0.0; net.n()]; net.apps.len()];
        let est_seen = vec![vec![false; net.n()]; net.apps.len()];
        let mut srv = OnlineServer {
            workload,
            est_rates,
            est_seen,
            obs_col: Vec::new(),
            est_col: Vec::new(),
            net,
            optimizer,
            opts,
            delay_hist: Histogram::new(4096),
            slot_no: 0,
            controller: None,
        };
        // optimizer starts against zero estimated load
        for (a, est) in srv.est_rates.iter().enumerate() {
            srv.net.apps[a].input_rates.copy_from_slice(est);
        }
        srv
    }

    /// Attach an [`AdaptationController`]; it inherits the server's EWMA
    /// factor and slot duration for its normalized-innovation statistic.
    pub fn attach_controller(&mut self, mut ctrl: AdaptationController) {
        ctrl.fast_ewma = self.opts.ewma;
        ctrl.slot_secs = self.opts.slot_secs;
        self.controller = Some(ctrl);
    }

    /// Change the hidden true base rate (models demand shifts mid-run).
    pub fn set_true_rate(&mut self, app: usize, node: usize, rate: f64) {
        self.workload.set_base_rate(app, node, rate);
    }

    /// Serving slots completed so far.
    pub fn slots_served(&self) -> usize {
        self.slot_no
    }

    /// The server's configuration.
    pub fn options(&self) -> &ServerOptions {
        &self.opts
    }

    /// Control-plane epoch rebuild: swap in a network whose application set
    /// changed. `remap[old_app] = Some(new_app)` for surviving apps, `None`
    /// for removed ones. Rate-estimate rows follow their app; new apps
    /// start unobserved (the usual EWMA cold start). The workload is
    /// rebound too ([`Workload::rebind`]): surviving streams keep their
    /// model/RNG state, new sources get fresh streams. The optimizer is
    /// NOT touched here — callers rebind it first ([`Optimizer::rebind`])
    /// so its strategy is shaped for `net` before the next slot runs.
    ///
    /// An attached [`AdaptationController`]'s per-stream slow-EWMA anchors
    /// are indexed by stream position; when a removal shifts stream
    /// indices they transiently misalign and re-learn over the next few
    /// slots (deterministically — worst case a spurious detection right
    /// after an epoch rebuild, when a reconvergence boost is active
    /// anyway).
    pub fn rebind_network(&mut self, net: Network, remap: &[Option<usize>]) {
        let mut est_rates = vec![vec![0.0; net.n()]; net.apps.len()];
        let mut est_seen = vec![vec![false; net.n()]; net.apps.len()];
        for (old_a, new_a) in remap.iter().enumerate() {
            if let Some(na) = new_a {
                est_rates[*na] = std::mem::take(&mut self.est_rates[old_a]);
                est_seen[*na] = std::mem::take(&mut self.est_seen[old_a]);
            }
        }
        self.est_rates = est_rates;
        self.est_seen = est_seen;
        // rebind the workload against the truth rates before the estimate
        // plane overwrites them below
        self.workload.rebind(&net, remap);
        self.net = net;
        for (a, est) in self.est_rates.iter().enumerate() {
            self.net.apps[a].input_rates.copy_from_slice(est);
        }
    }

    /// Serialize the serving-loop state — estimates, slot counter, delay
    /// histogram, workload and (if attached) controller — for
    /// checkpointing. The optimizer is serialized separately (φ via
    /// [`Optimizer::strategy`], step size via [`Optimizer::step_size`]).
    pub fn state_json(&self) -> anyhow::Result<crate::util::json::Json> {
        use crate::util::json::Json;
        Ok(Json::obj(vec![
            (
                "est_rates",
                Json::Arr(self.est_rates.iter().map(|r| Json::arr_f64(r)).collect()),
            ),
            (
                "est_seen",
                Json::Arr(
                    self.est_seen
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|&b| Json::Bool(b)).collect()))
                        .collect(),
                ),
            ),
            ("slot", Json::Num(self.slot_no as f64)),
            ("delay_hist", self.delay_hist.state_json()),
            ("workload", self.workload.state_json()?),
            (
                "controller",
                match &self.controller {
                    Some(c) => c.state_json(),
                    None => Json::Null,
                },
            ),
        ]))
    }

    /// Restore state saved by [`OnlineServer::state_json`] into a server
    /// already built on the same network shape. If the snapshot carries
    /// controller state and none is attached, a default-options controller
    /// is attached first (CLI options override by attaching before calling
    /// this).
    pub fn load_state_json(&mut self, v: &crate::util::json::Json) -> anyhow::Result<()> {
        use crate::util::json::Json;
        let rates = v
            .get("est_rates")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("server state: missing 'est_rates'"))?;
        anyhow::ensure!(
            rates.len() == self.net.apps.len(),
            "server state: {} estimate rows for {} apps",
            rates.len(),
            self.net.apps.len()
        );
        for (row, rv) in self.est_rates.iter_mut().zip(rates) {
            let rv = rv
                .as_arr()
                .filter(|a| a.len() == row.len())
                .ok_or_else(|| anyhow::anyhow!("server state: bad estimate row shape"))?;
            for (x, j) in row.iter_mut().zip(rv) {
                *x = j
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("server state: non-numeric estimate"))?;
            }
        }
        if let Some(seen) = v.get("est_seen").and_then(Json::as_arr) {
            anyhow::ensure!(
                seen.len() == self.est_seen.len(),
                "server state: est_seen shape"
            );
            for (row, rv) in self.est_seen.iter_mut().zip(seen) {
                let rv = rv
                    .as_arr()
                    .filter(|a| a.len() == row.len())
                    .ok_or_else(|| anyhow::anyhow!("server state: bad est_seen row shape"))?;
                for (x, j) in row.iter_mut().zip(rv) {
                    *x = j.as_bool().unwrap_or(false);
                }
            }
        }
        self.slot_no = v
            .get("slot")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("server state: missing 'slot'"))?;
        if let Some(h) = v.get("delay_hist") {
            self.delay_hist = crate::metrics::Histogram::from_state_json(h)?;
        }
        let wl = v
            .get("workload")
            .ok_or_else(|| anyhow::anyhow!("server state: missing 'workload'"))?;
        let workload = Workload::from_state_json(wl)?;
        for s in &workload.streams {
            anyhow::ensure!(
                s.app < self.net.apps.len() && s.node < self.net.n(),
                "server state: stream (app {}, node {}) outside the network",
                s.app,
                s.node
            );
        }
        self.opts.slot_secs = workload.slot_secs;
        self.workload = workload;
        match v.get("controller") {
            Some(crate::util::json::Json::Null) | None => {}
            Some(c) => {
                if self.controller.is_none() {
                    self.attach_controller(AdaptationController::new(
                        adapt::ControllerOptions::default(),
                    ));
                }
                let net = self.net.clone();
                self.controller
                    .as_mut()
                    .expect("attached above")
                    .load_state(c, &net)?;
            }
        }
        // expose the restored estimates to the optimizer's network view,
        // exactly as a served slot would have left them
        for (a, est) in self.est_rates.iter().enumerate() {
            self.net.apps[a].input_rates.copy_from_slice(est);
        }
        Ok(())
    }

    /// Current rate estimate for (app, node).
    pub fn estimated_rate(&self, app: usize, node: usize) -> f64 {
        self.est_rates[app][node]
    }

    /// Run one serving slot: draw arrivals from the workload, update
    /// estimates, run the controller + optimizer, report metrics.
    pub fn run_slot(&mut self) -> anyhow::Result<SlotMetrics> {
        self.slot_no += 1;
        crate::obs::set_slot(self.slot_no as u64);
        let _slot_span = crate::obs_span!("serving", "slot");
        // 1. arrivals this slot, per stream (batched SoA passes when the
        //    workload's stream table is active)
        let t_phase = std::time::Instant::now();
        let span = crate::obs_span!("serving", "sample");
        let arrivals = self.workload.sample_slot();
        drop(span);
        let phase_sample = t_phase.elapsed().as_secs_f64();
        let t_phase = std::time::Instant::now();
        let span = crate::obs_span!("serving", "observe");
        // 2. rate estimation (EWMA, initialized from the first observation
        //    instead of decaying up from zero). The per-stream columns are
        //    persistent and indexed by stream id — no per-slot allocation,
        //    and resize covers control-plane stream-set changes.
        let w = self.opts.ewma;
        let n = self.workload.streams.len();
        self.obs_col.resize(n, 0.0);
        self.est_col.resize(n, 0.0);
        for (i, s) in self.workload.streams.iter().enumerate() {
            let observed = s.last_offsets.len() as f64 / self.opts.slot_secs;
            let est = &mut self.est_rates[s.app][s.node];
            if !self.est_seen[s.app][s.node] {
                *est = observed;
                self.est_seen[s.app][s.node] = true;
            } else {
                *est = (1.0 - w) * *est + w * observed;
            }
            self.obs_col[i] = observed;
            self.est_col[i] = *est;
        }
        // 3. expose estimates to the optimizer
        for (a, est) in self.est_rates.iter().enumerate() {
            self.net.apps[a].input_rates.copy_from_slice(est);
        }
        // 4. change-point detection + re-optimization policy: a linear
        //    scan over the detector columns, aligned with obs/est above
        let mut detection = false;
        if let Some(ctrl) = self.controller.as_mut() {
            let before = ctrl.events().len();
            let action = ctrl.observe(&self.obs_col, &self.est_col);
            detection = ctrl.events().len() > before;
            match action {
                PolicyAction::None => {}
                PolicyAction::Restart => self.optimizer.restart(&self.net),
                PolicyAction::ScaleStep(f) => self.optimizer.scale_step(f),
            }
        }
        drop(span);
        let phase_observe = t_phase.elapsed().as_secs_f64();
        // 5. optimizer slot (timed: this is the L3 hot path)
        let t0 = std::time::Instant::now();
        let span = crate::obs_span!("serving", "optimize");
        let _opt_cost = self.optimizer.slot(&self.net)?;
        drop(span);
        let optimizer_latency = t0.elapsed().as_secs_f64();
        // 6. metrics at the TRUE rates (what users experience)
        let t_phase = std::time::Instant::now();
        let span = crate::obs_span!("serving", "measure");
        let mut truth = self.net.clone();
        self.workload.apply_true_rates(&mut truth);
        let fs = FlowState::solve(&truth, self.optimizer.strategy())
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let lambda = self.workload.total_true_rate();
        let expected_delay = if lambda > 0.0 {
            fs.total_cost / lambda
        } else {
            0.0
        };
        self.delay_hist.record(expected_delay);
        // 7. regret vs the omniscient oracle + reconvergence bookkeeping
        let (oracle_cost, regret) = match self.controller.as_mut() {
            Some(ctrl) => {
                let (o, r) = ctrl.post_slot(fs.total_cost, &truth);
                (Some(o), Some(r))
            }
            None => (None, None),
        };
        drop(span);
        let phase_measure = t_phase.elapsed().as_secs_f64();
        Ok(SlotMetrics {
            slot: self.slot_no,
            arrivals,
            cost: fs.total_cost,
            expected_delay,
            optimizer_latency,
            oracle_cost,
            regret,
            detection,
            phase_secs: [phase_sample, phase_observe, optimizer_latency, phase_measure],
        })
    }

    /// Run many slots, returning all metrics.
    pub fn run(&mut self, slots: usize) -> anyhow::Result<Vec<SlotMetrics>> {
        (0..slots).map(|_| self.run_slot()).collect()
    }
}

/// Flat per-stream rate-estimation columns for stream sets too large for
/// the per-(app, node) estimate grid — the `massive` tier's hot path.
/// Applies the same EWMA-with-cold-start rule as [`OnlineServer::run_slot`]
/// step 2, indexed by stream id, with zero steady-state allocation. Feed
/// the returned columns straight to [`AdaptationController::observe`].
pub struct StreamEstimator {
    slot_secs: f64,
    ewma: f64,
    /// observed rate this slot (counts / T), indexed by stream id
    pub obs: Vec<f64>,
    /// fast EWMA estimate, indexed by stream id
    pub est: Vec<f64>,
    /// whether the stream has observed its first slot yet
    pub seen: Vec<bool>,
}

impl StreamEstimator {
    pub fn new(slot_secs: f64, ewma: f64) -> StreamEstimator {
        StreamEstimator {
            slot_secs,
            ewma,
            obs: Vec::new(),
            est: Vec::new(),
            seen: Vec::new(),
        }
    }

    /// Update the columns from the workload's latest sampled slot; returns
    /// `(observed, estimate)` column slices for the detector scan.
    pub fn update(&mut self, workload: &Workload) -> (&[f64], &[f64]) {
        let n = workload.streams.len();
        self.obs.resize(n, 0.0);
        self.est.resize(n, 0.0);
        self.seen.resize(n, false);
        let w = self.ewma;
        for (i, s) in workload.streams.iter().enumerate() {
            let observed = s.last_offsets.len() as f64 / self.slot_secs;
            if !self.seen[i] {
                self.est[i] = observed;
                self.seen[i] = true;
            } else {
                self.est[i] = (1.0 - w) * self.est[i] + w * observed;
            }
            self.obs[i] = observed;
        }
        (&self.obs, &self.est)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::gp::{GpOptions, GradientProjection};
    use crate::testutil::small_net;
    use crate::workload::{Workload, WorkloadSpec};

    #[test]
    fn server_learns_rates_and_converges() {
        let net = small_net(true);
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::new(net, gp, ServerOptions::default());
        let metrics = srv.run(80).unwrap();
        // estimates must approach the (stationary) truth
        for s in &srv.workload.streams {
            let r = s.base_rate();
            let est = srv.est_rates[s.app][s.node];
            assert!(
                (est - r).abs() < 0.5 * r + 0.2,
                "rate ({},{}): est {est} true {r}",
                s.app,
                s.node
            );
        }
        // cost at the end beats the beginning (optimizer adapted to load)
        let head = metrics[3].cost;
        let tail = metrics.last().unwrap().cost;
        assert!(
            tail < head * 1.05,
            "no improvement under serving: {head} -> {tail}"
        );
        assert!(metrics.iter().all(|m| m.expected_delay.is_finite()));
    }

    #[test]
    fn first_slot_estimate_equals_first_observation() {
        // the EWMA cold-start fix: after one slot the estimate IS the first
        // observed rate, not ewma · observed decaying up from zero
        let net = small_net(true);
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::new(net, gp, ServerOptions::default());
        srv.run(1).unwrap();
        for s in &srv.workload.streams {
            let observed = s.last_offsets.len() as f64; // slot_secs = 1
            assert_eq!(
                srv.estimated_rate(s.app, s.node),
                observed,
                "stream ({},{}) first-slot estimate must equal the observation",
                s.app,
                s.node
            );
        }
    }

    #[test]
    fn demand_shift_is_absorbed() {
        let net = small_net(true);
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::new(net, gp, ServerOptions::default());
        srv.run(40).unwrap();
        let before = srv.run(1).unwrap()[0].cost;
        srv.set_true_rate(0, 3, 2.4); // triple node 3's demand
        let spike = srv.run(1).unwrap()[0].cost;
        srv.run(120).unwrap();
        let after = srv.run(1).unwrap()[0].cost;
        assert!(spike > before, "no spike visible");
        // after re-adaptation, the served cost must be within 15% of a
        // clairvoyant GP solved directly on the new true rates
        let mut truth = srv.net.clone();
        for app in &mut truth.apps {
            for r in &mut app.input_rates {
                *r = 0.0;
            }
        }
        for s in &srv.workload.streams {
            truth.apps[s.app].input_rates[s.node] = s.base_rate();
        }
        let mut gp = GradientProjection::new(&truth, GpOptions::default());
        let opt = gp.run(&truth, 2000).final_cost;
        assert!(
            after <= opt * 1.15,
            "re-adapted cost {after} vs clairvoyant optimum {opt}"
        );
    }

    #[test]
    fn nonstationary_workload_serves_and_reports_regret() {
        let net = small_net(true);
        let wl = Workload::from_spec(
            &WorkloadSpec::named("flash-crowd").unwrap(),
            &net,
            1.0,
            11,
        )
        .unwrap();
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::with_workload(net, gp, wl, ServerOptions::default());
        srv.attach_controller(AdaptationController::new(ControllerOptions::default()));
        let metrics = srv.run(90).unwrap();
        // the flash crowd (onset at t = 30) must be detected
        let summary = srv.controller.as_ref().unwrap().summary();
        assert!(summary.detections >= 1, "flash crowd not detected");
        assert!(summary.regret_total > 0.0);
        assert!(summary.reconverge_mean >= 1.0);
        let fired_at = metrics.iter().find(|m| m.detection).unwrap().slot;
        assert!(
            (31..=48).contains(&fired_at),
            "detection at slot {fired_at}, expected shortly after the t=30 onset"
        );
        assert!(metrics.iter().all(|m| m.oracle_cost.unwrap() > 0.0));
    }

    #[test]
    fn cold_restart_policy_still_converges() {
        let net = small_net(true);
        let wl = Workload::from_spec(
            &WorkloadSpec::named("flash-crowd").unwrap(),
            &net,
            1.0,
            11,
        )
        .unwrap();
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::with_workload(net, gp, wl, ServerOptions::default());
        srv.attach_controller(AdaptationController::new(ControllerOptions {
            policy: ReconvergePolicy::ColdRestart,
            ..ControllerOptions::default()
        }));
        let metrics = srv.run(120).unwrap();
        let summary = srv.controller.as_ref().unwrap().summary();
        assert!(summary.detections >= 1);
        // after the crowd decays (t > 70) the server must re-approach the
        // oracle: regret in the final quarter well below the spike regret
        let spike_regret: f64 = metrics[30..55]
            .iter()
            .map(|m| m.regret.unwrap())
            .fold(0.0, f64::max);
        let tail_regret: f64 = metrics[100..]
            .iter()
            .map(|m| m.regret.unwrap())
            .sum::<f64>()
            / 20.0;
        assert!(
            tail_regret < spike_regret * 0.5 + 1e-9,
            "tail regret {tail_regret} vs spike {spike_regret}"
        );
    }
}
