//! Online adaptation controller: change-point detection on the serving
//! loop's rate estimates, re-optimization policies, and regret accounting.
//!
//! The controller watches the per-stream EWMA rate estimates for drift using
//! a normalized-innovation statistic: alongside the server's fast EWMA it
//! maintains a slow EWMA per stream, and each slot forms
//!
//! ```text
//! z = Σ_s (fast_s − slow_s) / sqrt(Σ_s v̂_s),
//! v̂_s = (w_f/(2−w_f) + w_s/(2−w_s)) · slow_s / T
//! ```
//!
//! — the aggregate fast−slow gap in units of its stationary-Poisson standard
//! deviation. Under stationary traffic `z` hovers near zero; after a rate
//! change the fast estimate moves first and `z` grows. A detection fires
//! when `|z|` crosses [`ControllerOptions::threshold`] (abrupt shifts) or a
//! two-sided CUSUM of `|z|` crosses [`ControllerOptions::cusum_h`] (gradual
//! drift), after which the slow estimate re-anchors to the fast one and a
//! cooldown suppresses immediate re-fires.
//!
//! On detection the configured [`ReconvergePolicy`] re-triggers
//! optimization: `WarmStart` keeps the current φ and temporarily boosts the
//! optimizer step size (rescheduled back after
//! [`ControllerOptions::boost_slots`]); `ColdRestart` resets φ to the
//! min-hop initial strategy.
//!
//! Per-slot regret is measured against an *oracle*: a shadow
//! [`GradientProjection`] solved on the true (not estimated) rates each
//! slot, warm-started from its own previous solution. See
//! `docs/WORKLOADS.md` for the methodology and its caveats.

use crate::algo::gp::{GpOptions, GradientProjection};
use crate::app::Network;

/// What to do with the live optimizer when a change point is detected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReconvergePolicy {
    /// Keep the current φ; temporarily boost the step size so GP re-tracks
    /// faster, then reschedule it back.
    WarmStart,
    /// Reset φ to the min-hop initial strategy and re-optimize from scratch.
    ColdRestart,
}

impl ReconvergePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ReconvergePolicy::WarmStart => "warm-start",
            ReconvergePolicy::ColdRestart => "cold-restart",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<ReconvergePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "warm" | "warm-start" => Ok(ReconvergePolicy::WarmStart),
            "cold" | "cold-restart" => Ok(ReconvergePolicy::ColdRestart),
            other => anyhow::bail!("unknown policy '{other}' (warm|cold)"),
        }
    }
}

/// Controller configuration.
#[derive(Clone, Debug)]
pub struct ControllerOptions {
    pub policy: ReconvergePolicy,
    /// Slow-EWMA factor (the fast factor is the server's `ewma`).
    pub slow_ewma: f64,
    /// Fire immediately when |z| exceeds this (abrupt change points).
    pub threshold: f64,
    /// CUSUM drift allowance k: |z| in excess of this accumulates.
    pub cusum_k: f64,
    /// Fire when the CUSUM statistic exceeds this (gradual drift).
    pub cusum_h: f64,
    /// Step-size multiplier applied on WarmStart detections.
    pub alpha_boost: f64,
    /// Slots the boost stays active before being rescheduled back.
    pub boost_slots: usize,
    /// Minimum slots between detections.
    pub cooldown: usize,
    /// Warm oracle GP iterations per slot (the regret reference).
    pub oracle_iters: usize,
    /// Extra oracle iterations on its very first slot (cold start).
    pub oracle_warmup_iters: usize,
    /// A detection counts as reconverged once served cost is within this
    /// relative tolerance of the oracle cost.
    pub reconverge_tol: f64,
}

impl Default for ControllerOptions {
    fn default() -> Self {
        ControllerOptions {
            policy: ReconvergePolicy::WarmStart,
            slow_ewma: 0.05,
            threshold: 6.0,
            cusum_k: 1.5,
            cusum_h: 8.0,
            alpha_boost: 3.0,
            boost_slots: 10,
            cooldown: 5,
            oracle_iters: 30,
            oracle_warmup_iters: 400,
            reconverge_tol: 0.05,
        }
    }
}

/// Optimizer-side effect requested by the controller for this slot. The
/// server applies it to its (generic) optimizer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PolicyAction {
    None,
    /// Reset the optimizer to a cold-start strategy.
    Restart,
    /// Multiply the optimizer step size by the payload.
    ScaleStep(f64),
}

/// One detection and its outcome.
#[derive(Clone, Debug)]
pub struct AdaptationEvent {
    /// Serving slot (1-based, matching `SlotMetrics::slot`) of detection.
    pub slot: usize,
    /// Slots from detection until served cost re-entered the oracle's
    /// tolerance band (≥ 1). For unresolved detections this is the censored
    /// span observed so far.
    pub reconverge_slots: usize,
    /// False while the detection is still waiting for reconvergence.
    pub resolved: bool,
}

/// Aggregate adaptation metrics for a run.
#[derive(Clone, Debug, Default)]
pub struct AdaptationSummary {
    /// Slots observed.
    pub slots: usize,
    /// Change points detected.
    pub detections: usize,
    /// Mean slots-to-reconvergence across detections (censored spans
    /// included); 0.0 when nothing fired.
    pub reconverge_mean: f64,
    /// Worst reconvergence span.
    pub reconverge_max: usize,
    /// Σ per-slot regret (served cost − oracle cost, clamped at 0).
    pub regret_total: f64,
    /// Mean per-slot regret.
    pub regret_mean: f64,
}

impl AdaptationSummary {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("slots", Json::Num(self.slots as f64)),
            ("detections", Json::Num(self.detections as f64)),
            ("reconvergence_slots_mean", Json::Num(self.reconverge_mean)),
            ("reconvergence_slots_max", Json::Num(self.reconverge_max as f64)),
            ("regret_total", Json::Num(self.regret_total)),
            ("regret_mean", Json::Num(self.regret_mean)),
        ])
    }
}

/// Per-stream detector state as flat columns indexed by stream id — the
/// SoA layout matching the serving loop's observation/estimate columns, so
/// [`AdaptationController::observe`] is one linear scan regardless of
/// stream count.
pub(crate) struct DetectorColumns {
    /// Slow-EWMA anchor per stream.
    pub(crate) slow: Vec<f64>,
    /// Whether the stream has observed its first slot yet.
    pub(crate) seen: Vec<bool>,
}

impl DetectorColumns {
    fn new() -> DetectorColumns {
        DetectorColumns {
            slow: Vec::new(),
            seen: Vec::new(),
        }
    }

    /// Track the stream set: grow with unseen anchors; truncate on shrink
    /// (a control-plane app removal) so the re-anchor path stays
    /// shape-consistent.
    fn resize(&mut self, n: usize) {
        if n > self.slow.len() {
            self.slow.resize(n, 0.0);
            self.seen.resize(n, false);
        } else if n < self.slow.len() {
            self.slow.truncate(n);
            self.seen.truncate(n);
        }
    }

    /// One linear scan over the columns: advance the slow anchors and
    /// accumulate the aggregate `(gap, var)` plus the largest per-stream
    /// normalized innovation. Identical arithmetic (and accumulation
    /// order) to the per-stream reference formulation.
    fn scan(
        &mut self,
        observed: &[f64],
        fast: &[f64],
        ws: f64,
        vfactor: f64,
        slot_secs: f64,
    ) -> (f64, f64, f64) {
        let mut gap = 0.0;
        let mut var = 0.0;
        let mut stream_z = 0.0f64;
        for (s, &obs) in observed.iter().enumerate() {
            if !self.seen[s] {
                // same cold-start rule as the server's fast estimate
                self.slow[s] = obs;
                self.seen[s] = true;
            } else {
                self.slow[s] = (1.0 - ws) * self.slow[s] + ws * obs;
            }
            let g = fast[s] - self.slow[s];
            let v = vfactor * self.slow[s].max(1e-9) / slot_secs;
            gap += g;
            var += v;
            stream_z = stream_z.max(g.abs() / v.sqrt());
        }
        (gap, var, stream_z)
    }

    /// Re-anchor every slow estimate to the fast column (post-detection).
    fn reanchor(&mut self, fast: &[f64]) {
        self.slow.copy_from_slice(fast);
    }
}

/// The controller. Attach to an [`crate::serving::OnlineServer`] via
/// [`crate::serving::OnlineServer::attach_controller`]; the server feeds it
/// every slot.
pub struct AdaptationController {
    pub opts: ControllerOptions,
    /// Copied from the server at attach time.
    pub(super) fast_ewma: f64,
    pub(super) slot_secs: f64,
    det: DetectorColumns,
    cusum: f64,
    cooldown_left: usize,
    boost_left: usize,
    slot: usize,
    /// Latest normalized-innovation statistic (diagnostics).
    pub last_z: f64,
    events: Vec<AdaptationEvent>,
    regrets: Vec<f64>,
    oracle: Option<GradientProjection>,
    /// Latest oracle (omniscient) cost.
    pub last_oracle_cost: f64,
}

impl AdaptationController {
    pub fn new(opts: ControllerOptions) -> AdaptationController {
        AdaptationController {
            opts,
            fast_ewma: 0.3,
            slot_secs: 1.0,
            det: DetectorColumns::new(),
            cusum: 0.0,
            cooldown_left: 0,
            boost_left: 0,
            slot: 0,
            last_z: 0.0,
            events: Vec::new(),
            regrets: Vec::new(),
            oracle: None,
            last_oracle_cost: 0.0,
        }
    }

    /// Detection phase, called once per slot with the per-stream observed
    /// rates (this slot's counts / T) and the server's fast EWMA estimates
    /// (post-update). Returns the optimizer-side action for this slot.
    pub fn observe(&mut self, observed: &[f64], fast: &[f64]) -> PolicyAction {
        self.slot += 1;
        self.det.resize(observed.len());
        let ws = self.opts.slow_ewma;
        let wf = self.fast_ewma;
        let vfactor = wf / (2.0 - wf) + ws / (2.0 - ws);
        // opposite-direction shifts on different streams cancel in the
        // signed aggregate, so the scan also tracks the largest per-stream
        // |z| alongside (gap, var)
        let (gap, var, stream_z) = self.det.scan(observed, fast, ws, vfactor, self.slot_secs);
        self.last_z = if var > 0.0 { gap / var.sqrt() } else { 0.0 };
        // CUSUM integrates the aggregate only: a max-statistic has a
        // nonzero null mean that would drift it upward. Slow *opposing*
        // drifts therefore rely on the per-stream threshold below.
        self.cusum = (self.cusum + self.last_z.abs() - self.opts.cusum_k).max(0.0);

        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
        }
        let fired = self.cooldown_left == 0
            && (self.last_z.abs() > self.opts.threshold
                || stream_z > self.opts.threshold
                || self.cusum > self.opts.cusum_h);
        if fired {
            // re-anchor and re-arm the detector
            self.det.reanchor(fast);
            self.cusum = 0.0;
            self.cooldown_left = self.opts.cooldown;
            self.events.push(AdaptationEvent {
                slot: self.slot,
                reconverge_slots: 0,
                resolved: false,
            });
            return match self.opts.policy {
                ReconvergePolicy::ColdRestart => PolicyAction::Restart,
                ReconvergePolicy::WarmStart => {
                    let act = if self.boost_left == 0 {
                        PolicyAction::ScaleStep(self.opts.alpha_boost)
                    } else {
                        PolicyAction::None // boost already active; extend it
                    };
                    self.boost_left = self.opts.boost_slots;
                    act
                }
            };
        }
        if self.boost_left > 0 {
            self.boost_left -= 1;
            if self.boost_left == 0 {
                return PolicyAction::ScaleStep(1.0 / self.opts.alpha_boost);
            }
        }
        PolicyAction::None
    }

    /// Regret phase, called after the optimizer slot with the served cost at
    /// the true rates and the truth network itself. Runs the warm oracle,
    /// records regret, and advances reconvergence tracking. Returns
    /// `(oracle_cost, regret)`.
    pub fn post_slot(&mut self, served_cost: f64, truth: &Network) -> (f64, f64) {
        if let Some(gp) = self.oracle.as_mut() {
            gp.run(truth, self.opts.oracle_iters);
        } else {
            let mut gp = GradientProjection::new(truth, GpOptions::default());
            gp.run(truth, self.opts.oracle_warmup_iters);
            self.oracle = Some(gp);
        }
        let oracle_cost = self.oracle.as_ref().expect("set above").cost(truth);
        self.last_oracle_cost = oracle_cost;
        let regret = (served_cost - oracle_cost).max(0.0);
        self.regrets.push(regret);

        let tol = self.opts.reconverge_tol;
        for ev in &mut self.events {
            if !ev.resolved {
                ev.reconverge_slots += 1;
                if served_cost <= oracle_cost * (1.0 + tol) {
                    ev.resolved = true;
                }
            }
        }
        (oracle_cost, regret)
    }

    /// Serialize the controller's mutable state (EWMA anchors, CUSUM,
    /// cooldown/boost counters, detection history, regret trace, oracle φ)
    /// for checkpointing. Options are *not* serialized — a restore
    /// reconstructs them from configuration, then loads this state.
    pub fn state_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let events = self
            .events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("slot", Json::Num(e.slot as f64)),
                    ("reconverge_slots", Json::Num(e.reconverge_slots as f64)),
                    ("resolved", Json::Bool(e.resolved)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("slow", Json::arr_f64(&self.det.slow)),
            (
                "seen",
                Json::Arr(self.det.seen.iter().map(|&b| Json::Bool(b)).collect()),
            ),
            ("cusum", Json::Num(self.cusum)),
            ("cooldown_left", Json::Num(self.cooldown_left as f64)),
            ("boost_left", Json::Num(self.boost_left as f64)),
            ("slot", Json::Num(self.slot as f64)),
            ("last_z", Json::Num(self.last_z)),
            ("events", Json::Arr(events)),
            ("regrets", Json::arr_f64(&self.regrets)),
            ("last_oracle_cost", Json::Num(self.last_oracle_cost)),
            (
                "oracle_phi",
                match &self.oracle {
                    Some(gp) => gp.phi.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Restore state saved by [`AdaptationController::state_json`]. `net`
    /// supplies the graph/stage shape the oracle strategy is rebuilt on
    /// (the serving network — same shape as the truth network the oracle
    /// optimizes).
    pub fn load_state(
        &mut self,
        v: &crate::util::json::Json,
        net: &Network,
    ) -> anyhow::Result<()> {
        use crate::util::json::Json;
        let nums = |k: &str| -> anyhow::Result<Vec<f64>> {
            Ok(v.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("controller state: missing '{k}'"))?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0))
                .collect())
        };
        self.det.slow = nums("slow")?;
        self.det.seen = v
            .get("seen")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("controller state: missing 'seen'"))?
            .iter()
            .map(|x| x.as_bool().unwrap_or(false))
            .collect();
        anyhow::ensure!(
            self.det.seen.len() == self.det.slow.len(),
            "controller state: seen/slow length mismatch"
        );
        self.cusum = v.get("cusum").and_then(Json::as_f64).unwrap_or(0.0);
        self.cooldown_left = v
            .get("cooldown_left")
            .and_then(Json::as_usize)
            .unwrap_or(0);
        self.boost_left = v.get("boost_left").and_then(Json::as_usize).unwrap_or(0);
        self.slot = v
            .get("slot")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("controller state: missing 'slot'"))?;
        self.last_z = v.get("last_z").and_then(Json::as_f64).unwrap_or(0.0);
        self.events.clear();
        if let Some(events) = v.get("events").and_then(Json::as_arr) {
            for e in events {
                self.events.push(AdaptationEvent {
                    slot: e
                        .get("slot")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow::anyhow!("controller event: missing 'slot'"))?,
                    reconverge_slots: e
                        .get("reconverge_slots")
                        .and_then(Json::as_usize)
                        .unwrap_or(0),
                    resolved: e.get("resolved").and_then(Json::as_bool).unwrap_or(false),
                });
            }
        }
        self.regrets = nums("regrets")?;
        self.last_oracle_cost = v
            .get("last_oracle_cost")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        self.oracle = match v.get("oracle_phi") {
            Some(Json::Null) | None => None,
            Some(p) => {
                let phi = crate::strategy::Strategy::from_json(&net.graph, p)?;
                Some(GradientProjection::with_strategy(
                    net,
                    phi,
                    GpOptions::default(),
                ))
            }
        };
        Ok(())
    }

    /// Detections so far.
    pub fn events(&self) -> &[AdaptationEvent] {
        &self.events
    }

    /// Per-slot regret trace.
    pub fn regrets(&self) -> &[f64] {
        &self.regrets
    }

    /// Aggregate metrics over the run so far.
    pub fn summary(&self) -> AdaptationSummary {
        let detections = self.events.len();
        let (mut mean, mut max) = (0.0, 0usize);
        if detections > 0 {
            let spans: Vec<usize> = self.events.iter().map(|e| e.reconverge_slots).collect();
            mean = spans.iter().sum::<usize>() as f64 / detections as f64;
            max = spans.iter().copied().max().unwrap_or(0);
        }
        let regret_total: f64 = self.regrets.iter().sum();
        AdaptationSummary {
            slots: self.slot,
            detections,
            reconverge_mean: mean,
            reconverge_max: max,
            regret_total,
            regret_mean: if self.slot > 0 {
                regret_total / self.slot as f64
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Feed the detector synthetic Poisson streams directly (no server).
    /// Returns the total detection count afterwards.
    fn run_detector(
        ctrl: &mut AdaptationController,
        rates: &[f64],
        slots: usize,
        rng: &mut Rng,
        fast: &mut [f64],
        seen: &mut [bool],
    ) -> usize {
        for _ in 0..slots {
            let mut obs = vec![0.0; rates.len()];
            for (s, &r) in rates.iter().enumerate() {
                if r <= 0.0 {
                    continue;
                }
                let mut count = 0usize;
                let mut t = rng.exp(r);
                while t < 1.0 {
                    count += 1;
                    t += rng.exp(r);
                }
                obs[s] = count as f64;
                if !seen[s] {
                    fast[s] = obs[s];
                    seen[s] = true;
                } else {
                    fast[s] = 0.7 * fast[s] + 0.3 * obs[s];
                }
            }
            let _ = ctrl.observe(&obs, fast);
        }
        ctrl.events().len()
    }

    #[test]
    fn stationary_streams_do_not_fire() {
        let mut ctrl = AdaptationController::new(ControllerOptions::default());
        let rates = [1.0, 0.8, 1.2];
        let mut fast = [0.0; 3];
        let mut seen = [false; 3];
        let mut rng = Rng::new(2024);
        let fired = run_detector(&mut ctrl, &rates, 300, &mut rng, &mut fast, &mut seen);
        assert_eq!(fired, 0, "false alarm under stationary Poisson");
    }

    #[test]
    fn abrupt_step_fires_quickly() {
        let mut ctrl = AdaptationController::new(ControllerOptions::default());
        let mut fast = [0.0; 3];
        let mut seen = [false; 3];
        let mut rng = Rng::new(7);
        run_detector(&mut ctrl, &[1.0, 0.8, 1.2], 60, &mut rng, &mut fast, &mut seen);
        assert_eq!(ctrl.events().len(), 0);
        // all streams step x6 (a flash crowd hitting every source)
        run_detector(&mut ctrl, &[6.0, 4.8, 7.2], 10, &mut rng, &mut fast, &mut seen);
        assert!(
            !ctrl.events().is_empty(),
            "no detection within 10 slots of a 6x step (z={})",
            ctrl.last_z
        );
        let ev = &ctrl.events()[0];
        assert!(ev.slot > 60 && ev.slot <= 70, "fired at slot {}", ev.slot);
    }

    #[test]
    fn opposing_stream_shifts_are_detected() {
        // one stream surges while another collapses by the same amount:
        // the signed aggregate nets to ~0, the per-stream |z| must fire
        let mut ctrl = AdaptationController::new(ControllerOptions::default());
        let mut fast = [0.0; 2];
        let mut seen = [false; 2];
        let mut rng = Rng::new(31);
        run_detector(&mut ctrl, &[1.0, 5.0], 60, &mut rng, &mut fast, &mut seen);
        assert_eq!(ctrl.events().len(), 0);
        let fired = run_detector(&mut ctrl, &[5.0, 1.0], 12, &mut rng, &mut fast, &mut seen);
        assert!(
            fired >= 1,
            "opposing shifts cancelled in the detector (z={})",
            ctrl.last_z
        );
    }

    #[test]
    fn warm_start_boost_is_applied_and_rescheduled_back() {
        let mut ctrl = AdaptationController::new(ControllerOptions {
            policy: ReconvergePolicy::WarmStart,
            boost_slots: 3,
            cooldown: 1,
            ..ControllerOptions::default()
        });
        // prime one stationary slot, then an enormous step
        assert_eq!(ctrl.observe(&[1.0], &[1.0]), PolicyAction::None);
        let act = ctrl.observe(&[50.0], &[15.7]);
        assert_eq!(act, PolicyAction::ScaleStep(3.0));
        // boost expires after boost_slots quiet slots
        let mut unboost = None;
        for _ in 0..5 {
            match ctrl.observe(&[1.0], &[ctrl.det.slow[0]]) {
                PolicyAction::ScaleStep(f) => unboost = Some(f),
                PolicyAction::None => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        let f = unboost.expect("boost never rescheduled back");
        assert!((f - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn cold_restart_policy_requests_restart() {
        let opts = ControllerOptions {
            policy: ReconvergePolicy::ColdRestart,
            ..ControllerOptions::default()
        };
        let mut ctrl = AdaptationController::new(opts);
        assert_eq!(ctrl.observe(&[1.0], &[1.0]), PolicyAction::None);
        assert_eq!(ctrl.observe(&[60.0], &[18.7]), PolicyAction::Restart);
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [ReconvergePolicy::WarmStart, ReconvergePolicy::ColdRestart] {
            assert_eq!(ReconvergePolicy::parse(p.name()).unwrap(), p);
        }
        assert!(ReconvergePolicy::parse("lukewarm").is_err());
    }

    #[test]
    fn shrinking_stream_sets_do_not_panic_the_detector() {
        // a control-plane app removal shrinks the observed vector; the
        // detector must truncate its anchors (and not panic on re-anchor)
        let mut ctrl = AdaptationController::new(ControllerOptions::default());
        ctrl.observe(&[1.0, 0.8, 1.2], &[1.0, 0.8, 1.2]);
        ctrl.observe(&[1.0, 0.8, 1.2], &[1.0, 0.8, 1.2]);
        // two streams left, one of them stepping hard enough to fire
        let act = ctrl.observe(&[60.0, 0.8], &[18.7, 0.8]);
        assert_ne!(act, PolicyAction::None, "step after shrink must still fire");
        assert_eq!(ctrl.det.slow.len(), 2);
    }

    #[test]
    fn controller_state_roundtrip_resumes_identically() {
        let net = crate::testutil::small_net(true);
        let mut a = AdaptationController::new(ControllerOptions::default());
        a.observe(&[1.0, 0.8], &[1.0, 0.8]);
        a.post_slot(50.0, &net);
        a.observe(&[60.0, 0.8], &[18.7, 0.8]); // abrupt step: fires
        a.post_slot(80.0, &net);
        let v = crate::util::json::Json::parse(&a.state_json().to_string_pretty()).unwrap();
        let mut b = AdaptationController::new(ControllerOptions::default());
        b.load_state(&v, &net).unwrap();
        assert_eq!(b.events().len(), a.events().len());
        assert_eq!(b.slot, a.slot);
        assert_eq!(b.cusum.to_bits(), a.cusum.to_bits());
        // subsequent slots behave identically, including the warm oracle
        for obs in [[2.0, 1.0], [1.5, 0.9], [1.2, 0.7]] {
            let fast = [a.det.slow[0], a.det.slow[1]];
            let act_a = a.observe(&obs, &fast);
            let act_b = b.observe(&obs, &fast);
            assert_eq!(act_a, act_b);
            assert_eq!(a.last_z.to_bits(), b.last_z.to_bits());
            let (oa, ra) = a.post_slot(30.0, &net);
            let (ob, rb) = b.post_slot(30.0, &net);
            assert_eq!(oa.to_bits(), ob.to_bits());
            assert_eq!(ra.to_bits(), rb.to_bits());
        }
    }

    #[test]
    fn summary_counts_regret_and_reconvergence() {
        let mut ctrl = AdaptationController::new(ControllerOptions::default());
        let net = crate::testutil::small_net(true);
        ctrl.observe(&[1.0, 0.8], &[1.0, 0.8]);
        let (oracle, regret) = ctrl.post_slot(100.0, &net);
        assert!(oracle > 0.0 && regret > 0.0);
        let s = ctrl.summary();
        assert_eq!(s.slots, 1);
        assert!(s.regret_total > 0.0);
        let v = s.to_json();
        assert!(v.get("regret_mean").is_some());
        assert!(v.get("reconvergence_slots_mean").is_some());
    }
}
