//! The forwarding/offloading strategy φ.
//!
//! For each stage (a,k) and node i, `φ_ij(a,k)` is the fraction of i's stage
//! traffic forwarded to neighbor j, and `φ_i0(a,k)` (the CPU slot) the
//! fraction handed to i's local CPU to run task k+1. Constraint (1):
//! each (stage, node) row sums to 1, except the (final stage, destination)
//! row which sums to 0 (results exit the network there).
//!
//! Storage is sparse: per stage a single flat arena of `m + n` entries laid
//! out by the graph's CSR layout ([`crate::graph::CsrLayout`]) — node i owns
//! `out_degree(i) + 1` slots, link slots first (ascending by target id), CPU
//! slot last. Directions that are not links simply have no slot, which makes
//! "support restricted to existing links" structural rather than a runtime
//! check, and shrinks per-iteration work from O(|𝒮|·n²) to O(|𝒮|·(m+n)) on
//! sparse topologies (see `docs/PERFORMANCE.md`).
//!
//! Node-id addressed accessors ([`Strategy::get`], [`Strategy::set`],
//! [`Strategy::cpu_frac`]) translate through the layout; the hot paths use
//! the slot-aligned rows ([`Strategy::row`], [`Strategy::row_mut`]) directly.

use std::sync::Arc;

use crate::app::Network;
use crate::graph::{CsrLayout, Graph};
use crate::util::rng::Rng;

/// Tolerance for treating a forwarding fraction as zero.
pub const PHI_EPS: f64 = 1e-12;

/// Renormalize a single φ row to sum `want` (0.0 for exit rows, 1.0
/// otherwise): zero sub-PHI_EPS entries, then rescale — but only when the
/// sum is off by more than 1e-9, keeping the operation idempotent. Shared
/// by [`Strategy::renormalize`] and the distributed node actors so both
/// produce bit-identical rows.
pub fn renormalize_row(row: &mut [f64], want: f64) {
    for v in row.iter_mut() {
        if *v < PHI_EPS {
            *v = 0.0;
        }
    }
    if want == 0.0 {
        row.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    let sum: f64 = row.iter().sum();
    if sum > PHI_EPS && (sum - want).abs() > 1e-9 {
        let inv = want / sum;
        row.iter_mut().for_each(|v| *v *= inv);
    }
}

/// Reusable scratch buffers for [`Strategy::topo_order_into`] — lets the
/// per-iteration hot path (flow solve, marginals, loop safety net) run
/// without heap allocation.
#[derive(Clone, Debug)]
pub struct TopoScratch {
    indeg: Vec<usize>,
    queue: std::collections::VecDeque<usize>,
    /// The order produced by the last successful [`Strategy::topo_order_into`].
    pub order: Vec<usize>,
}

impl TopoScratch {
    pub fn new(n: usize) -> TopoScratch {
        TopoScratch {
            indeg: vec![0; n],
            queue: std::collections::VecDeque::with_capacity(n),
            order: Vec::with_capacity(n),
        }
    }
}

/// Sparse CSR-backed strategy variable φ.
#[derive(Clone, Debug, PartialEq)]
pub struct Strategy {
    layout: Arc<CsrLayout>,
    num_stages: usize,
    /// [stage][arena slot] — see [`CsrLayout`] for the slot order.
    phi: Vec<Vec<f64>>,
}

impl Strategy {
    /// All-zero strategy on `graph`'s slot layout (infeasible until rows are
    /// filled).
    ///
    /// # Examples
    ///
    /// ```
    /// use scfo::graph::Graph;
    /// use scfo::strategy::Strategy;
    ///
    /// // 0 -> 1 -> 2 path; each row has out_degree(i)+1 slots, CPU last.
    /// let g = Graph::new(3, &[(0, 1), (1, 2)]).unwrap();
    /// let mut phi = Strategy::zeros(&g, 1);
    /// phi.set(0, 0, 1, 1.0); // forward everything to node 1
    /// assert_eq!(phi.row(0, 0), &[1.0, 0.0]); // [link to 1, CPU]
    /// assert_eq!(phi.get(0, 0, 1), 1.0);
    /// assert_eq!(phi.get(0, 0, 2), 0.0); // (0,2) is not a link: no slot
    /// ```
    pub fn zeros(graph: &Graph, num_stages: usize) -> Self {
        let layout = Arc::clone(graph.layout());
        let slots = layout.num_slots();
        Strategy {
            layout,
            num_stages,
            phi: vec![vec![0.0; slots]; num_stages],
        }
    }

    pub fn n(&self) -> usize {
        self.layout.n()
    }
    pub fn num_stages(&self) -> usize {
        self.num_stages
    }
    /// Virtual column id of the CPU direction (`n`), accepted by
    /// [`Strategy::get`]/[`Strategy::set`] alongside neighbor node ids.
    pub fn cpu(&self) -> usize {
        self.layout.n()
    }
    /// The shared CSR slot layout.
    pub fn layout(&self) -> &Arc<CsrLayout> {
        &self.layout
    }

    /// φ in direction `j` from node `i` (`j == n` reads the CPU slot).
    /// Directions without a slot (non-links) are 0 by construction.
    #[inline]
    pub fn get(&self, s: usize, i: usize, j: usize) -> f64 {
        match self.layout.slot_of(i, j) {
            Some(t) => self.phi[s][t],
            None => 0.0,
        }
    }

    /// Set φ in direction `j` from node `i` (`j == n` writes the CPU slot).
    ///
    /// # Panics
    ///
    /// Panics if `(i, j)` is neither a link of the underlying graph nor the
    /// CPU direction — such directions have no slot (they are structurally
    /// zero and cannot carry mass).
    #[inline]
    pub fn set(&mut self, s: usize, i: usize, j: usize, v: f64) {
        let t = self
            .layout
            .slot_of(i, j)
            .unwrap_or_else(|| panic!("phi[{s}][{i}][{j}]: ({i},{j}) is not a link or the CPU"));
        self.phi[s][t] = v;
    }

    /// Sparse row φ_i(a,k): `out_degree(i) + 1` entries, link slots first
    /// (ascending by target — index-aligned with
    /// [`Graph::out_links`](crate::graph::Graph::out_links)), CPU last.
    ///
    /// # Examples
    ///
    /// ```
    /// use scfo::graph::Graph;
    /// use scfo::strategy::Strategy;
    ///
    /// let g = Graph::bidirected(3, &[(0, 1), (1, 2)]).unwrap();
    /// let mut phi = Strategy::zeros(&g, 1);
    /// phi.set(0, 1, 0, 0.25);
    /// phi.set(0, 1, 2, 0.25);
    /// phi.set(0, 1, phi.cpu(), 0.5);
    /// // node 1 has out-links to 0 and 2 (ascending) plus the CPU slot:
    /// assert_eq!(phi.row(0, 1), &[0.25, 0.25, 0.5]);
    /// assert_eq!(phi.positive_links(0, 1).collect::<Vec<_>>(), vec![0, 2]);
    /// assert_eq!(phi.cpu_frac(0, 1), 0.5);
    /// ```
    #[inline]
    pub fn row(&self, s: usize, i: usize) -> &[f64] {
        &self.phi[s][self.layout.slot_range(i)]
    }
    #[inline]
    pub fn row_mut(&mut self, s: usize, i: usize) -> &mut [f64] {
        let r = self.layout.slot_range(i);
        &mut self.phi[s][r]
    }

    /// Out-neighbors with positive forwarding fraction (excluding CPU),
    /// ascending by node id.
    pub fn positive_links(&self, s: usize, i: usize) -> impl Iterator<Item = usize> + '_ {
        let r = self.layout.link_slot_range(i);
        let vals = &self.phi[s][r];
        self.layout
            .link_targets(i)
            .iter()
            .zip(vals)
            .filter(|&(_j, &v)| v > PHI_EPS)
            .map(|(&j, _v)| j)
    }

    /// CPU fraction φ_i0.
    #[inline]
    pub fn cpu_frac(&self, s: usize, i: usize) -> f64 {
        self.phi[s][self.layout.cpu_slot(i)]
    }

    /// Overwrite this strategy with `other`'s values (shapes must match).
    /// Allocation-free — used by the GP workspace every iteration.
    pub fn copy_from(&mut self, other: &Strategy) {
        debug_assert_eq!(self.num_stages, other.num_stages);
        debug_assert_eq!(self.layout.num_slots(), other.layout.num_slots());
        for (dst, src) in self.phi.iter_mut().zip(&other.phi) {
            dst.copy_from_slice(src);
        }
    }

    /// Validate feasibility w.r.t. a network: row sums (constraint (1)),
    /// no CPU offload at final stages, and non-negativity. Support outside
    /// the link set is unrepresentable in the sparse layout, so it needs no
    /// check.
    pub fn validate(&self, net: &Network) -> anyhow::Result<()> {
        anyhow::ensure!(self.n() == net.n(), "node count mismatch");
        anyhow::ensure!(self.num_stages == net.num_stages(), "stage count mismatch");
        anyhow::ensure!(
            self.layout.num_slots() == net.graph.layout().num_slots(),
            "slot layout mismatch"
        );
        for (s, (a, _k)) in net.stages.iter() {
            let is_final = net.is_final_stage(s);
            let dest = net.apps[a].dest;
            for i in 0..self.n() {
                let row = self.row(s, i);
                let cpu = row.len() - 1;
                let mut sum = 0.0;
                for (t, &v) in row.iter().enumerate() {
                    anyhow::ensure!(
                        v >= -PHI_EPS && v <= 1.0 + 1e-9,
                        "phi[{s}][{i}] slot {t} = {v} out of [0,1]"
                    );
                    if t == cpu && v > PHI_EPS {
                        anyhow::ensure!(
                            !is_final,
                            "stage {s} is final but phi_cpu[{i}] = {v} > 0"
                        );
                    }
                    sum += v;
                }
                let want = if is_final && i == dest { 0.0 } else { 1.0 };
                anyhow::ensure!(
                    (sum - want).abs() < 1e-6,
                    "row sum phi[{s}][{i}] = {sum}, want {want}"
                );
            }
        }
        Ok(())
    }

    /// Does any stage contain a directed cycle through positive-φ links?
    /// (CPU transitions advance the stage and cannot close a loop.)
    pub fn has_loop(&self) -> bool {
        let mut scratch = TopoScratch::new(self.n());
        (0..self.num_stages).any(|s| !self.topo_order_into(s, &mut scratch))
    }

    /// Topological order of nodes for stage `s` over positive-φ links.
    /// Returns `None` if the stage subgraph has a cycle.
    pub fn topo_order(&self, s: usize) -> Option<Vec<usize>> {
        let mut scratch = TopoScratch::new(self.n());
        self.topo_order_into(s, &mut scratch).then_some(scratch.order)
    }

    /// Allocation-free topological sort (Kahn) of stage `s` over positive-φ
    /// links into `scratch.order`. Returns `false` (and leaves a partial
    /// order) if the stage subgraph has a cycle.
    pub fn topo_order_into(&self, s: usize, scratch: &mut TopoScratch) -> bool {
        let n = self.n();
        scratch.indeg.clear();
        scratch.indeg.resize(n, 0);
        for i in 0..n {
            for j in self.positive_links(s, i) {
                scratch.indeg[j] += 1;
            }
        }
        scratch.queue.clear();
        for i in 0..n {
            if scratch.indeg[i] == 0 {
                scratch.queue.push_back(i);
            }
        }
        scratch.order.clear();
        while let Some(u) = scratch.queue.pop_front() {
            scratch.order.push(u);
            for j in self.positive_links(s, u) {
                scratch.indeg[j] -= 1;
                if scratch.indeg[j] == 0 {
                    scratch.queue.push_back(j);
                }
            }
        }
        scratch.order.len() == n
    }

    /// Renormalize every row to satisfy constraint (1) exactly (fixes small
    /// numerical drift after many GP iterations). Idempotent: rows already
    /// within 1e-9 of their target sum are left untouched, so the leader's
    /// mirror and the node-local copies ([`crate::distributed`]) stay
    /// bit-identical under repeated application.
    pub fn renormalize(&mut self, net: &Network) {
        for (s, (a, _)) in net.stages.iter() {
            let is_final = net.is_final_stage(s);
            let dest = net.apps[a].dest;
            for i in 0..self.n() {
                let want = if is_final && i == dest { 0.0 } else { 1.0 };
                renormalize_row(self.row_mut(s, i), want);
            }
        }
    }

    // ---- initial strategies ------------------------------------------------

    /// Feasible loop-free initialization: every stage forwards along the
    /// min-hop path to the application's destination; all computation happens
    /// at the destination (φ_{d_a,cpu}(a,k) = 1 for k < |𝒯_a|).
    ///
    /// Loop-freeness: next hops strictly decrease hop distance to d_a.
    pub fn shortest_path_to_dest(net: &Network) -> Self {
        let n = net.n();
        let mut phi = Strategy::zeros(&net.graph, net.num_stages());
        for (s, (a, _k)) in net.stages.iter() {
            let dest = net.apps[a].dest;
            let (_dist, next) = net.graph.dijkstra_to(dest, |_| 1.0);
            let is_final = net.is_final_stage(s);
            for i in 0..n {
                if i == dest {
                    if !is_final {
                        phi.set(s, i, phi.cpu(), 1.0); // compute at destination
                    }
                    // final stage at dest: row stays zero (exit)
                } else {
                    phi.set(s, i, next[i], 1.0);
                }
            }
        }
        phi
    }

    /// Fractional-offload initialization from the per-app chain profiles:
    /// every non-final stage row splits `local_frac[k]` of its traffic onto
    /// the local CPU and forwards the remainder along the min-hop path to
    /// d_a (the DNN-split "compute this fraction of layer k here, ship the
    /// rest onward" semantics); the destination offloads fully. Final stages
    /// forward min-hop like [`Strategy::shortest_path_to_dest`].
    ///
    /// Identity chains have all-zero `local_frac`, so this degenerates to
    /// exactly `shortest_path_to_dest`. Loop-freeness: the link portion of
    /// every row follows a single next hop that strictly decreases hop
    /// distance to d_a.
    pub fn fractional_split(net: &Network) -> Self {
        let n = net.n();
        let mut phi = Strategy::zeros(&net.graph, net.num_stages());
        for (s, (a, k)) in net.stages.iter() {
            let dest = net.apps[a].dest;
            let (_dist, next) = net.graph.dijkstra_to(dest, |_| 1.0);
            let is_final = net.is_final_stage(s);
            let frac = if is_final {
                0.0
            } else {
                net.chains[a].local_frac[k].clamp(0.0, 1.0)
            };
            for i in 0..n {
                if i == dest {
                    if !is_final {
                        phi.set(s, i, phi.cpu(), 1.0); // compute at destination
                    }
                    // final stage at dest: row stays zero (exit)
                } else if frac > 0.0 {
                    phi.set(s, i, phi.cpu(), frac);
                    phi.set(s, i, next[i], 1.0 - frac);
                } else {
                    phi.set(s, i, next[i], 1.0);
                }
            }
        }
        phi
    }

    /// Random feasible loop-free initialization: every node spreads its
    /// stage-(a,k) traffic across neighbors strictly closer (in hop count) to
    /// d_a with random weights, plus a random CPU fraction (if not final).
    pub fn random_dag(net: &Network, rng: &mut Rng) -> Self {
        let n = net.n();
        let mut phi = Strategy::zeros(&net.graph, net.num_stages());
        for (s, (a, _k)) in net.stages.iter() {
            let dest = net.apps[a].dest;
            let (dist, _next) = net.graph.dijkstra_to(dest, |_| 1.0);
            let is_final = net.is_final_stage(s);
            for i in 0..n {
                if i == dest && is_final {
                    continue;
                }
                let width = net.graph.layout().width(i);
                let mut weights = vec![0.0; width];
                for (idx, &j) in net.graph.out_neighbors(i).iter().enumerate() {
                    if dist[j] < dist[i] {
                        weights[idx] = rng.range(0.1, 1.0);
                    }
                }
                if !is_final {
                    weights[width - 1] = rng.range(0.1, 1.0);
                }
                let sum: f64 = weights.iter().sum();
                let row = phi.row_mut(s, i);
                if sum <= 0.0 {
                    // destination node of a non-final stage with no downhill
                    // neighbor: must offload locally
                    debug_assert!(!is_final);
                    row[width - 1] = 1.0;
                } else {
                    for (t, w) in weights.into_iter().enumerate() {
                        if w > 0.0 {
                            row[t] = w / sum;
                        }
                    }
                }
            }
        }
        phi
    }

    /// Remap φ onto a network whose graph shares the node set but whose link
    /// set changed (topology churn — see [`crate::topo`]). Per (stage, node)
    /// row, the two sorted link-target lists are merge-walked: surviving
    /// directions copy their mass slot-by-slot into the new arena, the CPU
    /// slot carries over, and mass orphaned on removed links redistributes
    /// proportionally over the row's surviving entries (surviving + orphaned
    /// = the row target, so one [`renormalize_row`] does it). Link slots that
    /// exist only in the new arena start at 0 — gradient projection shifts
    /// mass onto them as it reconverges. Rows that lose *all* mass are
    /// reseeded onto the min-hop next hop toward the stage's destination on
    /// the NEW graph (the destination itself offloads locally).
    ///
    /// Because each surviving row's support is a subset of its old support,
    /// redistribution alone cannot create a forwarding loop — but reseeded
    /// rows mixed with surviving rows can close one, so every stage is
    /// topology-checked and falls back to a whole-stage min-hop seed if a
    /// cycle appears.
    ///
    /// The result is always feasible and loop-free for `new_net`
    /// ([`Strategy::validate`] passes). Remapping onto an identical layout
    /// reproduces `self` exactly (rows copy verbatim; renormalization is
    /// idempotent).
    ///
    /// # Panics
    ///
    /// Panics if `new_net` disagrees with `self` on node count or stage
    /// registry — topology rebind changes links, never nodes or apps.
    pub fn rebind_topology(&self, new_net: &Network) -> Strategy {
        assert_eq!(
            self.n(),
            new_net.n(),
            "topology rebind keeps the node set"
        );
        assert_eq!(
            self.num_stages,
            new_net.num_stages(),
            "topology rebind keeps the stage registry"
        );
        let n = self.n();
        let mut out = Strategy::zeros(&new_net.graph, self.num_stages);
        let new_layout = Arc::clone(new_net.graph.layout());
        let mut scratch = TopoScratch::new(n);
        for (s, (a, _k)) in new_net.stages.iter() {
            let dest = new_net.apps[a].dest;
            let is_final = new_net.is_final_stage(s);
            // min-hop next hops on the NEW graph: emptied-row reseeds and
            // the loop-safety fallback both route along these
            let (_dist, next) = new_net.graph.dijkstra_to(dest, |_| 1.0);
            let reseed_row = |out: &mut Strategy, i: usize| {
                let r = new_layout.slot_range(i);
                out.phi[s][r].iter_mut().for_each(|v| *v = 0.0);
                if i == dest {
                    debug_assert!(!is_final, "exit rows are never reseeded");
                    out.phi[s][new_layout.cpu_slot(i)] = 1.0;
                } else {
                    let t = new_layout
                        .slot_of(i, next[i])
                        .expect("min-hop next hop is a link of the new graph");
                    out.phi[s][t] = 1.0;
                }
            };
            let mut emptied: Vec<usize> = Vec::new();
            for i in 0..n {
                if is_final && i == dest {
                    continue; // exit row stays zero
                }
                let old_row = self.row(s, i);
                let old_targets = self.layout.link_targets(i);
                let new_targets = new_layout.link_targets(i);
                let range = new_layout.slot_range(i);
                let new_row = &mut out.phi[s][range];
                // merge-walk the sorted target lists: surviving links copy
                let mut oi = 0usize;
                for (t, &j) in new_targets.iter().enumerate() {
                    while oi < old_targets.len() && old_targets[oi] < j {
                        oi += 1;
                    }
                    if oi < old_targets.len() && old_targets[oi] == j {
                        new_row[t] = old_row[oi];
                        oi += 1;
                    }
                }
                // the CPU slot always survives (last in both rows)
                let w = new_row.len();
                new_row[w - 1] = old_row[old_row.len() - 1];
                if new_row.iter().sum::<f64>() > PHI_EPS {
                    renormalize_row(new_row, 1.0);
                } else {
                    emptied.push(i);
                }
            }
            for &i in &emptied {
                reseed_row(&mut out, i);
            }
            if !out.topo_order_into(s, &mut scratch) {
                // surviving rows mixed with reseeded ones closed a cycle the
                // old strategy never had: fall back to a min-hop stage
                for i in 0..n {
                    if is_final && i == dest {
                        continue;
                    }
                    reseed_row(&mut out, i);
                }
                debug_assert!(out.topo_order_into(s, &mut scratch));
            }
        }
        out
    }

    /// Serialize φ as `[stage][arena slot]` (the checkpoint format; slots
    /// follow the CSR arena order — node 0's row, node 1's row, …).
    /// Restored by [`Strategy::from_json`] on the same graph; f64 values
    /// round-trip losslessly through [`crate::util::json`].
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Arr(self.phi.iter().map(|row| Json::arr_f64(row)).collect())
    }

    /// Rebuild a strategy on `graph`'s slot layout from [`Strategy::to_json`]
    /// output. Rejects stage or arena shape mismatches.
    pub fn from_json(graph: &Graph, v: &crate::util::json::Json) -> anyhow::Result<Strategy> {
        use crate::util::json::Json;
        let stages = v
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("phi: expected a [stage][slot] array"))?;
        let mut phi = Strategy::zeros(graph, stages.len());
        let slots = phi.layout.num_slots();
        for (s, row) in stages.iter().enumerate() {
            let row = row
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("phi stage {s}: expected an array"))?;
            anyhow::ensure!(
                row.len() == slots,
                "phi stage {s}: {} slots, graph arena has {slots}",
                row.len()
            );
            for (t, x) in row.iter().enumerate() {
                phi.phi[s][t] = x
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("phi stage {s} slot {t}: not a number"))?;
            }
        }
        Ok(phi)
    }

    /// L∞ distance between two strategies (convergence diagnostics).
    pub fn max_diff(&self, other: &Strategy) -> f64 {
        let mut d: f64 = 0.0;
        for (a, b) in self.phi.iter().zip(&other.phi) {
            for (x, y) in a.iter().zip(b) {
                d = d.max((x - y).abs());
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::{Application, Network, StageRegistry};
    use crate::cost::CostFn;
    use crate::graph::topologies;

    fn net_on(g: crate::graph::Graph) -> Network {
        let n = g.n();
        let m = g.m();
        let mut r = vec![0.0; n];
        r[0] = 1.0;
        r[3] = 0.5;
        let apps = vec![Application {
            dest: 10,
            num_tasks: 2,
            packet_sizes: vec![10.0, 5.0, 1.0],
            input_rates: r,
        }];
        let stages = StageRegistry::new(&apps);
        let cw = vec![vec![1.0; n]; stages.len()];
        Network::new(
            g,
            apps,
            vec![CostFn::Linear { d: 1.0 }; m],
            vec![CostFn::Linear { d: 1.0 }; n],
            cw,
        )
        .unwrap()
    }

    fn net() -> Network {
        net_on(topologies::abilene())
    }

    /// Abilene minus the given directed pairs.
    fn net_without(pairs: &[(usize, usize)]) -> Network {
        let g0 = topologies::abilene();
        let edges: Vec<(usize, usize)> = g0
            .edges()
            .iter()
            .copied()
            .filter(|e| !pairs.contains(e))
            .collect();
        net_on(crate::graph::Graph::new(g0.n(), &edges).unwrap())
    }

    #[test]
    fn shortest_path_init_is_feasible_and_loop_free() {
        let net = net();
        let phi = Strategy::shortest_path_to_dest(&net);
        phi.validate(&net).unwrap();
        assert!(!phi.has_loop());
    }

    #[test]
    fn fractional_split_degenerates_to_shortest_path_on_identity_chains() {
        let net = net();
        let sp = Strategy::shortest_path_to_dest(&net);
        let fr = Strategy::fractional_split(&net);
        assert_eq!(sp, fr);
    }

    #[test]
    fn fractional_split_is_feasible_loop_free_and_splits_compute() {
        let g = topologies::abilene();
        let n = g.n();
        let m = g.m();
        let mut r = vec![0.0; n];
        r[0] = 1.0;
        let apps = vec![Application {
            dest: 10,
            num_tasks: 2,
            packet_sizes: vec![10.0, 5.0, 1.0],
            input_rates: r,
        }];
        let stages = StageRegistry::new(&apps);
        let cw = vec![vec![1.0; n]; stages.len()];
        let chain = crate::chain::ChainProfile {
            conv: vec![2.0, 0.5],
            result_size: 0.0,
            local_frac: vec![0.7, 0.3],
        };
        let net = Network::with_chains(
            g,
            apps,
            vec![CostFn::Linear { d: 1.0 }; m],
            vec![CostFn::Linear { d: 1.0 }; n],
            cw,
            vec![chain],
        )
        .unwrap();
        let phi = Strategy::fractional_split(&net);
        phi.validate(&net).unwrap();
        assert!(!phi.has_loop());
        // stage 0 at a non-destination node: local_frac[0] on the CPU slot
        assert!((phi.cpu_frac(0, 0) - 0.7).abs() < 1e-12);
        assert!((phi.cpu_frac(1, 0) - 0.3).abs() < 1e-12);
        // destination offloads fully on non-final stages
        assert!((phi.cpu_frac(0, 10) - 1.0).abs() < 1e-12);
        // final stage never computes
        assert_eq!(phi.cpu_frac(2, 0), 0.0);
    }

    #[test]
    fn random_init_is_feasible_and_loop_free_many_seeds() {
        let net = net();
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let phi = Strategy::random_dag(&net, &mut rng);
            phi.validate(&net).unwrap();
            assert!(!phi.has_loop(), "seed {seed}");
        }
    }

    #[test]
    fn validate_catches_bad_rows() {
        let net = net();
        let mut phi = Strategy::shortest_path_to_dest(&net);
        // break a row sum
        phi.set(0, 0, 1, 0.5);
        assert!(phi.validate(&net).is_err());
    }

    #[test]
    #[should_panic(expected = "not a link")]
    fn set_rejects_non_link_direction() {
        let net = net();
        let mut phi = Strategy::shortest_path_to_dest(&net);
        // 0 -> 10 is not an Abilene link: no slot exists for it
        phi.set(0, 0, 10, 1.0);
    }

    #[test]
    fn non_link_directions_read_as_zero() {
        let net = net();
        let phi = Strategy::shortest_path_to_dest(&net);
        assert_eq!(phi.get(0, 0, 10), 0.0);
        // and the sparse row width is the out-degree + CPU
        assert_eq!(
            phi.row(0, 0).len(),
            net.graph.out_neighbors(0).len() + 1
        );
    }

    #[test]
    fn loop_detection() {
        let net = net();
        let mut phi = Strategy::shortest_path_to_dest(&net);
        // create a 2-cycle 0 <-> 1 in stage 0
        let s = 0;
        let r0 = phi.row_mut(s, 0);
        r0.iter_mut().for_each(|v| *v = 0.0);
        phi.set(s, 0, 1, 1.0);
        let r1 = phi.row_mut(s, 1);
        r1.iter_mut().for_each(|v| *v = 0.0);
        phi.set(s, 1, 0, 1.0);
        assert!(phi.has_loop());
        assert!(phi.topo_order(s).is_none());
    }

    #[test]
    fn topo_order_covers_all_nodes() {
        let net = net();
        let phi = Strategy::shortest_path_to_dest(&net);
        for s in 0..net.num_stages() {
            let order = phi.topo_order(s).unwrap();
            assert_eq!(order.len(), net.n());
        }
    }

    #[test]
    fn topo_order_into_reuses_scratch() {
        let net = net();
        let phi = Strategy::shortest_path_to_dest(&net);
        let mut scratch = TopoScratch::new(net.n());
        for s in 0..net.num_stages() {
            assert!(phi.topo_order_into(s, &mut scratch));
            assert_eq!(scratch.order, phi.topo_order(s).unwrap());
        }
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let net = net();
        let mut rng = Rng::new(5);
        let phi = Strategy::random_dag(&net, &mut rng);
        let text = phi.to_json().to_string_pretty();
        let v = crate::util::json::Json::parse(&text).unwrap();
        let re = Strategy::from_json(&net.graph, &v).unwrap();
        assert_eq!(re, phi, "phi must round-trip bit-exactly through JSON");
        // shape mismatches are rejected
        let small = crate::graph::Graph::new(2, &[(0, 1), (1, 0)]).unwrap();
        assert!(Strategy::from_json(&small, &v).is_err());
    }

    #[test]
    fn rebind_onto_identical_layout_is_exact() {
        let net = net();
        let mut rng = Rng::new(11);
        let phi = Strategy::random_dag(&net, &mut rng);
        let re = phi.rebind_topology(&net);
        assert_eq!(re.max_diff(&phi), 0.0, "identity rebind must copy verbatim");
    }

    #[test]
    fn rebind_after_link_removal_is_feasible_many_seeds() {
        let full = net();
        let pruned = net_without(&[(0, 1), (1, 0), (4, 5), (5, 4)]);
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let phi = Strategy::random_dag(&full, &mut rng);
            let re = phi.rebind_topology(&pruned);
            re.validate(&pruned)
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            assert!(!re.has_loop(), "seed {seed}: rebind introduced a loop");
            // removed directions have no slot — structurally zero
            assert_eq!(re.get(0, 0, 1), 0.0);
            assert_eq!(re.get(0, 4, 5), 0.0);
        }
    }

    #[test]
    fn rebind_redistributes_orphaned_mass_proportionally() {
        let full = net();
        let pruned = net_without(&[(1, 0), (1, 2)]);
        let mut phi = Strategy::zeros(&full.graph, full.num_stages());
        for (s, (a, _)) in full.stages.iter() {
            let dest = full.apps[a].dest;
            let is_final = full.is_final_stage(s);
            for i in 0..full.n() {
                if is_final && i == dest {
                    continue;
                }
                if i == 1 && !is_final {
                    // node 1 (abilene: links to 0, 2, 3): half the mass on
                    // soon-dead links, the rest split 0.3 link / 0.2 CPU
                    phi.set(s, 1, 0, 0.25);
                    phi.set(s, 1, 2, 0.25);
                    phi.set(s, 1, 3, 0.3);
                    phi.set(s, 1, phi.cpu(), 0.2);
                } else if i == dest && !is_final {
                    phi.set(s, i, phi.cpu(), 1.0);
                } else {
                    let (_d, next) = full.graph.dijkstra_to(dest, |_| 1.0);
                    phi.set(s, i, next[i], 1.0);
                }
            }
        }
        phi.validate(&full).unwrap();
        let re = phi.rebind_topology(&pruned);
        re.validate(&pruned).unwrap();
        // 0.5 orphaned mass spreads 0.3:0.2 over the survivors
        assert!((re.get(0, 1, 3) - 0.6).abs() < 1e-12, "{}", re.get(0, 1, 3));
        assert!((re.cpu_frac(0, 1) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn rebind_reseeds_emptied_rows_min_hop() {
        let full = net();
        // node 0's only abilene out-links are 1 and 2; shortest-path init
        // puts all of node 0's mass on one of them
        let phi = Strategy::shortest_path_to_dest(&full);
        let hop = full.graph.dijkstra_to(10, |_| 1.0).1[0];
        let dead = [(0, hop), (hop, 0)];
        let pruned = net_without(&dead);
        let re = phi.rebind_topology(&pruned);
        re.validate(&pruned).unwrap();
        assert!(!re.has_loop());
        // the emptied row re-routes along the pruned graph's min-hop tree
        let want = pruned.graph.dijkstra_to(10, |_| 1.0).1[0];
        assert_eq!(re.get(0, 0, want), 1.0);
    }

    #[test]
    fn rebind_restores_links_with_zero_mass() {
        let full = net();
        let pruned = net_without(&[(0, 1), (1, 0)]);
        let mut rng = Rng::new(3);
        let phi = Strategy::random_dag(&pruned, &mut rng);
        let re = phi.rebind_topology(&full);
        re.validate(&full).unwrap();
        assert!(!re.has_loop());
        // repaired links come back as fresh slots with no mass yet
        assert_eq!(re.get(0, 0, 1), 0.0);
        assert_eq!(re.get(0, 1, 0), 0.0);
        // and surviving rows are untouched (sum already 1 → verbatim copy)
        assert_eq!(re.row(0, 5), phi.row(0, 5));
    }

    #[test]
    fn renormalize_fixes_drift() {
        let net = net();
        let mut phi = Strategy::shortest_path_to_dest(&net);
        let j = net.graph.out_neighbors(0)[0];
        let cur = phi.get(0, 0, j);
        phi.set(0, 0, j, cur + 1e-9);
        phi.renormalize(&net);
        phi.validate(&net).unwrap();
    }
}
