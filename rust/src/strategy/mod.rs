//! The forwarding/offloading strategy φ.
//!
//! For each stage (a,k) and node i, `φ_ij(a,k)` is the fraction of i's stage
//! traffic forwarded to neighbor j, and `φ_i0(a,k)` (the CPU slot) the
//! fraction handed to i's local CPU to run task k+1. Constraint (1):
//! each (stage, node) row sums to 1, except the (final stage, destination)
//! row which sums to 0 (results exit the network there).
//!
//! Storage is dense: per stage an (n) × (n+1) row-major matrix; column `n`
//! is the CPU slot. Dense storage keeps the GP update, the XLA bridge and
//! the broadcast protocol simple; evaluation sizes (n ≤ 100) make it cheap.

use crate::app::Network;
use crate::util::rng::Rng;

/// Tolerance for treating a forwarding fraction as zero.
pub const PHI_EPS: f64 = 1e-12;

/// Renormalize a single φ row to sum `want` (0.0 for exit rows, 1.0
/// otherwise): zero sub-PHI_EPS entries, then rescale — but only when the
/// sum is off by more than 1e-9, keeping the operation idempotent. Shared
/// by [`Strategy::renormalize`] and the distributed node actors so both
/// produce bit-identical rows.
pub fn renormalize_row(row: &mut [f64], want: f64) {
    for v in row.iter_mut() {
        if *v < PHI_EPS {
            *v = 0.0;
        }
    }
    if want == 0.0 {
        row.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    let sum: f64 = row.iter().sum();
    if sum > PHI_EPS && (sum - want).abs() > 1e-9 {
        let inv = want / sum;
        row.iter_mut().for_each(|v| *v *= inv);
    }
}

/// Dense strategy variable φ.
#[derive(Clone, Debug, PartialEq)]
pub struct Strategy {
    n: usize,
    num_stages: usize,
    /// [stage][i*(n+1) + j]; j == n is the CPU slot.
    phi: Vec<Vec<f64>>,
}

impl Strategy {
    /// All-zero strategy (infeasible until rows are filled).
    pub fn zeros(n: usize, num_stages: usize) -> Self {
        Strategy {
            n,
            num_stages,
            phi: vec![vec![0.0; n * (n + 1)]; num_stages],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }
    pub fn num_stages(&self) -> usize {
        self.num_stages
    }
    /// Column index of the CPU slot.
    pub fn cpu(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, s: usize, i: usize, j: usize) -> f64 {
        self.phi[s][i * (self.n + 1) + j]
    }
    #[inline]
    pub fn set(&mut self, s: usize, i: usize, j: usize, v: f64) {
        self.phi[s][i * (self.n + 1) + j] = v;
    }
    /// Row φ_i(a,k) of length n+1 (last entry = CPU).
    #[inline]
    pub fn row(&self, s: usize, i: usize) -> &[f64] {
        &self.phi[s][i * (self.n + 1)..(i + 1) * (self.n + 1)]
    }
    #[inline]
    pub fn row_mut(&mut self, s: usize, i: usize) -> &mut [f64] {
        &mut self.phi[s][i * (self.n + 1)..(i + 1) * (self.n + 1)]
    }

    /// Out-neighbors with positive forwarding fraction (excluding CPU).
    pub fn positive_links(&self, s: usize, i: usize) -> impl Iterator<Item = usize> + '_ {
        let row = self.row(s, i);
        (0..self.n).filter(move |&j| row[j] > PHI_EPS)
    }

    /// CPU fraction φ_i0.
    pub fn cpu_frac(&self, s: usize, i: usize) -> f64 {
        self.get(s, i, self.n)
    }

    /// Validate feasibility w.r.t. a network: row sums (constraint (1)),
    /// support restricted to existing links, no CPU offload at final stages,
    /// and non-negativity.
    pub fn validate(&self, net: &Network) -> anyhow::Result<()> {
        anyhow::ensure!(self.n == net.n(), "node count mismatch");
        anyhow::ensure!(self.num_stages == net.num_stages(), "stage count mismatch");
        for (s, (a, _k)) in net.stages.iter() {
            let is_final = net.is_final_stage(s);
            let dest = net.apps[a].dest;
            for i in 0..self.n {
                let row = self.row(s, i);
                let mut sum = 0.0;
                for (j, &v) in row.iter().enumerate() {
                    anyhow::ensure!(
                        v >= -PHI_EPS && v <= 1.0 + 1e-9,
                        "phi[{s}][{i}][{j}] = {v} out of [0,1]"
                    );
                    if j < self.n && v > PHI_EPS {
                        anyhow::ensure!(
                            net.graph.has_edge(i, j),
                            "phi[{s}][{i}][{j}] > 0 but ({i},{j}) not a link"
                        );
                    }
                    if j == self.n && v > PHI_EPS {
                        anyhow::ensure!(
                            !is_final,
                            "stage {s} is final but phi_cpu[{i}] = {v} > 0"
                        );
                    }
                    sum += v;
                }
                let want = if is_final && i == dest { 0.0 } else { 1.0 };
                anyhow::ensure!(
                    (sum - want).abs() < 1e-6,
                    "row sum phi[{s}][{i}] = {sum}, want {want}"
                );
            }
        }
        Ok(())
    }

    /// Does any stage contain a directed cycle through positive-φ links?
    /// (CPU transitions advance the stage and cannot close a loop.)
    pub fn has_loop(&self) -> bool {
        for s in 0..self.num_stages {
            if self.stage_has_loop(s) {
                return true;
            }
        }
        false
    }

    fn stage_has_loop(&self, s: usize) -> bool {
        // Kahn's algorithm on the positive-φ link subgraph.
        let n = self.n;
        let mut indeg = vec![0usize; n];
        for i in 0..n {
            for j in self.positive_links(s, i) {
                indeg[j] += 1;
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut removed = 0;
        while let Some(u) = queue.pop() {
            removed += 1;
            for j in self.positive_links(s, u) {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    queue.push(j);
                }
            }
        }
        removed < n
    }

    /// Topological order of nodes for stage `s` over positive-φ links.
    /// Returns `None` if the stage subgraph has a cycle.
    pub fn topo_order(&self, s: usize) -> Option<Vec<usize>> {
        let n = self.n;
        let mut indeg = vec![0usize; n];
        for i in 0..n {
            for j in self.positive_links(s, i) {
                indeg[j] += 1;
            }
        }
        let mut queue: std::collections::VecDeque<usize> =
            (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for j in self.positive_links(s, u) {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    queue.push_back(j);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    /// Renormalize every row to satisfy constraint (1) exactly (fixes small
    /// numerical drift after many GP iterations). Idempotent: rows already
    /// within 1e-9 of their target sum are left untouched, so the leader's
    /// mirror and the node-local copies ([`crate::distributed`]) stay
    /// bit-identical under repeated application.
    pub fn renormalize(&mut self, net: &Network) {
        for (s, (a, _)) in net.stages.iter() {
            let is_final = net.is_final_stage(s);
            let dest = net.apps[a].dest;
            for i in 0..self.n {
                let want = if is_final && i == dest { 0.0 } else { 1.0 };
                renormalize_row(self.row_mut(s, i), want);
            }
        }
    }

    // ---- initial strategies ------------------------------------------------

    /// Feasible loop-free initialization: every stage forwards along the
    /// min-hop path to the application's destination; all computation happens
    /// at the destination (φ_{d_a,cpu}(a,k) = 1 for k < |𝒯_a|).
    ///
    /// Loop-freeness: next hops strictly decrease hop distance to d_a.
    pub fn shortest_path_to_dest(net: &Network) -> Self {
        let n = net.n();
        let mut phi = Strategy::zeros(n, net.num_stages());
        for (s, (a, _k)) in net.stages.iter() {
            let dest = net.apps[a].dest;
            let (_dist, next) = net.graph.dijkstra_to(dest, |_| 1.0);
            let is_final = net.is_final_stage(s);
            for i in 0..n {
                if i == dest {
                    if !is_final {
                        phi.set(s, i, phi.cpu(), 1.0); // compute at destination
                    }
                    // final stage at dest: row stays zero (exit)
                } else {
                    phi.set(s, i, next[i], 1.0);
                }
            }
        }
        phi
    }

    /// Random feasible loop-free initialization: every node spreads its
    /// stage-(a,k) traffic across neighbors strictly closer (in hop count) to
    /// d_a with random weights, plus a random CPU fraction (if not final).
    pub fn random_dag(net: &Network, rng: &mut Rng) -> Self {
        let n = net.n();
        let mut phi = Strategy::zeros(n, net.num_stages());
        for (s, (a, _k)) in net.stages.iter() {
            let dest = net.apps[a].dest;
            let (dist, _next) = net.graph.dijkstra_to(dest, |_| 1.0);
            let is_final = net.is_final_stage(s);
            for i in 0..n {
                if i == dest && is_final {
                    continue;
                }
                let mut weights = vec![0.0; n + 1];
                for &j in net.graph.out_neighbors(i) {
                    if dist[j] < dist[i] {
                        weights[j] = rng.range(0.1, 1.0);
                    }
                }
                if !is_final {
                    weights[n] = rng.range(0.1, 1.0);
                }
                let sum: f64 = weights.iter().sum();
                if sum <= 0.0 {
                    // destination node of a non-final stage with no downhill
                    // neighbor: must offload locally
                    debug_assert!(!is_final);
                    phi.set(s, i, n, 1.0);
                } else {
                    for (j, w) in weights.into_iter().enumerate() {
                        if w > 0.0 {
                            phi.set(s, i, j, w / sum);
                        }
                    }
                }
            }
        }
        phi
    }

    /// L∞ distance between two strategies (convergence diagnostics).
    pub fn max_diff(&self, other: &Strategy) -> f64 {
        let mut d: f64 = 0.0;
        for (a, b) in self.phi.iter().zip(&other.phi) {
            for (x, y) in a.iter().zip(b) {
                d = d.max((x - y).abs());
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::{Application, Network, StageRegistry};
    use crate::cost::CostFn;
    use crate::graph::topologies;

    fn net() -> Network {
        let g = topologies::abilene();
        let n = g.n();
        let m = g.m();
        let mut r = vec![0.0; n];
        r[0] = 1.0;
        r[3] = 0.5;
        let apps = vec![Application {
            dest: 10,
            num_tasks: 2,
            packet_sizes: vec![10.0, 5.0, 1.0],
            input_rates: r,
        }];
        let stages = StageRegistry::new(&apps);
        let cw = vec![vec![1.0; n]; stages.len()];
        Network::new(
            g,
            apps,
            vec![CostFn::Linear { d: 1.0 }; m],
            vec![CostFn::Linear { d: 1.0 }; n],
            cw,
        )
        .unwrap()
    }

    #[test]
    fn shortest_path_init_is_feasible_and_loop_free() {
        let net = net();
        let phi = Strategy::shortest_path_to_dest(&net);
        phi.validate(&net).unwrap();
        assert!(!phi.has_loop());
    }

    #[test]
    fn random_init_is_feasible_and_loop_free_many_seeds() {
        let net = net();
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let phi = Strategy::random_dag(&net, &mut rng);
            phi.validate(&net).unwrap();
            assert!(!phi.has_loop(), "seed {seed}");
        }
    }

    #[test]
    fn validate_catches_bad_rows() {
        let net = net();
        let mut phi = Strategy::shortest_path_to_dest(&net);
        // break a row sum
        phi.set(0, 0, 1, 0.5);
        assert!(phi.validate(&net).is_err());
    }

    #[test]
    fn validate_catches_non_link_support() {
        let net = net();
        let mut phi = Strategy::shortest_path_to_dest(&net);
        // 0 -> 10 is not an Abilene link
        let row = phi.row_mut(0, 0);
        row.iter_mut().for_each(|v| *v = 0.0);
        phi.set(0, 0, 10, 1.0);
        assert!(phi.validate(&net).is_err());
    }

    #[test]
    fn loop_detection() {
        let net = net();
        let mut phi = Strategy::shortest_path_to_dest(&net);
        // create a 2-cycle 0 <-> 1 in stage 0
        let s = 0;
        let r0 = phi.row_mut(s, 0);
        r0.iter_mut().for_each(|v| *v = 0.0);
        phi.set(s, 0, 1, 1.0);
        let r1 = phi.row_mut(s, 1);
        r1.iter_mut().for_each(|v| *v = 0.0);
        phi.set(s, 1, 0, 1.0);
        assert!(phi.has_loop());
        assert!(phi.topo_order(s).is_none());
    }

    #[test]
    fn topo_order_covers_all_nodes() {
        let net = net();
        let phi = Strategy::shortest_path_to_dest(&net);
        for s in 0..net.num_stages() {
            let order = phi.topo_order(s).unwrap();
            assert_eq!(order.len(), net.n());
        }
    }

    #[test]
    fn renormalize_fixes_drift() {
        let net = net();
        let mut phi = Strategy::shortest_path_to_dest(&net);
        let j = net.graph.out_neighbors(0)[0];
        let cur = phi.get(0, 0, j);
        phi.set(0, 0, j, cur + 1e-9);
        phi.renormalize(&net);
        phi.validate(&net).unwrap();
    }
}
