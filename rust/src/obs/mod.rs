//! Unified observability: span tracing with a preallocated flight recorder
//! and Chrome trace-event export.
//!
//! The serving stack is an *online* system — when a massive-tier slot is
//! slow, operators need to see whether the time went to SoA sampling, the
//! GP projection, the marginal recursion or transport queues, live, without
//! perturbing any determinism gate. This module provides that as a std-only
//! layer:
//!
//! * **Span records** — fixed-size [`SpanRecord`] values carrying the
//!   subsystem, a static span name, wall-clock nanoseconds (via
//!   [`crate::util::timer::monotonic_ns`]) and the *virtual coordinates* of
//!   the moment: serving slot, GP iteration, control epoch and topology
//!   epoch. Virtual coordinates are what make traces comparable across
//!   machines — wall time is volatile, the slot/epoch lattice is not.
//! * **Flight recorder** — a preallocated fixed-capacity ring
//!   ([`FlightRecorder`]) behind a process-wide mutex. When the ring is
//!   full the oldest span is overwritten (`dropped` counts the losses), so
//!   memory is bounded no matter how long the server runs.
//! * **Zero cost when disabled** — the [`obs_span!`] macro expands to a
//!   guard whose construction is one relaxed atomic load when the recorder
//!   is off: no clock read, no lock, no allocation. The hot-path
//!   allocation-freedom gate (`rust/tests/alloc_free.rs`) pins this.
//!   When enabled, recording never allocates either: the ring's capacity
//!   is reserved up front and records are plain `Copy` values.
//! * **Chrome trace-event export** — [`chrome_trace_json`] renders the
//!   retained spans as a JSON array of matched `B`/`E` events (with
//!   `pid`/`tid`/`ts`/`name`/`cat` and the virtual coordinates as `args`)
//!   that loads directly in `chrome://tracing` / [Perfetto]. The CLI's
//!   `--profile out.json` flag and the ops API's `GET /profile` both go
//!   through it.
//!
//! Span taxonomy, naming rules and the workflow: `docs/OBSERVABILITY.md`.
//!
//! [Perfetto]: https://ui.perfetto.dev

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::timer::monotonic_ns;

/// Default flight-recorder capacity (spans). At ~80 bytes per record this
/// is a few MiB — hours of slot-level spans, seconds of iteration-level
/// ones. Override via [`enable`].
pub const DEFAULT_CAPACITY: usize = 65_536;

/// One completed span. Fixed-size and `Copy`: recording moves no heap data.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpanRecord {
    /// Subsystem slug (`"gp"`, `"serving"`, `"workload"`, `"control"`,
    /// `"distributed"`, `"bench"`).
    pub subsystem: &'static str,
    /// Span name within the subsystem (static — spans never format strings).
    pub name: &'static str,
    /// Start, nanoseconds since the process monotonic origin.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// Recording thread (small dense id, not the OS tid).
    pub tid: u64,
    /// Virtual coordinates at record time (0 until the owning loop sets
    /// them): serving slot, GP iteration, control epoch, topology epoch.
    pub slot: u64,
    pub gp_iter: u64,
    pub control_epoch: u64,
    pub topo_epoch: u64,
}

/// Preallocated ring of span records. All methods are allocation-free
/// after construction; overflow overwrites the oldest record.
#[derive(Debug)]
pub struct FlightRecorder {
    ring: Vec<SpanRecord>,
    /// Next write position once the ring is full.
    head: usize,
    /// All-time recorded spans (retained + overwritten).
    recorded: u64,
    cap: usize,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> FlightRecorder {
        let cap = capacity.max(1);
        FlightRecorder {
            ring: Vec::with_capacity(cap),
            head: 0,
            recorded: 0,
            cap,
        }
    }

    /// Append one record (O(1), never allocates: capacity is reserved).
    pub fn push(&mut self, rec: SpanRecord) {
        self.recorded += 1;
        if self.ring.len() < self.cap {
            self.ring.push(rec);
        } else {
            self.ring[self.head] = rec;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Retained spans, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let mut out = Vec::with_capacity(self.ring.len());
        out.extend_from_slice(&self.ring[self.head..]);
        out.extend_from_slice(&self.ring[..self.head]);
        out
    }

    /// All-time recorded spans.
    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Spans lost to ring overflow.
    pub fn dropped(&self) -> u64 {
        self.recorded - self.ring.len() as u64
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn clear(&mut self) {
        self.ring.clear();
        self.head = 0;
        self.recorded = 0;
    }
}

// ---- process-wide recorder state -------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static RECORDER: Mutex<Option<FlightRecorder>> = Mutex::new(None);

// Virtual coordinates, set by the owning loops (serving slot, GP step,
// control-plane commit, topology commit). Plain relaxed atomics: cheap
// enough to keep current even while tracing is disabled, and never
// allocating.
static SLOT: AtomicU64 = AtomicU64::new(0);
static GP_ITER: AtomicU64 = AtomicU64::new(0);
static CONTROL_EPOCH: AtomicU64 = AtomicU64::new(0);
static TOPO_EPOCH: AtomicU64 = AtomicU64::new(0);

/// Dense per-thread id for trace export (`tid` in the Chrome events).
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
}

fn thread_tid() -> u64 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        v
    })
}

fn lock_recorder() -> std::sync::MutexGuard<'static, Option<FlightRecorder>> {
    RECORDER.lock().unwrap_or_else(|e| e.into_inner())
}

/// Turn the flight recorder on, (re)allocating its ring to `capacity`.
/// The one place the observability layer allocates.
pub fn enable(capacity: usize) {
    let mut g = lock_recorder();
    *g = Some(FlightRecorder::new(capacity));
    ENABLED.store(true, Ordering::Release);
}

/// Stop recording. Retained spans stay exportable until [`clear`].
pub fn disable() {
    ENABLED.store(false, Ordering::Release);
}

/// Drop the recorder and its spans entirely.
pub fn clear() {
    ENABLED.store(false, Ordering::Release);
    *lock_recorder() = None;
}

/// Is span recording on? One relaxed load — the whole cost of a disabled
/// [`obs_span!`] site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Set the current serving slot virtual coordinate.
#[inline]
pub fn set_slot(v: u64) {
    SLOT.store(v, Ordering::Relaxed);
}
/// Set the current GP iteration virtual coordinate.
#[inline]
pub fn set_gp_iter(v: u64) {
    GP_ITER.store(v, Ordering::Relaxed);
}
/// Set the current control-plane epoch virtual coordinate.
#[inline]
pub fn set_control_epoch(v: u64) {
    CONTROL_EPOCH.store(v, Ordering::Relaxed);
}
/// Set the current topology epoch virtual coordinate.
#[inline]
pub fn set_topo_epoch(v: u64) {
    TOPO_EPOCH.store(v, Ordering::Relaxed);
}

/// Record one completed span into the global recorder (no-op when off).
pub fn record(subsystem: &'static str, name: &'static str, start_ns: u64, dur_ns: u64) {
    if !enabled() {
        return;
    }
    let rec = SpanRecord {
        subsystem,
        name,
        start_ns,
        dur_ns,
        tid: thread_tid(),
        slot: SLOT.load(Ordering::Relaxed),
        gp_iter: GP_ITER.load(Ordering::Relaxed),
        control_epoch: CONTROL_EPOCH.load(Ordering::Relaxed),
        topo_epoch: TOPO_EPOCH.load(Ordering::Relaxed),
    };
    if let Some(r) = lock_recorder().as_mut() {
        r.push(rec);
    }
}

/// RAII span: created by [`obs_span!`], records itself on drop. Inert (no
/// clock read, no lock) while the recorder is disabled.
pub struct SpanGuard {
    subsystem: &'static str,
    name: &'static str,
    start_ns: u64,
    active: bool,
}

impl SpanGuard {
    #[inline]
    pub fn begin(subsystem: &'static str, name: &'static str) -> SpanGuard {
        if !enabled() {
            return SpanGuard {
                subsystem,
                name,
                start_ns: 0,
                active: false,
            };
        }
        SpanGuard {
            subsystem,
            name,
            start_ns: monotonic_ns(),
            active: true,
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.active {
            let end = monotonic_ns();
            record(
                self.subsystem,
                self.name,
                self.start_ns,
                end.saturating_sub(self.start_ns),
            );
        }
    }
}

/// Open a span that closes at end of scope:
/// `let _span = obs_span!("gp", "flow-solve");`
/// Both arguments must be `&'static str`. One relaxed atomic load when the
/// recorder is disabled; never allocates either way.
#[macro_export]
macro_rules! obs_span {
    ($subsystem:expr, $name:expr) => {
        $crate::obs::SpanGuard::begin($subsystem, $name)
    };
}

// ---- stats + export --------------------------------------------------------

/// (retained, all-time recorded, dropped, capacity) of the global recorder;
/// zeros when no recorder exists.
pub fn stats() -> (usize, u64, u64, usize) {
    match lock_recorder().as_ref() {
        Some(r) => (r.snapshot().len(), r.recorded(), r.dropped(), r.capacity()),
        None => (0, 0, 0, 0),
    }
}

/// Retained spans of the global recorder, oldest first.
pub fn snapshot() -> Vec<SpanRecord> {
    lock_recorder().as_ref().map(FlightRecorder::snapshot).unwrap_or_default()
}

/// Trace-event phases: `E` (span end), `X` (complete, zero-duration here),
/// `B` (span begin). The discriminant order is the equal-timestamp sort
/// rank — closings drain, instants fire, then openings start.
#[derive(Clone, Copy, PartialEq)]
enum Phase {
    End,
    Complete,
    Begin,
}

/// Render spans as a Chrome trace-event JSON array: one matched `B`/`E`
/// pair per span (a zero-duration span becomes a single `X` complete
/// event — a `B`/`E` pair at one timestamp cannot be ordered), sorted by
/// timestamp (`ts` is microseconds since the process monotonic origin),
/// `pid` 1, `tid` the dense recording-thread id. Loads directly in
/// `chrome://tracing` and Perfetto.
pub fn chrome_trace_events(spans: &[SpanRecord]) -> Json {
    // (ts_ns, phase-rank, dur_ns, span). Ties sort E < X < B; among ties a
    // longer parent opens before / closes after its children, so nesting
    // survives equal timestamps.
    let mut keyed: Vec<(u64, u8, i64, &SpanRecord, Phase)> = Vec::with_capacity(spans.len() * 2);
    for s in spans {
        if s.dur_ns == 0 {
            keyed.push((s.start_ns, 1, 0, s, Phase::Complete));
        } else {
            keyed.push((s.start_ns, 2, -(s.dur_ns as i64), s, Phase::Begin));
            keyed.push((s.start_ns + s.dur_ns, 0, s.dur_ns as i64, s, Phase::End));
        }
    }
    keyed.sort_by(|a, b| (a.0, a.1, a.2, a.3.tid).cmp(&(b.0, b.1, b.2, b.3.tid)));
    let events = keyed
        .into_iter()
        .map(|(ts_ns, _, _, s, phase)| {
            let ph = match phase {
                Phase::End => "E",
                Phase::Complete => "X",
                Phase::Begin => "B",
            };
            let mut pairs = vec![
                ("ph", Json::Str(ph.into())),
                ("ts", Json::Num(ts_ns as f64 / 1e3)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(s.tid as f64)),
                ("name", Json::Str(s.name.to_string())),
                ("cat", Json::Str(s.subsystem.to_string())),
            ];
            if phase == Phase::Complete {
                pairs.push(("dur", Json::Num(0.0)));
            }
            if phase != Phase::End {
                pairs.push((
                    "args",
                    Json::obj(vec![
                        ("slot", Json::Num(s.slot as f64)),
                        ("gp_iter", Json::Num(s.gp_iter as f64)),
                        ("control_epoch", Json::Num(s.control_epoch as f64)),
                        ("topo_epoch", Json::Num(s.topo_epoch as f64)),
                    ]),
                ));
            }
            Json::obj(pairs)
        })
        .collect();
    Json::Arr(events)
}

/// The global recorder's retained spans as a Chrome trace-event array
/// (empty array when the recorder is off — still valid trace JSON).
pub fn chrome_trace_json() -> Json {
    chrome_trace_events(&snapshot())
}

/// Write the current flight-recorder snapshot to `path` as Chrome
/// trace-event JSON (the `--profile out.json` CLI flag).
pub fn write_profile(path: &std::path::Path) -> anyhow::Result<()> {
    let doc = chrome_trace_json();
    std::fs::write(path, doc.to_string_pretty())
        .map_err(|e| anyhow::anyhow!("cannot write profile {}: {e}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &'static str, start: u64, dur: u64) -> SpanRecord {
        SpanRecord {
            subsystem: "test",
            name,
            start_ns: start,
            dur_ns: dur,
            tid: 1,
            slot: 3,
            gp_iter: 7,
            control_epoch: 2,
            topo_epoch: 1,
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut r = FlightRecorder::new(3);
        for i in 0..5 {
            r.push(rec("s", i * 10, 5));
        }
        assert_eq!(r.recorded(), 5);
        assert_eq!(r.dropped(), 2);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 3);
        // oldest-first: spans 2, 3, 4 survive
        assert_eq!(
            snap.iter().map(|s| s.start_ns).collect::<Vec<_>>(),
            vec![20, 30, 40]
        );
        r.clear();
        assert_eq!(r.recorded(), 0);
        assert!(r.snapshot().is_empty());
    }

    #[test]
    fn chrome_export_emits_matched_sorted_be_pairs() {
        // parent [0, 100], child [10, 40], sibling [50, 60]
        let spans = [rec("parent", 0, 100), rec("child", 10, 30), rec("sib", 50, 10)];
        let doc = chrome_trace_events(&spans);
        let events = doc.as_arr().expect("array");
        assert_eq!(events.len(), 6);
        let mut last_ts = f64::NEG_INFINITY;
        let mut stack: Vec<String> = Vec::new();
        for e in events {
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last_ts, "ts must be monotone");
            last_ts = ts;
            assert!(e.get("pid").is_some() && e.get("tid").is_some());
            let name = e.get("name").unwrap().as_str().unwrap().to_string();
            match e.get("ph").unwrap().as_str().unwrap() {
                "B" => {
                    assert_eq!(
                        e.get("args").unwrap().get("gp_iter").unwrap().as_f64(),
                        Some(7.0)
                    );
                    stack.push(name);
                }
                "E" => assert_eq!(stack.pop().as_deref(), Some(name.as_str())),
                other => panic!("unexpected phase {other}"),
            }
        }
        assert!(stack.is_empty(), "unmatched B events: {stack:?}");
    }

    #[test]
    fn nesting_survives_equal_timestamps() {
        // parent and child begin at the same ns; child ends where sibling
        // begins — the tie-break must keep B(parent) < B(child) and
        // E(child) <= B(sibling) < E(parent)
        let spans = [rec("parent", 0, 100), rec("child", 0, 50), rec("sib", 50, 50)];
        let doc = chrome_trace_events(&spans);
        let seq: Vec<(String, String)> = doc
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| {
                (
                    e.get("ph").unwrap().as_str().unwrap().to_string(),
                    e.get("name").unwrap().as_str().unwrap().to_string(),
                )
            })
            .collect();
        let mut stack: Vec<&str> = Vec::new();
        for (ph, name) in &seq {
            if ph == "B" {
                stack.push(name);
            } else {
                assert_eq!(stack.pop(), Some(name.as_str()), "sequence {seq:?}");
            }
        }
        assert!(stack.is_empty());
    }

    #[test]
    fn zero_duration_spans_export_as_complete_events() {
        // a 0 ns span would otherwise emit E before its own B at one ts
        let spans = [rec("parent", 0, 100), rec("instant", 50, 0)];
        let doc = chrome_trace_events(&spans);
        let events = doc.as_arr().unwrap();
        assert_eq!(events.len(), 3);
        let phases: Vec<&str> = events
            .iter()
            .map(|e| e.get("ph").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(phases, vec!["B", "X", "E"]);
        let x = &events[1];
        assert_eq!(x.get("dur").unwrap().as_f64(), Some(0.0));
        assert_eq!(
            x.get("args").unwrap().get("slot").unwrap().as_f64(),
            Some(3.0)
        );
    }

    /// The global-recorder lifecycle in ONE test: enable/record/export/
    /// disable share process-wide state, so splitting this across parallel
    /// test threads would race.
    #[test]
    fn global_recorder_lifecycle() {
        assert!(!enabled());
        {
            // disabled spans are inert
            let _g = obs_span!("test", "disabled-span");
        }
        enable(16);
        assert!(enabled());
        set_slot(11);
        {
            let _g = obs_span!("test", "global-span");
        }
        let spans = snapshot();
        assert!(
            spans
                .iter()
                .any(|s| s.name == "global-span" && s.slot == 11),
            "recorded span missing: {spans:?}"
        );
        let (retained, recorded, _dropped, cap) = stats();
        assert!(retained >= 1 && recorded >= 1);
        assert_eq!(cap, 16);
        let doc = chrome_trace_json();
        assert!(doc.as_arr().unwrap().len() >= 2);
        clear();
        assert!(!enabled());
        assert!(snapshot().is_empty());
        // a disabled /profile export is still a valid (empty) trace array
        assert_eq!(chrome_trace_json(), Json::Arr(Vec::new()));
    }
}
