//! PJRT runtime: load and execute the AOT-compiled L2/L1 artifacts.
//!
//! `make artifacts` (the only time Python runs) lowers the JAX + Pallas
//! network evaluation to `artifacts/eval_n{N}_a{A}_k{K}.hlo.txt` plus
//! `manifest.json`. This module loads the HLO text, compiles it on the PJRT
//! CPU client once, and executes it from the L3 hot path: a scenario is
//! padded into the smallest fitting size bucket, evaluated, and the outputs
//! (aggregate cost, traffic, ∂D/∂t, δ-marginals) are unpadded back.
//!
//! [`XlaGp`] is the GP optimizer wired to this evaluator; it must produce
//! the same iterates as the pure-Rust [`crate::algo::gp::GradientProjection`]
//! (cross-checked in `rust/tests/xla_parity.rs`).

pub mod pjrt;
pub mod xla_stub;

pub use pjrt::{EvalOutputs, EvalRuntime, Manifest, XlaGp};

/// Default artifacts directory, overridable with `SCFO_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("SCFO_ARTIFACTS") {
        return std::path::PathBuf::from(d);
    }
    // walk up from cwd so tests/benches find the repo-root artifacts
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return std::path::PathBuf::from("artifacts");
        }
    }
}

/// True if the AOT artifacts have been built.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
