//! PJRT client wrapper: HLO-text artifact loading, padding, execution.

use std::path::Path;

use super::xla_stub as xla;
use crate::algo::blocked::BlockedSets;
use crate::algo::gp::{gp_row_update, GpOptions, GpReport, SupportMask};
use crate::app::Network;
use crate::cost::CostFn;
use crate::marginals::Marginals;
use crate::strategy::Strategy;
use crate::util::json::Json;

/// One size bucket from the manifest.
#[derive(Clone, Debug)]
pub struct Bucket {
    pub file: String,
    pub n: usize,
    pub num_apps: usize,
    pub kchain: usize,
}

impl Bucket {
    pub fn num_stages(&self) -> usize {
        self.num_apps * (self.kchain + 1)
    }
    /// Does a scenario of (n nodes, a apps, k tasks/app) fit?
    pub fn fits(&self, n: usize, a: usize, k: usize) -> bool {
        n <= self.n && a <= self.num_apps && k == self.kchain
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub buckets: Vec<Bucket>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let v = Json::parse(&text)?;
        let mut buckets = Vec::new();
        for b in v
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest: missing buckets"))?
        {
            buckets.push(Bucket {
                file: b
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("manifest: bucket.file"))?
                    .to_string(),
                n: b.get("n").and_then(Json::as_usize).unwrap_or(0),
                num_apps: b.get("num_apps").and_then(Json::as_usize).unwrap_or(0),
                kchain: b.get("kchain").and_then(Json::as_usize).unwrap_or(0),
            });
        }
        anyhow::ensure!(!buckets.is_empty(), "manifest has no buckets");
        Ok(Manifest { buckets })
    }

    /// Smallest bucket fitting the scenario.
    pub fn pick(&self, n: usize, a: usize, k: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.fits(n, a, k))
            .min_by_key(|b| b.n * b.num_stages())
    }
}

/// Outputs of one evaluation call, unpadded to the real scenario size.
#[derive(Clone, Debug)]
pub struct EvalOutputs {
    pub total_cost: f64,
    /// t_i(a,k): [stage][node].
    pub traffic: Vec<Vec<f64>>,
    /// ∂D/∂t: [stage][node].
    pub d_dt: Vec<Vec<f64>>,
    /// δ rows: [stage][CSR slot] — the sparse [`Marginals`] arena layout
    /// (per node: link slots ascending by target, CPU slot last).
    pub delta: Vec<Vec<f64>>,
}

/// A compiled evaluation executable for one bucket.
pub struct EvalRuntime {
    exe: xla::PjRtLoadedExecutable,
    bucket: Bucket,
    /// platform string, for logs
    pub platform: String,
}

impl EvalRuntime {
    /// Load the artifact fitting `net` and compile it on the PJRT CPU client.
    pub fn load_for(net: &Network) -> anyhow::Result<EvalRuntime> {
        let dir = super::artifacts_dir();
        Self::load_for_in(net, &dir)
    }

    pub fn load_for_in(net: &Network, dir: &Path) -> anyhow::Result<EvalRuntime> {
        let manifest = Manifest::load(dir)?;
        let kmax = net
            .apps
            .iter()
            .map(|a| a.num_tasks)
            .max()
            .unwrap_or(0);
        // every app must have the bucket's chain length; shorter chains are
        // padded by the packer (see pack()), so only the max matters here.
        let bucket = manifest
            .pick(net.n(), net.apps.len(), kmax)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no bucket fits n={} apps={} k={kmax}",
                    net.n(),
                    net.apps.len()
                )
            })?
            .clone();
        let client = xla::PjRtClient::cpu()?;
        let platform = client.platform_name();
        let proto = xla::HloModuleProto::from_text_file(
            dir.join(&bucket.file)
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(EvalRuntime {
            exe,
            bucket,
            platform,
        })
    }

    pub fn bucket(&self) -> &Bucket {
        &self.bucket
    }

    /// Evaluate the network state under `phi` on the XLA executable.
    pub fn eval(&self, net: &Network, phi: &Strategy) -> anyhow::Result<EvalOutputs> {
        let inputs = self.pack(net, phi)?;
        let literals: Vec<xla::Literal> = inputs
            .into_iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(&data);
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims_i64).map_err(anyhow::Error::from)
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 7, "expected 7 outputs, got {}", outs.len());
        self.unpack(net, outs)
    }

    /// Pack the real scenario into padded bucket-shaped f64 arrays
    /// (returns (flat data, dims) pairs in the manifest input order).
    fn pack(&self, net: &Network, phi: &Strategy) -> anyhow::Result<Vec<(Vec<f64>, Vec<usize>)>> {
        let bn = self.bucket.n;
        let ba = self.bucket.num_apps;
        let bk1 = self.bucket.kchain + 1;
        let bs = ba * bk1;
        let n = net.n();
        anyhow::ensure!(n <= bn && net.apps.len() <= ba, "scenario exceeds bucket");
        for app in &net.apps {
            anyhow::ensure!(
                app.num_tasks == self.bucket.kchain,
                "bucket requires |T_a| == {} (got {})",
                self.bucket.kchain,
                app.num_tasks
            );
        }

        let mut phi_link = vec![0.0; bs * bn * bn];
        let mut phi_cpu = vec![0.0; bs * bn];
        let mut exo = vec![0.0; ba * bn];
        let mut adj = vec![0.0; bn * bn];
        let mut link_isq = vec![0.0; bn * bn];
        let mut link_lin = vec![0.0; bn * bn];
        let mut link_cap = vec![1.0; bn * bn];
        let mut comp_isq = vec![0.0; bn];
        let mut comp_lin = vec![0.0; bn];
        let mut comp_cap = vec![1.0; bn];
        let mut packet = vec![1.0; bs];
        let mut weight = vec![0.0; bs * bn];

        for (a, app) in net.apps.iter().enumerate() {
            for k in 0..app.num_stages() {
                let s = net.stages.id(a, k);
                let ps = a * bk1 + k; // padded stage id
                packet[ps] = app.packet_sizes[k];
                for i in 0..n {
                    weight[ps * bn + i] = net.comp_weight[s][i];
                    phi_cpu[ps * bn + i] = phi.get(s, i, phi.cpu());
                    for j in 0..n {
                        phi_link[(ps * bn + i) * bn + j] = phi.get(s, i, j);
                    }
                }
            }
            for i in 0..n {
                exo[a * bn + i] = app.input_rates[i];
            }
        }
        for e in 0..net.m() {
            let (i, j) = net.graph.edge(e);
            adj[i * bn + j] = 1.0;
            match net.link_cost[e] {
                CostFn::Linear { d } => link_lin[i * bn + j] = d,
                CostFn::Queue { cap } => {
                    link_isq[i * bn + j] = 1.0;
                    link_cap[i * bn + j] = cap;
                }
                CostFn::Quadratic { .. } => {
                    anyhow::bail!("XLA bridge supports Linear/Queue link costs only")
                }
            }
        }
        for i in 0..n {
            match net.comp_cost[i] {
                CostFn::Linear { d } => comp_lin[i] = d,
                CostFn::Queue { cap } => {
                    comp_isq[i] = 1.0;
                    comp_cap[i] = cap;
                }
                CostFn::Quadratic { .. } => {
                    anyhow::bail!("XLA bridge supports Linear/Queue comp costs only")
                }
            }
        }

        Ok(vec![
            (phi_link, vec![bs, bn, bn]),
            (phi_cpu, vec![bs, bn]),
            (exo, vec![ba, bn]),
            (adj, vec![bn, bn]),
            (link_isq, vec![bn, bn]),
            (link_lin, vec![bn, bn]),
            (link_cap, vec![bn, bn]),
            (comp_isq, vec![bn]),
            (comp_lin, vec![bn]),
            (comp_cap, vec![bn]),
            (packet, vec![bs]),
            (weight, vec![bs, bn]),
        ])
    }

    /// Unpad the 7 outputs back to the real scenario.
    fn unpack(&self, net: &Network, outs: Vec<xla::Literal>) -> anyhow::Result<EvalOutputs> {
        let bn = self.bucket.n;
        let bk1 = self.bucket.kchain + 1;
        let n = net.n();
        let ns = net.num_stages();

        let total_cost = outs[0].to_vec::<f64>()?[0];
        let t_flat = outs[1].to_vec::<f64>()?; // (BS, BN)
        let ddt_flat = outs[4].to_vec::<f64>()?; // (BS, BN)
        let dl_flat = outs[5].to_vec::<f64>()?; // (BS, BN, BN)
        let dc_flat = outs[6].to_vec::<f64>()?; // (BS, BN)

        let layout = net.graph.layout();
        let mut traffic = vec![vec![0.0; n]; ns];
        let mut d_dt = vec![vec![0.0; n]; ns];
        let mut delta = vec![vec![0.0; layout.num_slots()]; ns];
        for (a, app) in net.apps.iter().enumerate() {
            for k in 0..app.num_stages() {
                let s = net.stages.id(a, k);
                let ps = a * bk1 + k;
                for i in 0..n {
                    traffic[s][i] = t_flat[ps * bn + i];
                    d_dt[s][i] = ddt_flat[ps * bn + i];
                    // unpad straight into the sparse arena: link slots first
                    // (ascending by target), then the CPU slot
                    let r = layout.slot_range(i);
                    for t in r.start..r.end - 1 {
                        let j = layout.slot_target(t);
                        delta[s][t] = dl_flat[(ps * bn + i) * bn + j];
                    }
                    delta[s][r.end - 1] = dc_flat[ps * bn + i];
                }
            }
        }
        Ok(EvalOutputs {
            total_cost,
            traffic,
            d_dt,
            delta,
        })
    }
}

/// GP optimizer driven by the PJRT-executed evaluation — the L3 hot path of
/// the three-layer stack. Iterates are identical to the pure-Rust GP (the
/// evaluator is numerically equivalent; see tests).
pub struct XlaGp {
    pub phi: Strategy,
    pub opts: GpOptions,
    runtime: EvalRuntime,
    support: SupportMask,
    /// Delayed trust region (when `opts.backtrack`): the cost increase from
    /// slot t's update is observed in slot t+1's evaluation — revert and
    /// halve α then, costing no extra XLA calls.
    prev: Option<(Strategy, f64)>,
    cur_alpha: f64,
    rejects: u32,
}

impl XlaGp {
    pub fn new(net: &Network, opts: GpOptions) -> anyhow::Result<XlaGp> {
        let runtime = EvalRuntime::load_for(net)?;
        Ok(Self::with_runtime(net, runtime, opts))
    }

    pub fn with_runtime(net: &Network, runtime: EvalRuntime, opts: GpOptions) -> XlaGp {
        let phi = Strategy::shortest_path_to_dest(net);
        let support = opts
            .support
            .clone()
            .unwrap_or_else(|| SupportMask::full(net));
        let cur_alpha = opts.alpha;
        XlaGp {
            phi,
            opts,
            runtime,
            support,
            prev: None,
            cur_alpha,
            rejects: 0,
        }
    }

    /// Evaluate current φ on the XLA executable.
    pub fn eval(&self, net: &Network) -> anyhow::Result<EvalOutputs> {
        self.runtime.eval(net, &self.phi)
    }

    /// Reset to the cold-start min-hop strategy and clear the delayed
    /// trust-region state (the serving controller's cold-restart hook).
    pub fn restart(&mut self, net: &Network) {
        self.phi = Strategy::shortest_path_to_dest(net);
        self.prev = None;
        self.cur_alpha = self.opts.alpha;
        self.rejects = 0;
    }

    /// Multiply the step size by `factor` (the serving controller's
    /// warm-start boost hook).
    pub fn scale_step(&mut self, factor: f64) {
        self.opts.alpha *= factor;
        self.cur_alpha *= factor;
    }

    /// (n, num_apps) of the loaded artifact bucket.
    pub fn bucket_info(&self) -> (usize, usize) {
        (self.runtime.bucket().n, self.runtime.bucket().num_apps)
    }

    /// One GP slot using XLA-computed marginals. With `opts.backtrack` a
    /// *delayed* trust region applies: a cost increase caused by slot t's
    /// update is seen in slot t+1's evaluation, where the iterate is
    /// reverted and the stepsize halved — no extra XLA calls.
    pub fn step(&mut self, net: &Network) -> anyhow::Result<f64> {
        let mut out = self.runtime.eval(net, &self.phi)?;
        if self.opts.backtrack {
            if let Some((prev_phi, prev_cost)) = self.prev.take() {
                if out.total_cost > prev_cost + 1e-12 && self.rejects < 6 {
                    // reject the last update; re-evaluate the restored iterate
                    self.phi = prev_phi;
                    self.cur_alpha = (self.cur_alpha * 0.5).max(1e-6);
                    self.rejects += 1;
                    out = self.runtime.eval(net, &self.phi)?;
                } else {
                    self.rejects = 0;
                    self.cur_alpha = (self.cur_alpha * 1.3).min(self.opts.alpha);
                }
            }
            self.prev = Some((self.phi.clone(), out.total_cost));
        }
        let n = net.n();
        let mg = Marginals::from_parts(out.d_dt, out.delta, &net.graph);
        let blocked = BlockedSets::compute(net, &self.phi, &mg);
        for (s, (a, _k)) in net.stages.iter() {
            let is_final = net.is_final_stage(s);
            let dest = net.apps[a].dest;
            for i in 0..n {
                if is_final && i == dest {
                    continue;
                }
                let drow = mg.delta_row(s, i);
                let arow = self.support.row(s, i);
                let brow = blocked.row(s, i);
                let usable = |t: usize| -> bool {
                    arow[t] && !brow[t] && drow[t] < crate::marginals::INF_MARGINAL
                };
                gp_row_update(
                    self.phi.row_mut(s, i),
                    drow,
                    usable,
                    out.traffic[s][i],
                    self.cur_alpha,
                );
            }
        }
        // loop-safety + renormalization, as in the native optimizer
        for s in 0..net.num_stages() {
            debug_assert!(self.phi.topo_order(s).is_some(), "XLA GP closed a loop");
        }
        self.phi.renormalize(net);
        Ok(out.total_cost)
    }

    /// Run `iters` slots; returns the cost trace (cost *before* each step).
    pub fn run(&mut self, net: &Network, iters: usize) -> anyhow::Result<GpReport> {
        let mut cost_trace = Vec::with_capacity(iters);
        for _ in 0..iters {
            cost_trace.push(self.step(net)?);
        }
        let final_cost = self.runtime.eval(net, &self.phi)?.total_cost;
        Ok(GpReport {
            final_cost,
            residual_trace: Vec::new(),
            iters,
            converged: false,
            cost_trace,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::FlowState;
    use crate::testutil::small_net;

    fn artifacts_or_skip() -> Option<std::path::PathBuf> {
        let dir = crate::runtime::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn manifest_parses() {
        let Some(dir) = artifacts_or_skip() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.buckets.is_empty());
        assert!(m.pick(11, 1, 2).is_some(), "abilene must fit a bucket");
        assert!(m.pick(100, 30, 2).is_some(), "SW must fit a bucket");
        assert!(m.pick(1000, 1, 2).is_none());
    }

    #[test]
    fn xla_eval_matches_native_flow_and_marginals() {
        let Some(_dir) = artifacts_or_skip() else { return };
        let net = small_net(true);
        let rt = EvalRuntime::load_for(&net).unwrap();
        let phi = Strategy::shortest_path_to_dest(&net);
        let out = rt.eval(&net, &phi).unwrap();
        let fs = FlowState::solve(&net, &phi).unwrap();
        let mg = Marginals::compute(&net, &phi, &fs);
        assert!(
            (out.total_cost - fs.total_cost).abs() < 1e-9 * (1.0 + fs.total_cost),
            "cost: xla {} native {}",
            out.total_cost,
            fs.total_cost
        );
        for s in 0..net.num_stages() {
            for i in 0..net.n() {
                assert!(
                    (out.traffic[s][i] - fs.traffic[s][i]).abs() < 1e-9,
                    "t[{s}][{i}]"
                );
                assert!(
                    (out.d_dt[s][i] - mg.d_dt[s][i]).abs()
                        < 1e-8 * (1.0 + mg.d_dt[s][i].abs()),
                    "ddt[{s}][{i}]: xla {} native {}",
                    out.d_dt[s][i],
                    mg.d_dt[s][i]
                );
                let r = net.graph.layout().slot_range(i);
                for t in r {
                    let a = out.delta[s][t];
                    let b = mg.delta[s][t];
                    let both_inf = a >= 1e29 && b >= 1e29;
                    assert!(
                        both_inf || (a - b).abs() < 1e-8 * (1.0 + b.abs()),
                        "delta[{s}][{i}] slot {t}: xla {a} native {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn xla_gp_descends_like_native() {
        let Some(_dir) = artifacts_or_skip() else { return };
        let net = small_net(true);
        let mut xgp = XlaGp::new(
            &net,
            GpOptions {
                backtrack: false, // strict parity with the native reference
                ..Default::default()
            },
        )
        .unwrap();
        let rep = xgp.run(&net, 30).unwrap();
        let native_cost = {
            use crate::algo::gp::GradientProjection;
            let mut gp = GradientProjection::with_strategy(
                &net,
                Strategy::shortest_path_to_dest(&net),
                GpOptions {
                    backtrack: false,
                    ..Default::default()
                },
            );
            for _ in 0..30 {
                gp.step(&net);
            }
            gp.cost(&net)
        };
        assert!(
            (rep.final_cost - native_cost).abs() < 1e-6 * (1.0 + native_cost),
            "xla {} vs native {native_cost}",
            rep.final_cost
        );
    }
}
