//! Inert stand-in for the `xla`/PJRT FFI bindings.
//!
//! The real PJRT bindings (and the libxla shared object they load) are not
//! present in this offline build, so this module mirrors the exact API
//! surface [`super::pjrt`] consumes and fails — cleanly, at runtime — on the
//! first call that would need the native library ([`PjRtClient::cpu`]).
//! Everything still type-checks, the `--xla` CLI paths return a descriptive
//! error instead of compiling the crate out, and the parity tests skip
//! themselves (they already gate on `artifacts/manifest.json` existing).
//!
//! Swapping the real bindings back in is a one-line change in
//! [`super::pjrt`]: replace `use super::xla_stub as xla;` with `use xla;`.

use std::fmt;

/// Error returned by every operation that needs the native XLA runtime.
#[derive(Debug, Clone, Copy)]
pub struct XlaError;

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "XLA/PJRT backend is not available in this build \
             (native libxla bindings were not linked)"
        )
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError)
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    /// Platform name of the client.
    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// An XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a module proto (infallible in the real bindings too).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, device-loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// A host-side literal (stub).
pub struct Literal;

impl Literal {
    /// Build a rank-1 f64 literal.
    pub fn vec1(_xs: &[f64]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    /// Copy out as a flat vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err}").contains("not available"));
    }

    #[test]
    fn literal_ops_fail_cleanly() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f64>().is_err());
        assert!(lit.to_tuple().is_err());
    }
}
