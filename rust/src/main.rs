//! `scfo` — CLI launcher for the service-chain forwarding/offloading stack.
//!
//! ```text
//! scfo run      --topology geant [--alpha 0.1] [--iters 500] [--config cfg.json]
//! scfo compare  --topology abilene [--iters 500]   # GP vs all baselines
//! scfo table2                                      # print Table II inventory
//! scfo fig5 | fig6 | fig7                          # regenerate paper figures
//! scfo scenarios list [--tier large|dynamic|distributed|ha]  # scenario matrices
//! scfo scenarios run --all --jobs 8 [--out DIR]    # parallel batch + JSON reports
//! scfo scenarios run --all --tier large            # 1000-node-class sparse tier
//! scfo scenarios run --all --tier dynamic          # nonstationary serving tier
//! scfo scenarios run --all --tier distributed      # async-runtime chaos tier
//! scfo scenarios run --all --tier churn            # control-plane app churn tier
//! scfo scenarios run --all --tier topo-churn       # link-flap epoch-rebind tier
//! scfo scenarios run --tier massive                # million-stream SoA hot path
//! scfo scenarios run --all --tier ha               # replicated-control failover tier
//! scfo scenarios run --all --tier dnn              # DNN-split generalized-chain tier
//! scfo scenarios run --spec my.toml                # one spec file (TOML or JSON)
//! scfo distributed run --shards 4 --faults lossy   # async sharded runtime
//! scfo distributed run --faults spec.toml --json D.json  # custom fault spec
//! scfo distributed faults                          # list fault presets
//! scfo bench --json [--scenarios a,b] [--iters N]  # GP hot-path → BENCH.json
//! scfo bench --json --workload flash-crowd         # serving-mode bench (regret)
//! scfo bench --json --distributed --shards 4       # async runtime → BENCH.json v5
//! scfo serve    --topology geant [--slots 200] [--workload diurnal] [--xla]
//! scfo serve    --http 127.0.0.1:8080 --checkpoint ckpt [--slots 0]   # control plane
//! scfo serve    --checkpoint ckpt --restore        # resume bit-identically
//! scfo serve    --http 127.0.0.1:8080 --replica 0 --peers 127.0.0.1:8080,127.0.0.1:8081,127.0.0.1:8082
//! scfo bench --json --ha [--replicas 3] [--commands 50]   # replication → BENCH.json v8
//! scfo bench --json --control [--slots 90]         # control plane → BENCH.json v5
//! scfo bench --json --topo-churn [--slots 60]      # link flaps → BENCH.json v5
//! scfo bench --json --massive [--apps 1000] [--sources 1000]  # 1M streams → v7
//! scfo bench --json --dnn [--slots 40] [--iters 60]  # chain tier gaps → v9
//! scfo bench --json --massive --profile prof.json  # + Chrome trace (Perfetto)
//! scfo trace record --topology abilene --workload mmpp --slots 120 --out t.json
//! scfo trace replay t.json | stats t.json          # bit-identical trace replay
//! scfo validate --topology abilene                 # DES vs analytic cost
//! scfo broadcast --topology geant                  # protocol message audit
//! ```

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::bench::print_table;
use scfo::cli::Args;
use scfo::config::Scenario;
use scfo::flow::FlowState;
use scfo::graph::topologies::SCENARIO_NAMES;
use scfo::prelude::*;
use scfo::serving::{
    AdaptationController, ControllerOptions, OnlineServer, Optimizer, ReconvergePolicy,
    ServerOptions,
};
use scfo::sim;
use scfo::util::json::Json;
use scfo::workload::{Trace, Workload, WorkloadSpec};

fn scenario_from(args: &Args) -> anyhow::Result<Scenario> {
    if let Some(cfg) = args.flag("config") {
        return Scenario::load(std::path::Path::new(cfg));
    }
    let topo = args.flag_or("topology", "abilene");
    let mut sc = Scenario::table2(&topo)?;
    sc.seed = args.flag_usize("seed", sc.seed as usize)? as u64;
    sc.rate_scale = args.flag_f64("rate-scale", 1.0)?;
    Ok(sc)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let sc = scenario_from(args)?;
    let iters = args.flag_usize("iters", 500)?;
    let alpha = args.flag_f64("alpha", 0.1)?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    println!(
        "scenario {} : |V|={} |E|={} |A|={} |S|={}",
        sc.name,
        net.n(),
        net.m(),
        net.apps.len(),
        net.num_stages()
    );
    if args.switch("xla") {
        let mut gp = scfo::runtime::XlaGp::new(
            &net,
            GpOptions {
                alpha,
                ..Default::default()
            },
        )?;
        let rep = gp.run(&net, iters)?;
        println!("XLA-GP final cost: {:.6}", rep.final_cost);
    } else {
        let mut gp = GradientProjection::new(
            &net,
            GpOptions {
                alpha,
                ..Default::default()
            },
        );
        let rep = gp.run(&net, iters);
        println!(
            "GP final cost: {:.6} (converged={} iters={})",
            rep.final_cost, rep.converged, rep.iters
        );
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let sc = scenario_from(args)?;
    let iters = args.flag_usize("iters", 500)?;
    let row = sim::compare_algorithms(&sc, iters, 1)?;
    let norm = row.normalized();
    let rows: Vec<Vec<String>> = row
        .costs
        .iter()
        .zip(&norm)
        .map(|((name, cost), (_n, x))| {
            vec![name.to_string(), format!("{cost:.4}"), format!("{x:.3}")]
        })
        .collect();
    print_table(
        &format!("Algorithm comparison — {}", sc.name),
        &["algorithm", "total cost", "normalized"],
        &rows,
    );
    Ok(())
}

fn cmd_table2(_args: &Args) -> anyhow::Result<()> {
    let mut rows = Vec::new();
    for name in SCENARIO_NAMES {
        let sc = Scenario::table2(name)?;
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng)?;
        rows.push(vec![
            name.to_string(),
            net.n().to_string(),
            (net.m() / 2).to_string(),
            sc.num_apps.to_string(),
            sc.num_sources.to_string(),
            format!("{:?}", sc.link_kind),
            format!("{}", sc.link_param),
            format!("{:?}", sc.comp_kind),
            format!("{}", sc.comp_param),
        ]);
    }
    print_table(
        "Table II — simulated network scenarios",
        &["topology", "|V|", "|E|", "|A|", "R", "link", "d̄ij", "comp", "s̄i"],
        &rows,
    );
    Ok(())
}

fn cmd_fig5(args: &Args) -> anyhow::Result<()> {
    let iters = args.flag_usize("iters", 400)?;
    let mut scenarios: Vec<Scenario> = SCENARIO_NAMES
        .iter()
        .map(|n| Scenario::table2(n).unwrap())
        .collect();
    scenarios.push(Scenario::sw_linear());
    let mut rows = Vec::new();
    for sc in &scenarios {
        let row = sim::compare_algorithms(sc, iters, 1)?;
        let mut cells = vec![sc.name.clone()];
        for (_n, x) in row.normalized() {
            cells.push(format!("{x:.3}"));
        }
        rows.push(cells);
    }
    print_table(
        "Fig. 5 — normalized total cost per scenario",
        &["scenario", "GP", "SPOC", "LCOF", "LPR-SC"],
        &rows,
    );
    Ok(())
}

fn cmd_fig6(args: &Args) -> anyhow::Result<()> {
    let iters = args.flag_usize("iters", 400)?;
    let sc = Scenario::table2("abilene")?;
    let scales = [0.4, 0.6, 0.8, 1.0, 1.2, 1.4, 1.6, 1.8];
    let sweep = sim::rate_sweep(&sc, &scales, iters)?;
    let rows: Vec<Vec<String>> = sweep
        .iter()
        .map(|(scale, row)| {
            let mut cells = vec![format!("{scale:.1}")];
            for (_n, c) in &row.costs {
                cells.push(format!("{c:.4}"));
            }
            cells
        })
        .collect();
    print_table(
        "Fig. 6 — total cost vs input rate scale (Abilene)",
        &["rate scale", "GP", "SPOC", "LCOF", "LPR-SC"],
        &rows,
    );
    Ok(())
}

fn cmd_fig7(args: &Args) -> anyhow::Result<()> {
    let iters = args.flag_usize("iters", 400)?;
    let sc = Scenario::table2("abilene")?;
    let l0s = [2.0, 4.0, 6.0, 8.0, 10.0, 14.0, 20.0];
    let rows_data = sim::packet_size_sweep(&sc, &l0s, iters)?;
    let rows: Vec<Vec<String>> = rows_data
        .iter()
        .map(|r| {
            vec![
                format!("{:.0}", r.l0),
                format!("{:.3}", r.data_hops),
                format!("{:.3}", r.result_hops),
            ]
        })
        .collect();
    print_table(
        "Fig. 7 — avg packet hops vs input packet size (GP, Abilene)",
        &["L(a,0)", "data hops", "result hops"],
        &rows,
    );
    Ok(())
}

/// Drive a built server to completion and print the serving + adaptation
/// summary (shared by the native and XLA paths of `scfo serve`).
fn drive_server<O: Optimizer>(mut srv: OnlineServer<O>, slots: usize) -> anyhow::Result<()> {
    anyhow::ensure!(slots > 0, "--slots must be at least 1");
    let metrics = srv.run(slots)?;
    println!("delay histogram: {}", srv.delay_hist.summary());
    let last = metrics.last().unwrap();
    let lat: Vec<f64> = metrics.iter().map(|m| m.optimizer_latency).collect();
    println!(
        "served {} slots; final cost {:.4}; expected delay {:.4}s; optimizer latency mean {:.2}ms p95 {:.2}ms",
        metrics.len(),
        last.cost,
        last.expected_delay,
        scfo::util::stats::mean(&lat) * 1e3,
        scfo::util::stats::percentile(&lat, 95.0) * 1e3,
    );
    if let Some(ctrl) = &srv.controller {
        let s = ctrl.summary();
        println!(
            "adaptation ({}): {} detections; reconvergence mean {:.1} / max {} slots; regret mean {:.4} total {:.4}",
            ctrl.opts.policy.name(),
            s.detections,
            s.reconverge_mean,
            s.reconverge_max,
            s.regret_mean,
            s.regret_total,
        );
    }
    Ok(())
}

/// Control-plane serving: `scfo serve --http ADDR | --checkpoint DIR
/// [--restore]`. Builds (or restores) a [`scfo::control::ControlPlane`],
/// serves slots, polls the ops API between slots, and checkpoints
/// periodically. `--slots 0` serves until killed (the CI smoke mode).
///
/// With `--replica I --peers ...` the process checkpoints into its own
/// `replica-I/` subdirectory of `--checkpoint DIR` (consensus state
/// embedded) and auto-resumes from it on restart — even without
/// `--restore` — so a crashed replica rejoins with the log it acked
/// rather than forking the group from an empty one.
fn cmd_serve_control(args: &Args) -> anyhow::Result<()> {
    use scfo::control::{ControlOptions, ControlPlane, LiveReplica, OpsServer};

    anyhow::ensure!(
        !args.switch("xla"),
        "--xla is not supported with the control plane (centralized GP only)"
    );
    let slots = args.flag_usize("slots", 200)?; // 0 = serve until killed
    let checkpoint_dir = args.flag("checkpoint").map(std::path::PathBuf::from);
    let checkpoint_every = args.flag_usize("checkpoint-every", 50)?;
    let default_pace: u64 = if args.flag("http").is_some() { 20 } else { 0 };
    let pace_ms = args.flag_u64("pace", default_pace)?;

    let mut copts = ControlOptions {
        adapt: args.switch("adapt") || args.flag("workload").is_some(),
        ..ControlOptions::default()
    };
    copts.controller.policy = ReconvergePolicy::parse(&args.flag_or("policy", "warm"))?;
    if let Some(w) = args.flag("workload") {
        copts.workload = Some(WorkloadSpec::parse(w)?);
    }
    copts.admission.headroom = args.flag_f64("admit-headroom", copts.admission.headroom)?;
    copts.admission.max_cost_increase =
        args.flag_f64("admit-budget", copts.admission.max_cost_increase)?;

    // `--replica I --peers a:p0,b:p1,c:p2` joins a replicated control
    // plane; parsed before the plane is built because a replica's
    // checkpoints live in its private subdirectory of --checkpoint DIR
    // ([`snapshot::replica_dir`]) and restore must resolve that path.
    let repl_args: Option<(usize, Vec<String>)> = match args.flag("replica") {
        Some(_) => {
            anyhow::ensure!(
                args.flag("http").is_some(),
                "--replica needs --http ADDR (replication runs over the ops API)"
            );
            let id = args.flag_usize("replica", 0)?;
            let peers: Vec<String> = args
                .flag("peers")
                .ok_or_else(|| anyhow::anyhow!("--replica needs --peers a:p0,b:p1,..."))?
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect();
            Some((id, peers))
        }
        None => None,
    };
    // where THIS process checkpoints to and restores from
    let plane_dir = checkpoint_dir.as_deref().map(|dir| match &repl_args {
        Some((id, _)) => scfo::control::snapshot::replica_dir(dir, *id),
        None => dir.to_path_buf(),
    });
    // a replica auto-resumes from its last checkpoint even without
    // --restore: rejoining with a fresh term-1 log would ack same-term
    // appends it never stored and silently fork committed epochs
    let auto_resume = repl_args.is_some()
        && plane_dir
            .as_deref()
            .is_some_and(|d| scfo::control::snapshot::snapshot_path(d).is_file());

    let (mut plane, restored_doc) = if args.switch("restore") || auto_resume {
        let dir = plane_dir
            .clone()
            .ok_or_else(|| anyhow::anyhow!("--restore needs --checkpoint DIR"))?;
        let doc = scfo::control::snapshot::load(&dir)?;
        let plane = ControlPlane::restore_from_doc(&doc, copts)?;
        println!(
            "restored from {}: epoch {}, slot {}, {} apps",
            dir.display(),
            plane.epoch(),
            plane.slots_served(),
            plane.catalog.len()
        );
        (plane, Some(doc))
    } else {
        let sc = scenario_from(args)?;
        let plane = ControlPlane::new(sc, copts)?;
        println!(
            "control plane on {}: {} apps, |V|={} |E|={}",
            plane.scenario.name,
            plane.catalog.len(),
            plane.graph().n(),
            plane.graph().m()
        );
        (plane, None)
    };
    let ops = match args.flag("http") {
        Some(addr) => {
            let srv = OpsServer::bind(addr)?;
            println!("ops API listening on http://{}", srv.local_addr());
            Some(srv)
        }
        None => None,
    };

    // Mutating ops routes go through the multipaxos command log and
    // followers redirect writers to the leader (`GET /raftish` inspects).
    let mut repl = match repl_args {
        Some((id, peers)) => {
            let group = peers.len();
            let mut lr = LiveReplica::new(id, peers, plane.scenario.seed)?;
            // resume consensus state (term, vote, log) from the snapshot's
            // `replication` key; replica 0 then re-asserts leadership in a
            // term above the restored one, so its first appends truncate
            // stale same-term suffixes on followers instead of silently
            // acking over a diverged log
            if let Some(rs) = restored_doc.as_ref().and_then(|d| d.get("replication")) {
                lr.load_persistent(rs)?;
                if id == 0 {
                    lr.rebootstrap();
                }
                println!(
                    "replication state resumed: term {}, commit {}",
                    lr.term(),
                    lr.commit_index()
                );
            }
            if checkpoint_dir.is_none() {
                println!(
                    "warning: --replica without --checkpoint DIR; a restarted \
                     replica rejoins with an empty log (no restart durability)"
                );
            }
            let role = if lr.is_leader() {
                "bootstrap leader"
            } else {
                "follower"
            };
            println!("replica {id}/{group} ({role})");
            Some(lr)
        }
        None => None,
    };

    let mut served = 0usize;
    loop {
        if slots > 0 && served >= slots {
            break;
        }
        plane.run_slot()?;
        served += 1;
        if let Some(dir) = &checkpoint_dir {
            if checkpoint_every > 0 && plane.slots_served() % checkpoint_every == 0 {
                // a replica checkpoints into its private subdirectory with
                // its consensus state embedded, same as POST /checkpoint
                match repl.as_ref() {
                    Some(r) => plane.checkpoint_replicated(dir, r)?,
                    None => plane.checkpoint(dir)?,
                };
            }
        }
        match &ops {
            Some(srv) if pace_ms > 0 => {
                // pace the loop while staying responsive to the ops API
                let deadline =
                    std::time::Instant::now() + std::time::Duration::from_millis(pace_ms);
                loop {
                    srv.poll_repl(&mut plane, checkpoint_dir.as_deref(), repl.as_mut());
                    if std::time::Instant::now() >= deadline {
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            }
            Some(srv) => {
                srv.poll_repl(&mut plane, checkpoint_dir.as_deref(), repl.as_mut());
            }
            None if pace_ms > 0 => {
                std::thread::sleep(std::time::Duration::from_millis(pace_ms))
            }
            None => {}
        }
    }
    if let Some(dir) = &checkpoint_dir {
        let path = match repl.as_ref() {
            Some(r) => plane.checkpoint_replicated(dir, r)?,
            None => plane.checkpoint(dir)?,
        };
        println!("final checkpoint: {}", path.display());
    }
    let last_cost = plane
        .stats
        .last
        .as_ref()
        .map(|m| m.cost)
        .unwrap_or(f64::NAN);
    println!(
        "served {served} slots; epoch {}; {} apps; final cost {:.6}; admission {}/{} accepted",
        plane.epoch(),
        plane.catalog.len(),
        last_cost,
        plane.stats.admission_accepted,
        plane.stats.admission_accepted + plane.stats.admission_rejected,
    );
    println!("delay histogram: {}", plane.server.delay_hist.summary());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    scfo::cli::guard_subcommand(args, "serve", &[])?;
    // --restore is a switch; if the parser quirk turned it into a valued
    // flag (`--restore ckpt`), refuse instead of silently starting a fresh
    // run that would overwrite the snapshot the user meant to resume
    if let Some(v) = args.flag("restore") {
        anyhow::bail!(
            "--restore takes no value (got '{v}'); use `scfo serve --checkpoint DIR --restore`"
        );
    }
    if args.flag("http").is_some() || args.flag("checkpoint").is_some() || args.switch("restore")
    {
        return cmd_serve_control(args);
    }
    let sc = scenario_from(args)?;
    let slots = args.flag_usize("slots", 200)?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let opts = ServerOptions::default();
    let wspec = match args.flag("workload") {
        Some(w) => Some(WorkloadSpec::parse(w)?),
        None => None,
    };
    // nonstationary workloads get the controller by default; --adapt forces
    // it for stationary serving too
    let adapt = args.switch("adapt") || wspec.is_some();
    let policy = ReconvergePolicy::parse(&args.flag_or("policy", "warm"))?;
    // both arms honor --seed (via sc.seed) so stationary and workload-driven
    // serving are seeded consistently
    let workload = match &wspec {
        Some(w) => Workload::from_spec(w, &net, opts.slot_secs, sc.seed)?,
        None => Workload::stationary(&net, opts.slot_secs, sc.seed),
    };
    let ctrl = if adapt {
        Some(AdaptationController::new(ControllerOptions {
            policy,
            ..ControllerOptions::default()
        }))
    } else {
        None
    };
    if args.switch("xla") {
        let gp = scfo::runtime::XlaGp::new(&net, GpOptions::default())?;
        let mut srv = OnlineServer::with_workload(net, gp, workload, opts);
        if let Some(c) = ctrl {
            srv.attach_controller(c);
        }
        drive_server(srv, slots)
    } else {
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::with_workload(net, gp, workload, opts);
        if let Some(c) = ctrl {
            srv.attach_controller(c);
        }
        drive_server(srv, slots)
    }
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    scfo::cli::guard_subcommand(args, "trace", &["record", "replay", "stats"])?;
    match args.subcommand() {
        Some("record") => {
            let sc = scenario_from(args)?;
            let wspec = WorkloadSpec::parse(&args.flag_or("workload", "diurnal"))?;
            let slots = args.flag_usize("slots", 120)?;
            let slot_secs = args.flag_f64("slot-secs", 1.0)?;
            let out = std::path::PathBuf::from(args.flag_or("out", "trace.json"));
            let mut rng = Rng::new(sc.seed);
            let net = sc.build(&mut rng)?;
            let mut wl = Workload::from_spec(&wspec, &net, slot_secs, sc.seed)?;
            let trace = Trace::record(&mut wl, slots, Some(&sc));
            trace.save(&out)?;
            let total: u64 = trace.stats().iter().map(|s| s.arrivals).sum();
            println!(
                "recorded {slots} slots x {} streams, {total} arrivals (workload {}, scenario {}) -> {}",
                trace.streams.len(),
                wspec.name(),
                sc.name,
                out.display()
            );
            Ok(())
        }
        Some("replay") => {
            let path = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("trace replay needs a FILE argument"))?;
            let trace = Trace::load(std::path::Path::new(path))?;
            let sc = match &trace.scenario {
                Some(sc) => sc.clone(),
                None => scenario_from(args)?,
            };
            let slots = args.flag_usize("slots", trace.num_slots())?;
            anyhow::ensure!(
                slots > 0,
                "nothing to replay: the trace is empty and no --slots given"
            );
            let mut rng = Rng::new(sc.seed);
            let net = sc.build(&mut rng)?;
            let wl = trace.workload();
            for s in &wl.streams {
                anyhow::ensure!(
                    s.app < net.apps.len() && s.node < net.n(),
                    "trace stream (app {}, node {}) does not fit scenario '{}'",
                    s.app,
                    s.node,
                    sc.name
                );
            }
            let gp = GradientProjection::new(&net, GpOptions::default());
            let mut srv = OnlineServer::with_workload(
                net,
                gp,
                wl,
                ServerOptions {
                    slot_secs: trace.slot_secs,
                    ..ServerOptions::default()
                },
            );
            srv.attach_controller(AdaptationController::new(ControllerOptions::default()));
            let metrics = srv.run(slots)?;
            let last = metrics.last().unwrap();
            let arrivals: usize = metrics.iter().map(|m| m.arrivals).sum();
            let s = srv.controller.as_ref().unwrap().summary();
            // NOTE: deterministic output only (no wall-clock) — CI diffs two
            // replays of the same trace byte-for-byte.
            println!(
                "replayed {} slots ({arrivals} arrivals) of {}",
                metrics.len(),
                path
            );
            println!(
                "final cost {:.9}; expected delay {:.9}s; detections {}; regret total {:.9}",
                last.cost, last.expected_delay, s.detections, s.regret_total
            );
            if let Some(out) = args.flag("json") {
                let doc = Json::obj(vec![
                    ("trace", Json::Str(path.to_string())),
                    ("slots", Json::Num(metrics.len() as f64)),
                    ("arrivals", Json::Num(arrivals as f64)),
                    ("final_cost", Json::Num(last.cost)),
                    ("expected_delay", Json::Num(last.expected_delay)),
                    ("adaptation", s.to_json()),
                ]);
                std::fs::write(out, doc.to_string_pretty())?;
                println!("wrote {out}");
            }
            Ok(())
        }
        Some("stats") => {
            let path = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("trace stats needs a FILE argument"))?;
            let trace = Trace::load(std::path::Path::new(path))?;
            println!(
                "trace {path}: v{} | {} slots x {:.3}s | {} streams | scenario {}",
                scfo::workload::TRACE_VERSION,
                trace.num_slots(),
                trace.slot_secs,
                trace.streams.len(),
                trace
                    .scenario
                    .as_ref()
                    .map(|s| s.name.as_str())
                    .unwrap_or("(none)"),
            );
            let rows: Vec<Vec<String>> = trace
                .stats()
                .iter()
                .map(|s| {
                    vec![
                        format!("({}, {})", s.app, s.node),
                        s.model.clone(),
                        s.arrivals.to_string(),
                        format!("{:.4}", s.mean_rate),
                        format!("{:.4}", s.peak_rate),
                        format!("{:.3}", s.dispersion),
                    ]
                })
                .collect();
            print_table(
                &format!("Trace streams — {path}"),
                &["(app, node)", "model", "arrivals", "mean rate", "peak rate", "dispersion"],
                &rows,
            );
            Ok(())
        }
        other => {
            if let Some(o) = other {
                eprintln!("unknown trace subcommand '{o}'");
            }
            anyhow::bail!(
                "usage: scfo trace record --topology T --workload W --slots N --out FILE | \
                 scfo trace replay FILE [--json OUT] | scfo trace stats FILE"
            )
        }
    }
}

fn cmd_validate(args: &Args) -> anyhow::Result<()> {
    let sc = scenario_from(args)?;
    let iters = args.flag_usize("iters", 300)?;
    let horizon = args.flag_f64("horizon", 2000.0)?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let mut gp = GradientProjection::new(&net, GpOptions::default());
    gp.run(&net, iters);
    let analytic = FlowState::solve(&net, &gp.phi).unwrap().total_cost;
    let rep = sim::simulate(&net, &gp.phi, horizon, sc.seed)?;
    println!("analytic cost (expected packets in system): {analytic:.4}");
    println!(
        "DES measured: occupancy {:.4}, mean delay {:.4}s, delivered {}, λ {:.3}",
        rep.avg_occupancy, rep.mean_delay, rep.delivered, rep.lambda
    );
    println!(
        "Little cross-check: λ·W = {:.4} (vs N = {:.4})",
        rep.lambda * rep.mean_delay,
        rep.avg_occupancy
    );
    Ok(())
}

/// GP hot-path benchmark: time per-iteration wall clock + cost trajectory on
/// the requested scenarios; `--json` writes the machine-readable BENCH.json
/// perf baseline (schema: docs/PERFORMANCE.md). With `--workload NAME` the
/// bench drives the online serving loop instead (iters = serving slots) and
/// BENCH.json gains the regret / reconvergence-slots columns. With `--dnn`
/// the bench runs the generalized-chain tier and BENCH.json gains the v9
/// per-cell GP-vs-baseline cost-gap columns.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    scfo::cli::guard_subcommand(args, "bench", &[])?;
    let scenarios = args.flag_or("scenarios", "abilene,geant,sw");
    let iters = args.flag_usize("iters", 60)?;
    let workload = args.flag("workload");
    let distributed = args.switch("distributed") || args.flag("faults").is_some();
    let control = args.switch("control");
    let topo_churn = args.switch("topo-churn");
    let massive = args.switch("massive");
    let ha = args.switch("ha");
    let dnn = args.switch("dnn");
    let mut results = Vec::new();
    if ha {
        let replicas = args.flag_usize("replicas", 3)?;
        let commands = args.flag_usize("commands", 50)?;
        for name in scenarios.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            eprintln!("bench {name} (ha, {replicas} replicas, {commands} commands)...");
            results.push(scfo::bench::bench_ha_scenario(name, replicas, commands)?);
        }
    }
    if massive && !ha {
        // the massive tier has one fixed family (er-1000-4000); size the
        // stream table with --apps/--sources instead of --scenarios
        let apps = args.flag_usize("apps", 1000)?;
        let sources = args.flag_usize("sources", 1000)?;
        let slots = args.flag_usize("slots", 20)?;
        eprintln!("bench massive ({apps} x {sources} streams, {slots} slots)...");
        results.push(scfo::bench::bench_massive_scenario(apps, sources, slots)?);
    }
    if dnn && !ha && !massive {
        // the dnn tier crosses its own fixed families × chain profiles ×
        // congestion; --slots sizes the serving horizon, --iters the
        // baseline-comparison budget
        let slots = args.flag_usize("slots", 40)?;
        eprintln!("bench dnn tier ({slots} slots, {iters} iters per cell)...");
        results.push(scfo::bench::bench_dnn_scenario(slots, iters)?);
    }
    for name in scenarios.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        if massive || ha || dnn {
            break;
        }
        if topo_churn {
            let slots = args.flag_usize("slots", 60)?;
            eprintln!("bench {name} (topo churn, {slots} slots)...");
            results.push(scfo::bench::bench_topo_churn_scenario(name, slots)?);
            continue;
        }
        if control {
            let slots = args.flag_usize("slots", 90)?;
            eprintln!("bench {name} (control plane, {slots} slots)...");
            results.push(scfo::bench::bench_control_scenario(name, slots)?);
            continue;
        }
        if distributed {
            use scfo::distributed::FaultSpec;
            let shards = args.flag_usize("shards", 4)?;
            let epochs = args.flag_usize("epochs", 4000)?;
            let fname = args.flag_or("faults", "lossy");
            let faults = if fname.ends_with(".toml") || fname.ends_with(".json") {
                FaultSpec::load(std::path::Path::new(&fname))?
            } else {
                FaultSpec::preset(&fname, args.flag_u64("fault-seed", 2023)?)?
            };
            eprintln!("bench {name} (distributed, {shards} shards, faults {})...", faults.name);
            results.push(scfo::bench::bench_distributed_scenario(
                name, shards, &faults, epochs,
            )?);
            continue;
        }
        match workload {
            Some(w) => {
                eprintln!("bench {name} ({iters} serving slots, workload {w})...");
                results.push(scfo::bench::bench_serving_scenario(name, w, iters)?);
            }
            None => {
                eprintln!("bench {name} ({iters} iters)...");
                results.push(scfo::bench::bench_gp_scenario(name, iters)?);
            }
        }
    }
    if ha {
        let rows: Vec<Vec<String>> = results
            .iter()
            .map(|r| {
                let h = r.ha.as_ref().expect("ha bench has an ha block");
                vec![
                    r.name.clone(),
                    h.replicas.to_string(),
                    h.faults.clone(),
                    h.commands.to_string(),
                    h.committed.to_string(),
                    h.lost.to_string(),
                    format!("{}t/{:.2}ms", h.election_ticks, h.election_secs * 1e3),
                    format!("{}t/{:.2}ms", h.failover_ticks, h.failover_secs * 1e3),
                    format!("{:.0}", h.commands_per_sec),
                    h.msgs_sent.to_string(),
                ]
            })
            .collect();
        print_table(
            "Replicated control-plane bench (BENCH.json v8 columns)",
            &[
                "scenario",
                "replicas",
                "faults",
                "commands",
                "committed",
                "lost",
                "election",
                "failover",
                "cmds/sec",
                "msgs",
            ],
            &rows,
        );
    } else if massive {
        let rows: Vec<Vec<String>> = results
            .iter()
            .map(|r| {
                let ms = r.massive.as_ref().expect("massive bench has a massive block");
                vec![
                    r.name.clone(),
                    format!("{}/{}", r.n, r.m),
                    ms.streams.to_string(),
                    ms.slots.to_string(),
                    ms.arrivals_total.to_string(),
                    ms.detections.to_string(),
                    format!("{:.2}", ms.slot_wall_ms_mean),
                    format!(
                        "{:.2}/{:.2}/{:.2}",
                        ms.phase_sample_ms_mean, ms.phase_estimate_ms_mean, ms.phase_detect_ms_mean
                    ),
                    format!("{:.2}", ms.slot_wall_ms_max),
                    format!("{:.0}", ms.streams_per_sec),
                ]
            })
            .collect();
        print_table(
            "Million-stream workload bench (BENCH.json v7 columns)",
            &[
                "scenario",
                "|V|/|E|",
                "streams",
                "slots",
                "arrivals",
                "detections",
                "slot ms mean",
                "smp/est/det ms",
                "slot ms max",
                "streams/sec",
            ],
            &rows,
        );
    } else if dnn {
        let rows: Vec<Vec<String>> = results
            .iter()
            .flat_map(|r| {
                let d = r.dnn.as_ref().expect("dnn bench has a dnn block");
                d.rows
                    .iter()
                    .map(|row| {
                        let mut cells = vec![
                            row.name.clone(),
                            row.profile.clone(),
                            row.congestion.clone(),
                            format!("{:.4}", row.gp_cost),
                        ];
                        for (name, g) in &row.gaps {
                            cells.push(if *g > 50.0 {
                                format!("sat({name})")
                            } else {
                                format!("{g:.2}x")
                            });
                        }
                        cells
                    })
                    .collect::<Vec<_>>()
            })
            .collect();
        print_table(
            "DNN-split chain tier bench (BENCH.json v9 columns)",
            &[
                "cell",
                "profile",
                "congestion",
                "GP cost",
                "SPOC",
                "LCOF",
                "LPR-SC",
            ],
            &rows,
        );
    } else if topo_churn {
        let rows: Vec<Vec<String>> = results
            .iter()
            .map(|r| {
                let tc = r
                    .topo_churn
                    .as_ref()
                    .expect("topo-churn bench has a topo_churn block");
                vec![
                    r.name.clone(),
                    format!("{}/{}", r.n, r.m),
                    tc.slots.to_string(),
                    format!("{}/{}", tc.changes, tc.events),
                    format!("{:.2}", tc.rebind_secs_mean * 1e3),
                    format!("{:.1}", tc.reconverge_iters_warm_mean),
                    format!("{:.1}", tc.reconverge_iters_cold_mean),
                    format!("{:.4}", tc.retained_optimality_mean),
                    format!(
                        "{:.4}",
                        r.cost_trajectory.last().copied().unwrap_or(f64::NAN)
                    ),
                ]
            })
            .collect();
        print_table(
            "Topology-churn bench (BENCH.json v5 columns)",
            &[
                "scenario",
                "|V|/|E|",
                "slots",
                "changes",
                "rebind ms",
                "reconv warm",
                "reconv cold",
                "retained",
                "final cost",
            ],
            &rows,
        );
    } else if control {
        let rows: Vec<Vec<String>> = results
            .iter()
            .map(|r| {
                let c = r.control.as_ref().expect("control bench has a control block");
                vec![
                    r.name.clone(),
                    format!("{}/{}", r.n, r.m),
                    c.slots.to_string(),
                    format!("{}/{}", c.admission_accepted, c.apps_registered),
                    format!("{:.2}", c.admission_latency_secs_mean * 1e3),
                    c.epochs.to_string(),
                    c.reconverge_iters_warm.to_string(),
                    c.reconverge_iters_cold.to_string(),
                    format!(
                        "{:.4}",
                        r.cost_trajectory.last().copied().unwrap_or(f64::NAN)
                    ),
                ]
            })
            .collect();
        print_table(
            "Control-plane bench (BENCH.json v5 columns)",
            &[
                "scenario",
                "|V|/|E|",
                "slots",
                "admitted",
                "admit ms",
                "epochs",
                "reconv warm",
                "reconv cold",
                "final cost",
            ],
            &rows,
        );
    } else if distributed {
        let rows: Vec<Vec<String>> = results
            .iter()
            .map(|r| {
                let d = r
                    .distributed
                    .as_ref()
                    .expect("distributed bench has a distributed block");
                vec![
                    r.name.clone(),
                    format!("{}/{}", r.n, r.m),
                    format!("{}x {}", d.shards, d.transport),
                    d.faults.clone(),
                    if d.converged { "yes" } else { "NO" }.to_string(),
                    d.rounds.to_string(),
                    format!("{:.2}", d.convergence_secs),
                    d.messages.to_string(),
                    d.max_queue_depth.to_string(),
                    d.stale_reads.to_string(),
                ]
            })
            .collect();
        print_table(
            "Distributed async runtime bench (BENCH.json v5 columns)",
            &[
                "scenario",
                "|V|/|E|",
                "shards",
                "faults",
                "quiesced",
                "rounds",
                "conv secs",
                "messages",
                "max queue",
                "stale reads",
            ],
            &rows,
        );
    } else if workload.is_some() {
        let rows: Vec<Vec<String>> = results
            .iter()
            .map(|r| {
                let d = r.dynamics.as_ref().expect("serving bench has dynamics");
                vec![
                    r.name.clone(),
                    d.workload.clone(),
                    d.slots.to_string(),
                    format!("{:.3}", r.mean_iter_secs() * 1e3),
                    format!(
                        "{:.4}",
                        r.cost_trajectory.last().copied().unwrap_or(f64::NAN)
                    ),
                    format!("{:.4}", d.summary.regret_mean),
                    format!("{:.1}", d.summary.reconverge_mean),
                    d.summary.detections.to_string(),
                ]
            })
            .collect();
        print_table(
            "Serving-mode bench (online GP under nonstationary workload)",
            &[
                "scenario",
                "workload",
                "slots",
                "slot ms",
                "final cost",
                "regret mean",
                "reconv slots",
                "detections",
            ],
            &rows,
        );
    } else {
        let rows: Vec<Vec<String>> = results
            .iter()
            .map(|r| {
                vec![
                    r.name.clone(),
                    format!("{}/{}", r.n, r.m),
                    r.stages.to_string(),
                    r.arena_slots.to_string(),
                    format!("{:.3}", r.mean_iter_secs() * 1e3),
                    format!(
                        "{:.4}",
                        r.cost_trajectory.last().copied().unwrap_or(f64::NAN)
                    ),
                    match r.peak_rss_bytes {
                        Some(b) => format!("{:.1}", b as f64 / (1024.0 * 1024.0)),
                        None => "n/a".to_string(),
                    },
                ]
            })
            .collect();
        print_table(
            "GP hot-path bench (sparse CSR core)",
            &["scenario", "|V|/|E|", "|S|", "arena", "iter ms", "final cost", "peak RSS MB"],
            &rows,
        );
    }
    if args.switch("json") || args.flag("out").is_some() {
        let out = std::path::PathBuf::from(args.flag_or("out", "BENCH.json"));
        let doc = scfo::bench::gp_bench_json(&results);
        std::fs::write(&out, doc.to_string_pretty())?;
        println!("wrote {}", out.display());
    }
    Ok(())
}

fn cmd_scenarios(args: &Args) -> anyhow::Result<()> {
    use scfo::scenarios::{run_batch, RunnerOptions, ScenarioSpec};

    /// Expand the selected tier's matrix. Each tier carries its own default
    /// budgets (standard: 600/300; large: 150/60 — thousand-node scenarios
    /// need far fewer, more expensive iterations; dynamic: 200 serving
    /// slots via --slots); explicit --iters / --event-iters flags override,
    /// with --event-iters defaulting to half of an explicitly given --iters
    /// as before.
    fn tier_matrix(args: &Args) -> anyhow::Result<Vec<ScenarioSpec>> {
        let tier = args.flag_or("tier", "standard");
        if tier == "distributed" {
            let shards = args.flag_usize("shards", 4)?;
            let epochs = args.flag_usize("epochs", 2000)?;
            let mut specs = ScenarioSpec::distributed_matrix_sized(shards, epochs);
            if args.flag("iters").is_some() {
                let iters = args.flag_usize("iters", 1500)?;
                for s in &mut specs {
                    s.iters = iters;
                }
            }
            return Ok(specs);
        }
        if tier == "churn" {
            let slots = args.flag_usize("slots", 200)?;
            let mut specs = ScenarioSpec::churn_matrix_sized(slots);
            if args.flag("iters").is_some() {
                let iters = args.flag_usize("iters", 300)?;
                for s in &mut specs {
                    s.iters = iters;
                }
            }
            return Ok(specs);
        }
        if tier == "topo-churn" {
            let slots = args.flag_usize("slots", 150)?;
            let iters = args.flag_usize("iters", 150)?;
            return Ok(ScenarioSpec::topo_churn_matrix_sized(slots, iters));
        }
        if tier == "massive" {
            // million-stream batched workload hot path; --apps/--sources
            // size the stream table (streams = apps x sources), --slots the
            // served horizon. No optimizer runs in this tier.
            let apps = args.flag_usize("apps", 1000)?;
            let sources = args.flag_usize("sources", 1000)?;
            let slots = args.flag_usize("slots", 20)?;
            return Ok(ScenarioSpec::massive_matrix_sized(apps, sources, slots));
        }
        if tier == "ha" {
            // replicated control plane: elect, churn apps, kill the leader,
            // assert no committed epoch is lost; --replicas sizes the group
            let slots = args.flag_usize("slots", 80)?;
            let replicas = args.flag_usize("replicas", 3)?;
            return Ok(ScenarioSpec::ha_matrix_sized(slots, replicas));
        }
        if tier == "dynamic" {
            let slots = args.flag_usize("slots", 200)?;
            let mut specs = ScenarioSpec::dynamic_matrix_sized(slots);
            // honor --iters (the baseline-comparison budget) like the
            // other tiers do
            if args.flag("iters").is_some() {
                let iters = args.flag_usize("iters", 300)?;
                for s in &mut specs {
                    s.iters = iters;
                }
            }
            return Ok(specs);
        }
        if tier == "dnn" {
            // generalized DNN-split chains (data inflation, result-return
            // flows) served online; --slots sizes the horizon, --iters the
            // baseline-comparison budget
            let slots = args.flag_usize("slots", 100)?;
            let iters = args.flag_usize("iters", 150)?;
            return Ok(ScenarioSpec::dnn_matrix_sized(slots, iters));
        }
        let (def_iters, def_event) = match tier.as_str() {
            "standard" | "default" => (600, 300),
            "large" => (150, 60),
            other => {
                anyhow::bail!(
                    "unknown scenario tier '{other}' \
                     (standard|large|dynamic|distributed|churn|topo-churn|massive|ha|dnn)"
                )
            }
        };
        let iters = args.flag_usize("iters", def_iters)?;
        let event_default = if args.flag("iters").is_some() {
            iters / 2
        } else {
            def_event
        };
        let event_iters = args.flag_usize("event-iters", event_default)?;
        Ok(match tier.as_str() {
            "large" => ScenarioSpec::large_matrix_sized(iters, event_iters),
            _ => ScenarioSpec::matrix_sized(iters, event_iters),
        })
    }

    // Guard against the flags-before-subcommand parser quirk (shared
    // helper — also diagnoses a flag that swallowed the subcommand word).
    // A bare `scfo scenarios [--tier ...]` still defaults to `list`, so the
    // shared guard only applies when a subcommand-shaped token is in play;
    // a run-shaped invocation with no subcommand must not silently `list`.
    if args.subcommand().is_some() || args.flag_values().any(|v| v == "list" || v == "run") {
        scfo::cli::guard_subcommand(args, "scenarios", &["list", "run"])?;
    }
    if args.subcommand().is_none()
        && (args.switch("all") || args.flag("spec").is_some() || args.flag("filter").is_some())
    {
        anyhow::bail!(
            "missing scenarios subcommand; use `scfo scenarios run --all` \
             (flags must come after the subcommand)"
        );
    }
    match args.subcommand() {
        Some("list") | None => {
            let rows: Vec<Vec<String>> = tier_matrix(args)?
                .iter()
                .map(|s| {
                    let dynamics = if let Some(h) = &s.ha {
                        format!("ha:{} replicas faults:{}", h.replicas, h.faults.name)
                    } else if let Some(tc) = &s.topo_churn {
                        format!("topo-churn:{} events x{}", tc.events.len(), s.slots)
                    } else if let Some(c) = &s.churn {
                        format!("churn:{} events x{}", c.events.len(), s.slots)
                    } else {
                        match (&s.workload, &s.distributed) {
                            (Some(w), _) => format!("workload:{} x{}", w.name(), s.slots),
                            (None, Some(d)) => {
                                format!("faults:{} x{} shards", d.faults.name, d.shards)
                            }
                            (None, None) => s
                                .events
                                .iter()
                                .map(|e| e.kind())
                                .collect::<Vec<_>>()
                                .join(","),
                        }
                    };
                    vec![
                        s.name().to_string(),
                        s.base.topology.clone(),
                        s.congestion.name().to_string(),
                        dynamics,
                        s.iters.to_string(),
                    ]
                })
                .collect();
            print_table(
                "Scenario matrix (scfo scenarios run --all)",
                &["name", "topology", "congestion", "events/workload", "iters"],
                &rows,
            );
            Ok(())
        }
        Some("run") => {
            let iters = args.flag_usize("iters", 600)?;
            let event_iters = args.flag_usize("event-iters", iters / 2)?;
            let specs: Vec<ScenarioSpec> = if let Some(path) = args.flag("spec") {
                let mut spec = ScenarioSpec::load(std::path::Path::new(path))?;
                // explicit budget flags override the spec file's budgets
                if args.flag("iters").is_some() {
                    spec.iters = iters;
                }
                if args.flag("iters").is_some() || args.flag("event-iters").is_some() {
                    for ev in &mut spec.events {
                        use scfo::scenarios::DynamicEvent;
                        match ev {
                            DynamicEvent::RateScale { iters, .. }
                            | DynamicEvent::LinkDown { iters }
                            | DynamicEvent::LinkUp { iters } => *iters = event_iters,
                        }
                    }
                }
                vec![spec]
            } else if args.switch("all")
                || args.flag("filter").is_some()
                // an explicit tier selects its whole matrix, --all implied
                || args.flag("tier").is_some()
            {
                let filter = args.flag_or("filter", "");
                tier_matrix(args)?
                    .into_iter()
                    .filter(|s| s.name().contains(&filter))
                    .collect()
            } else {
                anyhow::bail!(
                    "scenarios run needs --all, --filter SUBSTR, --tier NAME or --spec FILE"
                );
            };
            anyhow::ensure!(!specs.is_empty(), "scenario filter matched nothing");
            let opts = RunnerOptions {
                jobs: args.flag_usize("jobs", RunnerOptions::default().jobs)?,
                out_dir: Some(std::path::PathBuf::from(
                    args.flag_or("out", "reports/scenarios"),
                )),
                quiet: args.switch("quiet"),
            };
            let reports = run_batch(&specs, &opts)?;
            print_table(
                "Scenario engine — GP vs baselines (ratios to GP)",
                &scfo::bench::SCENARIO_SUMMARY_HEADER,
                &scfo::bench::scenario_summary_rows(&reports),
            );
            let wins = reports.iter().filter(|r| r.gp_within_baselines).count();
            println!(
                "GP within every baseline: {wins}/{} scenarios; reports in {}",
                reports.len(),
                opts.out_dir.as_ref().unwrap().display()
            );
            Ok(())
        }
        Some(other) => {
            anyhow::bail!("unknown scenarios subcommand '{other}' (list|run)")
        }
    }
}

/// The asynchronous sharded runtime from the command line: run a topology
/// to quiescence under a fault spec (preset name or TOML/JSON file), print
/// the rounds/messages/bytes summary, optionally dump it as JSON.
fn cmd_distributed(args: &Args) -> anyhow::Result<()> {
    use scfo::distributed::{AsyncRuntime, FaultSpec, RuntimeOptions};

    scfo::cli::guard_subcommand(args, "distributed", &["run", "faults"])?;
    match args.subcommand() {
        Some("faults") => {
            let rows: Vec<Vec<String>> = FaultSpec::PRESETS
                .iter()
                .map(|name| {
                    let f = FaultSpec::preset(name, 0).unwrap();
                    vec![
                        f.name.clone(),
                        format!("{:.2}", f.drop),
                        format!("{:.2}", f.dup),
                        format!("{}..={}", f.min_delay, f.max_delay),
                        f.partitions.len().to_string(),
                    ]
                })
                .collect();
            print_table(
                "Fault presets (scfo distributed run --faults NAME)",
                &["name", "drop", "dup", "delay ticks", "partitions"],
                &rows,
            );
            Ok(())
        }
        Some("run") => {
            // accept generator families (er-200-800, sw-1024-2048, ...) in
            // addition to the Table-II names and --config files
            let sc = if args.flag("config").is_some() {
                scenario_from(args)?
            } else {
                let topo = args.flag_or("topology", "abilene");
                match scenario_from(args) {
                    Ok(sc) => sc,
                    Err(_) => {
                        let mut sc = ScenarioSpec::named(&topo, Congestion::Nominal)?
                            .effective_base();
                        sc.seed = args.flag_usize("seed", sc.seed as usize)? as u64;
                        sc
                    }
                }
            };
            let shards = args.flag_usize("shards", 4)?;
            let max_epochs = args.flag_u64("epochs", 4000)?;
            let faults = match args.flag("faults") {
                None => FaultSpec::clean(sc.seed),
                Some(f) if f.ends_with(".toml") || f.ends_with(".json") => {
                    FaultSpec::load(std::path::Path::new(f))?
                }
                Some(name) => FaultSpec::preset(name, args.flag_u64("fault-seed", sc.seed)?)?,
            };
            let mut rng = Rng::new(sc.seed);
            let net = sc.build(&mut rng)?;
            println!(
                "distributed {} : |V|={} |E|={} |S|={} shards={} faults={}",
                sc.name,
                net.n(),
                net.m(),
                net.num_stages(),
                shards,
                faults.name
            );
            let phi0 = Strategy::shortest_path_to_dest(&net);
            let opts = RuntimeOptions {
                shards,
                max_epochs,
                alpha: args.flag_f64("alpha", 0.1)?,
                ..RuntimeOptions::default()
            };
            let mut rt = if faults.is_clean() {
                AsyncRuntime::in_mem(net.clone(), phi0, opts)
            } else {
                AsyncRuntime::sim_net(net.clone(), phi0, faults.clone(), opts)
            };
            let rep = rt.run_until_quiescent();
            let s = &rep.stats;
            println!(
                "{} after {} rounds ({} ticks): final cost {:.9}",
                if rep.converged { "quiesced" } else { "budget exhausted" },
                rep.epochs,
                rep.ticks,
                rep.final_cost
            );
            println!(
                "transport {}: {} msgs sent / {} delivered / {} dropped ({} fault, {} partition, {} overflow), {} bytes, max queue depth {}",
                s.transport_name,
                s.transport.sent,
                s.transport.delivered,
                s.transport.dropped_total(),
                s.transport.dropped_fault,
                s.transport.dropped_partition,
                s.transport.dropped_overflow,
                s.transport.bytes_sent,
                s.transport.max_queue_depth,
            );
            println!(
                "control msgs {}, stale reads {}, safety-net reverts {}",
                s.control_messages, s.stale_reads, s.reverted_stages
            );
            if args.switch("compare") {
                let mut gp = GradientProjection::new(&net, GpOptions::default());
                let central = gp.run(&net, args.flag_usize("iters", 2000)?).final_cost;
                let rel = (rep.final_cost - central).abs() / (1.0 + central);
                println!("centralized GP {central:.9}; relative gap {rel:.3e}");
            }
            if let Some(out) = args.flag("json") {
                let doc = Json::obj(vec![
                    ("scenario", Json::Str(sc.name.clone())),
                    ("shards", Json::Num(shards as f64)),
                    ("faults", faults.to_json()),
                    ("converged", Json::Bool(rep.converged)),
                    ("rounds", Json::Num(rep.epochs as f64)),
                    ("ticks", Json::Num(rep.ticks as f64)),
                    ("final_cost", Json::Num(rep.final_cost)),
                    ("messages_sent", Json::Num(s.transport.sent as f64)),
                    ("messages_dropped", Json::Num(s.transport.dropped_total() as f64)),
                    ("bytes_sent", Json::Num(s.transport.bytes_sent as f64)),
                    ("max_queue_depth", Json::Num(s.transport.max_queue_depth as f64)),
                    ("stale_reads", Json::Num(s.stale_reads as f64)),
                    ("cost_trace", Json::arr_f64(&rep.cost_trace)),
                ]);
                std::fs::write(out, doc.to_string_pretty())?;
                println!("wrote {out}");
            }
            Ok(())
        }
        other => {
            if let Some(o) = other {
                eprintln!("unknown distributed subcommand '{o}'");
            }
            anyhow::bail!(
                "usage: scfo distributed run --topology T --shards N \
                 [--faults clean|lossy|partition|spec.toml] [--epochs N] [--compare] \
                 [--json OUT] | scfo distributed faults"
            )
        }
    }
}

fn cmd_broadcast(args: &Args) -> anyhow::Result<()> {
    let sc = scenario_from(args)?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let phi = Strategy::shortest_path_to_dest(&net);
    let fs = FlowState::solve(&net, &phi).unwrap();
    let out = scfo::broadcast::run_broadcast(&net, &phi, &fs);
    println!(
        "broadcast audit on {}: |S|={} |E|={} messages={} (bound |S||E|={}) rounds={}",
        sc.name,
        net.num_stages(),
        net.m(),
        out.messages,
        net.num_stages() * net.m(),
        out.rounds
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    scfo::util::logging::init();
    let args = Args::from_env();
    // `--profile FILE` turns the flight recorder on for any command and
    // writes the Chrome trace-event snapshot on success (crate::obs)
    let profile_out = args.flag("profile").map(std::path::PathBuf::from);
    if profile_out.is_some() {
        scfo::obs::enable(scfo::obs::DEFAULT_CAPACITY);
    }
    let outcome = match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("compare") => cmd_compare(&args),
        Some("table2") => cmd_table2(&args),
        Some("fig5") => cmd_fig5(&args),
        Some("fig6") => cmd_fig6(&args),
        Some("fig7") => cmd_fig7(&args),
        Some("scenarios") => cmd_scenarios(&args),
        Some("bench") => cmd_bench(&args),
        Some("serve") => cmd_serve(&args),
        Some("trace") => cmd_trace(&args),
        Some("validate") => cmd_validate(&args),
        Some("distributed") => cmd_distributed(&args),
        Some("broadcast") => cmd_broadcast(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown command '{o}'");
            }
            eprintln!(
                "usage: scfo <run|compare|table2|fig5|fig6|fig7|scenarios|bench|serve|trace|validate|distributed|broadcast> \
                 [--topology NAME] [--config FILE] [--iters N] [--alpha A] [--jobs N] \
                 [--tier large|dynamic|distributed|churn|topo-churn|massive|ha|dnn] [--workload SPEC] [--shards N] \
                 [--faults SPEC] [--http ADDR] [--checkpoint DIR] [--restore] [--control] \
                 [--topo-churn] [--profile FILE] [--xla]"
            );
            std::process::exit(2);
        }
    };
    if outcome.is_ok() {
        if let Some(path) = &profile_out {
            scfo::obs::write_profile(path)?;
            let (retained, recorded, dropped, _) = scfo::obs::stats();
            eprintln!(
                "profile: wrote {retained} spans to {} ({recorded} recorded, {dropped} dropped)",
                path.display()
            );
        }
    }
    outcome
}
