//! `scfo` — CLI launcher for the service-chain forwarding/offloading stack.
//!
//! ```text
//! scfo run      --topology geant [--alpha 0.1] [--iters 500] [--config cfg.json]
//! scfo compare  --topology abilene [--iters 500]   # GP vs all baselines
//! scfo table2                                      # print Table II inventory
//! scfo fig5 | fig6 | fig7                          # regenerate paper figures
//! scfo scenarios list [--tier large]               # the scenario-engine matrix
//! scfo scenarios run --all --jobs 8 [--out DIR]    # parallel batch + JSON reports
//! scfo scenarios run --all --tier large            # 1000-node-class sparse tier
//! scfo scenarios run --spec my.toml                # one spec file (TOML or JSON)
//! scfo bench --json [--scenarios a,b] [--iters N]  # GP hot-path → BENCH.json
//! scfo serve    --topology geant [--slots 200] [--xla]
//! scfo validate --topology abilene                 # DES vs analytic cost
//! scfo broadcast --topology geant                  # protocol message audit
//! ```

use scfo::algo::gp::{GpOptions, GradientProjection};
use scfo::bench::print_table;
use scfo::cli::Args;
use scfo::config::Scenario;
use scfo::flow::FlowState;
use scfo::graph::topologies::SCENARIO_NAMES;
use scfo::prelude::*;
use scfo::serving::{OnlineServer, ServerOptions};
use scfo::sim;

fn scenario_from(args: &Args) -> anyhow::Result<Scenario> {
    if let Some(cfg) = args.flag("config") {
        return Scenario::load(std::path::Path::new(cfg));
    }
    let topo = args.flag_or("topology", "abilene");
    let mut sc = Scenario::table2(&topo)?;
    sc.seed = args.flag_usize("seed", sc.seed as usize)? as u64;
    sc.rate_scale = args.flag_f64("rate-scale", 1.0)?;
    Ok(sc)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let sc = scenario_from(args)?;
    let iters = args.flag_usize("iters", 500)?;
    let alpha = args.flag_f64("alpha", 0.1)?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    println!(
        "scenario {} : |V|={} |E|={} |A|={} |S|={}",
        sc.name,
        net.n(),
        net.m(),
        net.apps.len(),
        net.num_stages()
    );
    if args.switch("xla") {
        let mut gp = scfo::runtime::XlaGp::new(
            &net,
            GpOptions {
                alpha,
                ..Default::default()
            },
        )?;
        let rep = gp.run(&net, iters)?;
        println!("XLA-GP final cost: {:.6}", rep.final_cost);
    } else {
        let mut gp = GradientProjection::new(
            &net,
            GpOptions {
                alpha,
                ..Default::default()
            },
        );
        let rep = gp.run(&net, iters);
        println!(
            "GP final cost: {:.6} (converged={} iters={})",
            rep.final_cost, rep.converged, rep.iters
        );
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let sc = scenario_from(args)?;
    let iters = args.flag_usize("iters", 500)?;
    let row = sim::compare_algorithms(&sc, iters, 1)?;
    let norm = row.normalized();
    let rows: Vec<Vec<String>> = row
        .costs
        .iter()
        .zip(&norm)
        .map(|((name, cost), (_n, x))| {
            vec![name.to_string(), format!("{cost:.4}"), format!("{x:.3}")]
        })
        .collect();
    print_table(
        &format!("Algorithm comparison — {}", sc.name),
        &["algorithm", "total cost", "normalized"],
        &rows,
    );
    Ok(())
}

fn cmd_table2(_args: &Args) -> anyhow::Result<()> {
    let mut rows = Vec::new();
    for name in SCENARIO_NAMES {
        let sc = Scenario::table2(name)?;
        let mut rng = Rng::new(sc.seed);
        let net = sc.build(&mut rng)?;
        rows.push(vec![
            name.to_string(),
            net.n().to_string(),
            (net.m() / 2).to_string(),
            sc.num_apps.to_string(),
            sc.num_sources.to_string(),
            format!("{:?}", sc.link_kind),
            format!("{}", sc.link_param),
            format!("{:?}", sc.comp_kind),
            format!("{}", sc.comp_param),
        ]);
    }
    print_table(
        "Table II — simulated network scenarios",
        &["topology", "|V|", "|E|", "|A|", "R", "link", "d̄ij", "comp", "s̄i"],
        &rows,
    );
    Ok(())
}

fn cmd_fig5(args: &Args) -> anyhow::Result<()> {
    let iters = args.flag_usize("iters", 400)?;
    let mut scenarios: Vec<Scenario> = SCENARIO_NAMES
        .iter()
        .map(|n| Scenario::table2(n).unwrap())
        .collect();
    scenarios.push(Scenario::sw_linear());
    let mut rows = Vec::new();
    for sc in &scenarios {
        let row = sim::compare_algorithms(sc, iters, 1)?;
        let mut cells = vec![sc.name.clone()];
        for (_n, x) in row.normalized() {
            cells.push(format!("{x:.3}"));
        }
        rows.push(cells);
    }
    print_table(
        "Fig. 5 — normalized total cost per scenario",
        &["scenario", "GP", "SPOC", "LCOF", "LPR-SC"],
        &rows,
    );
    Ok(())
}

fn cmd_fig6(args: &Args) -> anyhow::Result<()> {
    let iters = args.flag_usize("iters", 400)?;
    let sc = Scenario::table2("abilene")?;
    let scales = [0.4, 0.6, 0.8, 1.0, 1.2, 1.4, 1.6, 1.8];
    let sweep = sim::rate_sweep(&sc, &scales, iters)?;
    let rows: Vec<Vec<String>> = sweep
        .iter()
        .map(|(scale, row)| {
            let mut cells = vec![format!("{scale:.1}")];
            for (_n, c) in &row.costs {
                cells.push(format!("{c:.4}"));
            }
            cells
        })
        .collect();
    print_table(
        "Fig. 6 — total cost vs input rate scale (Abilene)",
        &["rate scale", "GP", "SPOC", "LCOF", "LPR-SC"],
        &rows,
    );
    Ok(())
}

fn cmd_fig7(args: &Args) -> anyhow::Result<()> {
    let iters = args.flag_usize("iters", 400)?;
    let sc = Scenario::table2("abilene")?;
    let l0s = [2.0, 4.0, 6.0, 8.0, 10.0, 14.0, 20.0];
    let rows_data = sim::packet_size_sweep(&sc, &l0s, iters)?;
    let rows: Vec<Vec<String>> = rows_data
        .iter()
        .map(|r| {
            vec![
                format!("{:.0}", r.l0),
                format!("{:.3}", r.data_hops),
                format!("{:.3}", r.result_hops),
            ]
        })
        .collect();
    print_table(
        "Fig. 7 — avg packet hops vs input packet size (GP, Abilene)",
        &["L(a,0)", "data hops", "result hops"],
        &rows,
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let sc = scenario_from(args)?;
    let slots = args.flag_usize("slots", 200)?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let opts = ServerOptions::default();
    let metrics = if args.switch("xla") {
        let gp = scfo::runtime::XlaGp::new(&net, GpOptions::default())?;
        let mut srv = OnlineServer::new(net, gp, opts);
        let m = srv.run(slots)?;
        println!("delay histogram: {}", srv.delay_hist.summary());
        m
    } else {
        let gp = GradientProjection::new(&net, GpOptions::default());
        let mut srv = OnlineServer::new(net, gp, opts);
        let m = srv.run(slots)?;
        println!("delay histogram: {}", srv.delay_hist.summary());
        m
    };
    let last = metrics.last().unwrap();
    let lat: Vec<f64> = metrics.iter().map(|m| m.optimizer_latency).collect();
    println!(
        "served {} slots; final cost {:.4}; expected delay {:.4}s; optimizer latency mean {:.2}ms p95 {:.2}ms",
        metrics.len(),
        last.cost,
        last.expected_delay,
        scfo::util::stats::mean(&lat) * 1e3,
        scfo::util::stats::percentile(&lat, 95.0) * 1e3,
    );
    Ok(())
}

fn cmd_validate(args: &Args) -> anyhow::Result<()> {
    let sc = scenario_from(args)?;
    let iters = args.flag_usize("iters", 300)?;
    let horizon = args.flag_f64("horizon", 2000.0)?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let mut gp = GradientProjection::new(&net, GpOptions::default());
    gp.run(&net, iters);
    let analytic = FlowState::solve(&net, &gp.phi).unwrap().total_cost;
    let rep = sim::simulate(&net, &gp.phi, horizon, sc.seed)?;
    println!("analytic cost (expected packets in system): {analytic:.4}");
    println!(
        "DES measured: occupancy {:.4}, mean delay {:.4}s, delivered {}, λ {:.3}",
        rep.avg_occupancy, rep.mean_delay, rep.delivered, rep.lambda
    );
    println!(
        "Little cross-check: λ·W = {:.4} (vs N = {:.4})",
        rep.lambda * rep.mean_delay,
        rep.avg_occupancy
    );
    Ok(())
}

/// GP hot-path benchmark: time per-iteration wall clock + cost trajectory on
/// the requested scenarios; `--json` writes the machine-readable BENCH.json
/// perf baseline (schema: docs/PERFORMANCE.md).
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let scenarios = args.flag_or("scenarios", "abilene,geant,sw");
    let iters = args.flag_usize("iters", 60)?;
    let mut results = Vec::new();
    for name in scenarios.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        eprintln!("bench {name} ({iters} iters)...");
        results.push(scfo::bench::bench_gp_scenario(name, iters)?);
    }
    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                format!("{}/{}", r.n, r.m),
                r.stages.to_string(),
                r.arena_slots.to_string(),
                format!("{:.3}", r.mean_iter_secs() * 1e3),
                format!(
                    "{:.4}",
                    r.cost_trajectory.last().copied().unwrap_or(f64::NAN)
                ),
                match r.peak_rss_bytes {
                    Some(b) => format!("{:.1}", b as f64 / (1024.0 * 1024.0)),
                    None => "n/a".to_string(),
                },
            ]
        })
        .collect();
    print_table(
        "GP hot-path bench (sparse CSR core)",
        &["scenario", "|V|/|E|", "|S|", "arena", "iter ms", "final cost", "peak RSS MB"],
        &rows,
    );
    if args.switch("json") || args.flag("out").is_some() {
        let out = std::path::PathBuf::from(args.flag_or("out", "BENCH.json"));
        let doc = scfo::bench::gp_bench_json(&results);
        std::fs::write(&out, doc.to_string_pretty())?;
        println!("wrote {}", out.display());
    }
    Ok(())
}

fn cmd_scenarios(args: &Args) -> anyhow::Result<()> {
    use scfo::scenarios::{run_batch, RunnerOptions, ScenarioSpec};

    /// Expand the selected tier's matrix. Each tier carries its own default
    /// budgets (standard: 600/300; large: 150/60 — thousand-node scenarios
    /// need far fewer, more expensive iterations); explicit --iters /
    /// --event-iters flags override, with --event-iters defaulting to half
    /// of an explicitly given --iters as before.
    fn tier_matrix(args: &Args) -> anyhow::Result<Vec<ScenarioSpec>> {
        let tier = args.flag_or("tier", "standard");
        let (def_iters, def_event) = match tier.as_str() {
            "standard" | "default" => (600, 300),
            "large" => (150, 60),
            other => anyhow::bail!("unknown scenario tier '{other}' (standard|large)"),
        };
        let iters = args.flag_usize("iters", def_iters)?;
        let event_default = if args.flag("iters").is_some() {
            iters / 2
        } else {
            def_event
        };
        let event_iters = args.flag_usize("event-iters", event_default)?;
        Ok(match tier.as_str() {
            "large" => ScenarioSpec::large_matrix_sized(iters, event_iters),
            _ => ScenarioSpec::matrix_sized(iters, event_iters),
        })
    }

    // Guard against the flags-before-subcommand parser quirk: a run-shaped
    // invocation with no subcommand word must not silently become `list`.
    if args.subcommand().is_none()
        && (args.switch("all") || args.flag("spec").is_some() || args.flag("filter").is_some())
    {
        anyhow::bail!(
            "missing scenarios subcommand; use `scfo scenarios run --all` \
             (flags must come after the subcommand)"
        );
    }
    match args.subcommand() {
        Some("list") | None => {
            let rows: Vec<Vec<String>> = tier_matrix(args)?
                .iter()
                .map(|s| {
                    vec![
                        s.name().to_string(),
                        s.base.topology.clone(),
                        s.congestion.name().to_string(),
                        s.events
                            .iter()
                            .map(|e| e.kind())
                            .collect::<Vec<_>>()
                            .join(","),
                        s.iters.to_string(),
                    ]
                })
                .collect();
            print_table(
                "Scenario matrix (scfo scenarios run --all)",
                &["name", "topology", "congestion", "events", "iters"],
                &rows,
            );
            Ok(())
        }
        Some("run") => {
            let iters = args.flag_usize("iters", 600)?;
            let event_iters = args.flag_usize("event-iters", iters / 2)?;
            let specs: Vec<ScenarioSpec> = if let Some(path) = args.flag("spec") {
                let mut spec = ScenarioSpec::load(std::path::Path::new(path))?;
                // explicit budget flags override the spec file's budgets
                if args.flag("iters").is_some() {
                    spec.iters = iters;
                }
                if args.flag("iters").is_some() || args.flag("event-iters").is_some() {
                    for ev in &mut spec.events {
                        use scfo::scenarios::DynamicEvent;
                        match ev {
                            DynamicEvent::RateScale { iters, .. }
                            | DynamicEvent::LinkDown { iters }
                            | DynamicEvent::LinkUp { iters } => *iters = event_iters,
                        }
                    }
                }
                vec![spec]
            } else if args.switch("all") || args.flag("filter").is_some() {
                let filter = args.flag_or("filter", "");
                tier_matrix(args)?
                    .into_iter()
                    .filter(|s| s.name().contains(&filter))
                    .collect()
            } else {
                anyhow::bail!(
                    "scenarios run needs --all, --filter SUBSTR or --spec FILE"
                );
            };
            anyhow::ensure!(!specs.is_empty(), "scenario filter matched nothing");
            let opts = RunnerOptions {
                jobs: args.flag_usize("jobs", RunnerOptions::default().jobs)?,
                out_dir: Some(std::path::PathBuf::from(
                    args.flag_or("out", "reports/scenarios"),
                )),
                quiet: args.switch("quiet"),
            };
            let reports = run_batch(&specs, &opts)?;
            print_table(
                "Scenario engine — GP vs baselines (ratios to GP)",
                &scfo::bench::SCENARIO_SUMMARY_HEADER,
                &scfo::bench::scenario_summary_rows(&reports),
            );
            let wins = reports.iter().filter(|r| r.gp_within_baselines).count();
            println!(
                "GP within every baseline: {wins}/{} scenarios; reports in {}",
                reports.len(),
                opts.out_dir.as_ref().unwrap().display()
            );
            Ok(())
        }
        Some(other) => {
            anyhow::bail!("unknown scenarios subcommand '{other}' (list|run)")
        }
    }
}

fn cmd_broadcast(args: &Args) -> anyhow::Result<()> {
    let sc = scenario_from(args)?;
    let mut rng = Rng::new(sc.seed);
    let net = sc.build(&mut rng)?;
    let phi = Strategy::shortest_path_to_dest(&net);
    let fs = FlowState::solve(&net, &phi).unwrap();
    let out = scfo::broadcast::run_broadcast(&net, &phi, &fs);
    println!(
        "broadcast audit on {}: |S|={} |E|={} messages={} (bound |S||E|={}) rounds={}",
        sc.name,
        net.num_stages(),
        net.m(),
        out.messages,
        net.num_stages() * net.m(),
        out.rounds
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    scfo::util::logging::init();
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("run") => cmd_run(&args),
        Some("compare") => cmd_compare(&args),
        Some("table2") => cmd_table2(&args),
        Some("fig5") => cmd_fig5(&args),
        Some("fig6") => cmd_fig6(&args),
        Some("fig7") => cmd_fig7(&args),
        Some("scenarios") => cmd_scenarios(&args),
        Some("bench") => cmd_bench(&args),
        Some("serve") => cmd_serve(&args),
        Some("validate") => cmd_validate(&args),
        Some("broadcast") => cmd_broadcast(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown command '{o}'");
            }
            eprintln!(
                "usage: scfo <run|compare|table2|fig5|fig6|fig7|scenarios|bench|serve|validate|broadcast> \
                 [--topology NAME] [--config FILE] [--iters N] [--alpha A] [--jobs N] [--tier large] [--xla]"
            );
            std::process::exit(2);
        }
    }
}
