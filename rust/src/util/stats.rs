//! Small statistics helpers used by metrics, benches and the DES validator.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (n-1 denominator); 0.0 for len < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile via linear interpolation on the sorted copy. q in [0,100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, q)
}

/// [`percentile`] on an already-ascending slice (skips the sort — callers
/// that cache a sorted view, like `metrics::Histogram`, use this).
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = (q / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (rank - lo as f64)
    }
}

/// Max of a slice; NaN-free inputs assumed. 0.0 for empty.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max).max(0.0)
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.5, -1.0, 0.25];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
