//! Timing helpers shared by the bench harness and metrics.

use std::time::{Duration, Instant};

/// Stopwatch measuring wall-clock spans.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch {
            start: Instant::now(),
        }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Nanoseconds since a process-wide monotonic origin (the first call).
/// Shared clock for span tracing ([`crate::obs`]): all spans in one process
/// are on the same axis, so Chrome-trace timestamps nest correctly.
/// Allocation-free after the first call.
pub fn monotonic_ns() -> u64 {
    static ORIGIN: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    ORIGIN.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Human-readable duration (ns/µs/ms/s autoscale).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_result() {
        let (x, secs) = time_it(|| 21 * 2);
        assert_eq!(x, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn monotonic_ns_is_monotone() {
        let a = monotonic_ns();
        let b = monotonic_ns();
        assert!(b >= a);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(2.5e-9).ends_with("ns"));
        assert!(fmt_duration(2.5e-6).ends_with("µs"));
        assert!(fmt_duration(2.5e-3).ends_with("ms"));
        assert!(fmt_duration(2.5).ends_with('s'));
    }
}
