//! Shared substrates: deterministic PRNG, JSON, statistics, property-test
//! harness, timing and logging. These replace external crates (rand, serde,
//! proptest, criterion plumbing) that are unavailable in this offline build.

pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
pub mod toml;

/// Float comparison helper used across tests: |a-b| <= atol + rtol*|b|.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs()
}

#[cfg(test)]
mod tests {
    use super::close;

    #[test]
    fn close_semantics() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(!close(1.0, 1.1, 1e-6, 1e-6));
        assert!(close(0.0, 1e-9, 0.0, 1e-6));
    }
}
