//! Minimal TOML subset parser (the `toml` crate is unavailable offline).
//!
//! Parses the subset the scenario-spec files use and converts it into the
//! crate's [`Json`] value model so one loading path serves both formats:
//!
//! * `key = value` pairs with string, integer, float, boolean and flat
//!   array values,
//! * `[table]` / `[table.sub]` headers,
//! * `[[array-of-tables]]` headers (used for event schedules),
//! * `#` comments and blank lines.
//!
//! Not supported (rejected with an error): inline tables, string escapes,
//! multi-line strings, dotted keys in assignments, dates. The scenario
//! engine does not need them.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Parse a TOML document into a [`Json::Obj`].
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    // Path of the currently open table; an Index segment addresses an
    // element of an array of tables created by a [[...]] header.
    let mut path: Vec<PathSeg> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]")) {
            let keys = split_table_key(inner, lineno)?;
            let arr = resolve_array(&mut root, &keys, lineno)?;
            arr.push(Json::Obj(BTreeMap::new()));
            let idx = arr.len() - 1;
            path = to_segs(&keys);
            path.push(PathSeg::Index(idx));
        } else if let Some(inner) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            let keys = split_table_key(inner, lineno)?;
            path = to_segs(&keys);
            // materialize the table so empty sections exist in the output
            let _ = resolve_table(&mut root, &path, lineno)?;
        } else if let Some((k, v)) = line.split_once('=') {
            let key = unquote_key(k.trim(), lineno)?;
            let value = parse_value(v.trim(), lineno)?;
            let table = resolve_table(&mut root, &path, lineno)?;
            table.insert(key, value);
        } else {
            anyhow::bail!("toml line {}: cannot parse '{line}'", lineno + 1);
        }
    }
    Ok(Json::Obj(root))
}

#[derive(Clone, Debug)]
enum PathSeg {
    Key(String),
    Index(usize),
}

fn to_segs(keys: &[String]) -> Vec<PathSeg> {
    keys.iter().map(|k| PathSeg::Key(k.clone())).collect()
}

fn strip_comment(line: &str) -> &str {
    // a '#' outside of a quoted string starts a comment
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn split_table_key(inner: &str, lineno: usize) -> anyhow::Result<Vec<String>> {
    let mut out = Vec::new();
    for part in inner.split('.') {
        out.push(unquote_key(part.trim(), lineno)?);
    }
    Ok(out)
}

fn unquote_key(k: &str, lineno: usize) -> anyhow::Result<String> {
    anyhow::ensure!(!k.is_empty(), "toml line {}: empty key", lineno + 1);
    if let Some(q) = k.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        return Ok(q.to_string());
    }
    anyhow::ensure!(
        k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
        "toml line {}: bad bare key '{k}'",
        lineno + 1
    );
    Ok(k.to_string())
}

/// Walk (creating intermediate tables as needed) to the table addressed by
/// `path`. Index segments step into an element of an array of tables.
fn resolve_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[PathSeg],
    lineno: usize,
) -> anyhow::Result<&'a mut BTreeMap<String, Json>> {
    let mut cur: &'a mut BTreeMap<String, Json> = root;
    let mut i = 0;
    while i < path.len() {
        let k = match &path[i] {
            PathSeg::Key(k) => k,
            PathSeg::Index(_) => {
                anyhow::bail!("toml line {}: misplaced table index", lineno + 1)
            }
        };
        if let Some(PathSeg::Index(idx)) = path.get(i + 1) {
            let entry = cur
                .entry(k.clone())
                .or_insert_with(|| Json::Arr(Vec::new()));
            let arr = match entry {
                Json::Arr(a) => a,
                _ => anyhow::bail!(
                    "toml line {}: key '{k}' is not an array of tables",
                    lineno + 1
                ),
            };
            anyhow::ensure!(
                *idx < arr.len(),
                "toml line {}: table index out of range",
                lineno + 1
            );
            cur = match &mut arr[*idx] {
                Json::Obj(o) => o,
                _ => anyhow::bail!(
                    "toml line {}: array '{k}' holds non-table values",
                    lineno + 1
                ),
            };
            i += 2;
        } else {
            let entry = cur
                .entry(k.clone())
                .or_insert_with(|| Json::Obj(BTreeMap::new()));
            cur = match entry {
                Json::Obj(next) => next,
                _ => anyhow::bail!(
                    "toml line {}: key '{k}' is not a table",
                    lineno + 1
                ),
            };
            i += 1;
        }
    }
    Ok(cur)
}

/// Walk to the array of tables addressed by `keys`, creating it if absent.
fn resolve_array<'a>(
    root: &'a mut BTreeMap<String, Json>,
    keys: &[String],
    lineno: usize,
) -> anyhow::Result<&'a mut Vec<Json>> {
    let (last, prefix) = keys.split_last().expect("non-empty table key");
    let parent = resolve_table(root, &to_segs(prefix), lineno)?;
    let entry = parent
        .entry(last.clone())
        .or_insert_with(|| Json::Arr(Vec::new()));
    match entry {
        Json::Arr(a) => Ok(a),
        _ => anyhow::bail!("toml line {}: key '{last}' is not an array", lineno + 1),
    }
}

fn parse_value(v: &str, lineno: usize) -> anyhow::Result<Json> {
    anyhow::ensure!(!v.is_empty(), "toml line {}: empty value", lineno + 1);
    if let Some(q) = v.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
        anyhow::ensure!(
            !q.contains('"') && !q.contains('\\'),
            "toml line {}: unsupported escaped string",
            lineno + 1
        );
        return Ok(Json::Str(q.to_string()));
    }
    if v == "true" {
        return Ok(Json::Bool(true));
    }
    if v == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(inner) = v.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
        let inner = inner.trim();
        let mut items = Vec::new();
        if !inner.is_empty() {
            for part in split_top_level(inner) {
                let part = part.trim();
                if part.is_empty() {
                    continue; // trailing comma
                }
                items.push(parse_value(part, lineno)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    let num = v.replace('_', "");
    let parsed = num
        .parse::<f64>()
        .map_err(|_| anyhow::anyhow!("toml line {}: bad value '{v}'", lineno + 1))?;
    // Rust's f64 parser accepts "NaN"/"inf", which TOML does not — and a
    // NaN smuggled into a per-stage scale array would poison every flow
    // downstream. Reject non-finite numbers with the offending text.
    anyhow::ensure!(
        parsed.is_finite(),
        "toml line {}: non-finite number '{v}'",
        lineno + 1
    );
    Ok(Json::Num(parsed))
}

/// Split an array body on commas at bracket depth 0 (quote-aware), so
/// nested arrays like `[[0, 0.5], [3, 1.0]]` — the control-plane churn
/// specs' sparse rate lists — parse correctly.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_keys_and_types() {
        let doc = r##"
            # a comment
            name = "er-heavy"   # trailing comment
            jobs = 4
            rate = 1.25
            on = true
            tags = ["a", "b"]
            empty = []
        "##;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("er-heavy"));
        assert_eq!(v.get("jobs").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("rate").unwrap().as_f64(), Some(1.25));
        assert_eq!(v.get("on").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("tags").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("empty").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn tables_and_subtables() {
        let doc = r#"
            top = 1
            [workload]
            num_apps = 3
            [workload.sizes]
            base = 10.0
        "#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("top").unwrap().as_f64(), Some(1.0));
        let w = v.get("workload").unwrap();
        assert_eq!(w.get("num_apps").unwrap().as_usize(), Some(3));
        assert_eq!(
            w.get("sizes").unwrap().get("base").unwrap().as_f64(),
            Some(10.0)
        );
    }

    #[test]
    fn array_of_tables() {
        let doc = r#"
            name = "x"
            [[events]]
            kind = "rate-scale"
            factor = 1.5
            [[events]]
            kind = "link-down"
        "#;
        let v = parse(doc).unwrap();
        let evs = v.get("events").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].get("kind").unwrap().as_str(), Some("rate-scale"));
        assert_eq!(evs[0].get("factor").unwrap().as_f64(), Some(1.5));
        assert_eq!(evs[1].get("kind").unwrap().as_str(), Some("link-down"));
    }

    #[test]
    fn nested_arrays_parse() {
        let v = parse("rates = [[0, 0.5], [3, 1.0]]").unwrap();
        let arr = v.get("rates").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_arr().unwrap()[0].as_usize(), Some(0));
        assert_eq!(arr[0].as_arr().unwrap()[1].as_f64(), Some(0.5));
        assert_eq!(arr[1].as_arr().unwrap()[0].as_usize(), Some(3));
        // strings containing commas and brackets stay intact
        let v = parse("xs = [\"a,b\", \"c]d\"]").unwrap();
        let arr = v.get("xs").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_str(), Some("a,b"));
        assert_eq!(arr[1].as_str(), Some("c]d"));
    }

    #[test]
    fn per_stage_float_arrays_parse() {
        // the chain-spec shape: flat float lists with underscores and a
        // trailing comma
        let v = parse("scale = [5.33, 0.5, 0.25, 1_000.0,]").unwrap();
        let arr = v.get("scale").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[0].as_f64(), Some(5.33));
        assert_eq!(arr[3].as_f64(), Some(1000.0));
        // nested per-stage lists (one row per app)
        let v = parse("scales = [[2.0, 0.5], [1.0, 1.0, 1.0]]").unwrap();
        let rows = v.get("scales").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].as_arr().unwrap().len(), 2);
        assert_eq!(rows[1].as_arr().unwrap().len(), 3);
        assert_eq!(rows[0].as_arr().unwrap()[0].as_f64(), Some(2.0));
    }

    #[test]
    fn ragged_rows_survive_parsing_for_the_resolver_to_reject() {
        // raggedness is a semantic error: the parser hands the rows through
        // and ChainSpec::resolve reports the length mismatch with context
        let v = parse("scale = [2.0, 0.5, 0.25]").unwrap();
        let spec = crate::chain::ChainSpec::Explicit {
            scale: v
                .get("scale")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap())
                .collect(),
            result_size: 0.0,
            local_frac: vec![],
        };
        let err = spec.resolve(2).unwrap_err().to_string();
        assert!(err.contains("ragged"), "got: {err}");
        assert!(err.contains("3 entries"), "got: {err}");
    }

    #[test]
    fn non_finite_numbers_are_rejected_with_context() {
        for bad in ["NaN", "nan", "inf", "-inf", "infinity"] {
            let doc = format!("scale = [1.0, {bad}]");
            let err = parse(&doc).unwrap_err().to_string();
            assert!(
                err.contains("non-finite") && err.contains(bad),
                "{bad}: got '{err}'"
            );
        }
        // scalar position too
        assert!(parse("x = NaN").unwrap_err().to_string().contains("non-finite"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("not a kv line").is_err());
        assert!(parse("x =").is_err());
        assert!(parse("[bad").is_err());
        assert!(parse("a = {inline = 1}").is_err());
        assert!(parse("key with space = 1").is_err());
    }

    #[test]
    fn comment_inside_string_preserved() {
        let v = parse("s = \"a # b\"").unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a # b"));
    }
}
