//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Usage (`no_run`: doctest binaries can't locate libxla's rpath in this
//! offline environment; the behaviour is covered by unit tests below):
//! ```no_run
//! use scfo::prop_assert;
//! use scfo::util::prop::{forall, Gen};
//! forall("sum is commutative", 100, |g: &mut Gen| {
//!     let a = g.f64_in(-1e3, 1e3);
//!     let b = g.f64_in(-1e3, 1e3);
//!     prop_assert!(g, (a + b - (b + a)).abs() < 1e-12, "a={a} b={b}");
//!     true
//! });
//! ```
//!
//! Each case gets a deterministic seed derived from the test name and case
//! index, so failures are reproducible and reported with the failing seed.
//!
//! ## Shrinking
//!
//! [`forall_cases`] separates generation from checking: the generator
//! produces a concrete *case value* (any [`Shrink`] type) and the property
//! judges it. On failure the harness greedily walks [`Shrink::shrink`]
//! candidates — halving numeric inputs toward zero, removing elements from
//! vectors, deleting edges/nodes from topologies ([`Shrink` for
//! `Graph`](crate::graph::Graph)) — re-testing each, and panics with the
//! *minimal* still-failing counterexample plus the replay seed
//! ([`replay_case`]).

use super::rng::Rng;
use crate::graph::Graph;

/// Per-case random input generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
    pub case: usize,
    failure: Option<String>,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }
    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        lo + self.rng.usize(hi_incl - lo + 1)
    }
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }
    /// Record a failure message (used by `prop_assert!`).
    pub fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
    }
}

/// Assert inside a property; records the message and aborts the case.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($fmt:tt)*) => {
        if !$cond {
            $g.fail(format!($($fmt)*));
            return false;
        }
    };
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run `cases` random cases of the property. Panics (with seed + message) on
/// the first failing case. The closure returns `true` on success; `false`
/// (usually via `prop_assert!`) on failure.
pub fn forall<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> bool,
{
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(case as u64 + 1));
        let mut g = Gen {
            rng: Rng::new(seed),
            seed,
            case,
            failure: None,
        };
        let ok = prop(&mut g);
        if !ok || g.failure.is_some() {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {}",
                g.failure.unwrap_or_else(|| "returned false".into())
            );
        }
    }
}

/// Verdict of a [`forall_cases`] property on one concrete case.
#[derive(Clone, Debug, PartialEq)]
pub enum PropResult {
    Pass,
    Fail(String),
    /// The generated case does not satisfy the property's preconditions
    /// (also used to reject invalid shrink candidates).
    Discard,
}

/// Types whose failing values can be shrunk toward a minimal counterexample.
/// `shrink` returns *strictly simpler* candidates (the harness guards
/// against non-terminating shrink loops with a budget, but candidates
/// should still always decrease some size measure).
pub trait Shrink: Sized {
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for f64 {
    /// Halve toward zero, try zero and the integer truncation.
    fn shrink(&self) -> Vec<f64> {
        let x = *self;
        if x == 0.0 {
            return Vec::new();
        }
        let mut out = vec![0.0, x / 2.0];
        if x.fract() != 0.0 {
            out.push(x.trunc());
        }
        out.retain(|c| c.abs() < x.abs());
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        let x = *self;
        match x {
            0 => Vec::new(),
            1 => vec![0],
            _ => vec![0, x / 2, x - 1],
        }
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<u64> {
        let x = *self;
        match x {
            0 => Vec::new(),
            1 => vec![0],
            _ => vec![0, x / 2, x - 1],
        }
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<(A, B)> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1.clone()));
        }
        for b in self.1.shrink() {
            out.push((self.0.clone(), b));
        }
        out
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    /// Remove the front/back half, remove single elements, then shrink
    /// individual elements.
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        if n > 1 {
            out.push(self[n / 2..].to_vec());
            out.push(self[..n / 2].to_vec());
        }
        for i in 0..n {
            let mut v = self.clone();
            v.remove(i);
            out.push(v);
        }
        for (i, x) in self.iter().enumerate() {
            for sx in x.shrink() {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

impl Shrink for Graph {
    /// Subgraph shrinking: drop one directed edge at a time, or drop the
    /// highest-numbered node together with its incident edges. Candidates
    /// that fail graph validation are skipped (properties additionally
    /// discard candidates violating their own preconditions, e.g.
    /// reachability).
    fn shrink(&self) -> Vec<Graph> {
        let mut out = Vec::new();
        let n = self.n();
        let edges = self.edges();
        for skip in 0..edges.len() {
            let es: Vec<(usize, usize)> = edges
                .iter()
                .enumerate()
                .filter(|&(i, _)| i != skip)
                .map(|(_, &e)| e)
                .collect();
            if let Ok(g) = Graph::new(n, &es) {
                out.push(g);
            }
        }
        if n > 1 {
            let es: Vec<(usize, usize)> = edges
                .iter()
                .copied()
                .filter(|&(i, j)| i != n - 1 && j != n - 1)
                .collect();
            if let Ok(g) = Graph::new(n - 1, &es) {
                out.push(g);
            }
        }
        out
    }
}

/// Budget of property evaluations spent shrinking one failure.
const SHRINK_BUDGET: usize = 2000;

/// Greedily shrink `witness` while the property keeps failing; returns the
/// minimal counterexample and its failure message.
fn shrink_to_minimal<T: Shrink>(
    witness: T,
    msg: String,
    prop: &mut impl FnMut(&T) -> PropResult,
) -> (T, String, usize) {
    let mut cur = witness;
    let mut cur_msg = msg;
    let mut evals = 0usize;
    let mut steps = 0usize;
    'outer: loop {
        for cand in cur.shrink() {
            if evals >= SHRINK_BUDGET {
                break 'outer;
            }
            evals += 1;
            if let PropResult::Fail(m) = prop(&cand) {
                cur = cand;
                cur_msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break; // no candidate still fails: minimal
    }
    (cur, cur_msg, steps)
}

/// Run `cases` random cases of a property with shrinking: `gen` builds a
/// concrete case value from the per-case RNG, `prop` judges it (returning
/// [`PropResult::Discard`] for values outside the property's
/// preconditions). On failure, panics with the minimal counterexample (per
/// [`Shrink`]) and the replay seed for [`replay_case`].
pub fn forall_cases<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink + std::fmt::Debug,
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> PropResult,
{
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(case as u64 + 1));
        let mut g = Gen {
            rng: Rng::new(seed),
            seed,
            case,
            failure: None,
        };
        let value = gen(&mut g);
        match prop(&value) {
            PropResult::Pass | PropResult::Discard => {}
            PropResult::Fail(msg) => {
                let (minimal, min_msg, steps) = shrink_to_minimal(value, msg, &mut prop);
                panic!(
                    "property '{name}' failed at case {case} (replay seed {seed:#x}): {min_msg}\n\
                     minimal counterexample after {steps} shrink steps:\n{minimal:#?}"
                );
            }
        }
    }
}

/// Re-run a single [`forall_cases`] failure by its replay seed.
pub fn replay_case<T, G, P>(name: &str, seed: u64, mut gen: G, mut prop: P)
where
    T: Shrink + std::fmt::Debug,
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> PropResult,
{
    let mut g = Gen {
        rng: Rng::new(seed),
        seed,
        case: 0,
        failure: None,
    };
    let value = gen(&mut g);
    let verdict = prop(&value);
    assert!(
        !matches!(verdict, PropResult::Fail(_)),
        "replay of '{name}' seed {seed:#x} failed: {verdict:?} on {value:#?}"
    );
}

/// Re-run a single failing case by seed (debug helper).
pub fn replay<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> bool,
{
    let mut g = Gen {
        rng: Rng::new(seed),
        seed,
        case: 0,
        failure: None,
    };
    let ok = prop(&mut g);
    assert!(
        ok && g.failure.is_none(),
        "replay of '{name}' seed {seed:#x} failed: {:?}",
        g.failure
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivially true", 50, |_g| {
            count += 1;
            true
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_seed() {
        forall("always false", 10, |g| {
            prop_assert!(g, false, "nope");
            true
        });
    }

    #[test]
    fn shrink_halves_numeric_inputs_to_minimal() {
        // property: x < 100. The generator emits values up to 1e6; the
        // minimal counterexample must land just at/above the boundary.
        let mut witnessed = None;
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            forall_cases(
                "x below 100",
                50,
                |g: &mut Gen| g.f64_in(0.0, 1e6),
                |&x| {
                    if x < 100.0 {
                        PropResult::Pass
                    } else {
                        witnessed = Some(x);
                        PropResult::Fail(format!("x = {x}"))
                    }
                },
            );
        }));
        let err = res.expect_err("property must fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("replay seed"), "no replay seed in: {msg}");
        assert!(msg.contains("minimal counterexample"), "{msg}");
        // halving from anywhere below 1e6 lands in [100, 200)
        let last = witnessed.expect("saw a failure");
        assert!(
            (100.0..200.0).contains(&last),
            "minimal witness {last} not shrunk to the boundary"
        );
    }

    #[test]
    fn shrink_removes_vector_elements() {
        // property: no element is >= 10; minimal counterexample is [10].
        let mut minimal_len = usize::MAX;
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            forall_cases(
                "all below 10",
                20,
                |g: &mut Gen| {
                    let n = g.usize_in(3, 8);
                    (0..n).map(|_| g.usize_in(0, 40)).collect::<Vec<usize>>()
                },
                |v| {
                    if v.iter().all(|&x| x < 10) {
                        PropResult::Pass
                    } else {
                        minimal_len = minimal_len.min(v.len());
                        PropResult::Fail(format!("{v:?}"))
                    }
                },
            );
        }));
        assert!(res.is_err(), "property must fail");
        assert_eq!(minimal_len, 1, "vector not shrunk to a single element");
    }

    #[test]
    fn graph_shrink_produces_subgraphs() {
        let g = Graph::bidirected(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]).unwrap();
        let cands = g.shrink();
        assert!(!cands.is_empty());
        // every candidate is strictly smaller
        for c in &cands {
            assert!(
                c.m() < g.m() || c.n() < g.n(),
                "candidate not smaller: n={} m={}",
                c.n(),
                c.m()
            );
        }
        // node-removal candidate exists
        assert!(cands.iter().any(|c| c.n() == 3));
    }

    #[test]
    fn discarded_cases_do_not_fail() {
        forall_cases(
            "discards are fine",
            30,
            |g: &mut Gen| g.usize_in(0, 10),
            |&x| {
                if x % 2 == 1 {
                    PropResult::Discard // odd inputs out of scope
                } else {
                    PropResult::Pass
                }
            },
        );
    }

    #[test]
    fn replay_case_reruns_by_seed() {
        replay_case(
            "anything",
            0x1234,
            |g: &mut Gen| g.f64_in(0.0, 1.0),
            |&x| {
                if (0.0..1.0).contains(&x) {
                    PropResult::Pass
                } else {
                    PropResult::Fail("out of range".into())
                }
            },
        );
    }

    #[test]
    fn deterministic_inputs_per_name() {
        let mut first: Vec<f64> = vec![];
        forall("det", 5, |g| {
            first.push(g.f64_in(0.0, 1.0));
            true
        });
        let mut second: Vec<f64> = vec![];
        forall("det", 5, |g| {
            second.push(g.f64_in(0.0, 1.0));
            true
        });
        assert_eq!(first, second);
    }
}
