//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Usage (`no_run`: doctest binaries can't locate libxla's rpath in this
//! offline environment; the behaviour is covered by unit tests below):
//! ```no_run
//! use scfo::prop_assert;
//! use scfo::util::prop::{forall, Gen};
//! forall("sum is commutative", 100, |g: &mut Gen| {
//!     let a = g.f64_in(-1e3, 1e3);
//!     let b = g.f64_in(-1e3, 1e3);
//!     prop_assert!(g, (a + b - (b + a)).abs() < 1e-12, "a={a} b={b}");
//!     true
//! });
//! ```
//!
//! Each case gets a deterministic seed derived from the test name and case
//! index, so failures are reproducible and reported with the failing seed.

use super::rng::Rng;

/// Per-case random input generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
    pub case: usize,
    failure: Option<String>,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }
    pub fn usize_in(&mut self, lo: usize, hi_incl: usize) -> usize {
        lo + self.rng.usize(hi_incl - lo + 1)
    }
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }
    /// Record a failure message (used by `prop_assert!`).
    pub fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
    }
}

/// Assert inside a property; records the message and aborts the case.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($fmt:tt)*) => {
        if !$cond {
            $g.fail(format!($($fmt)*));
            return false;
        }
    };
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run `cases` random cases of the property. Panics (with seed + message) on
/// the first failing case. The closure returns `true` on success; `false`
/// (usually via `prop_assert!`) on failure.
pub fn forall<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> bool,
{
    let base = name_seed(name);
    for case in 0..cases {
        let seed = base.wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(case as u64 + 1));
        let mut g = Gen {
            rng: Rng::new(seed),
            seed,
            case,
            failure: None,
        };
        let ok = prop(&mut g);
        if !ok || g.failure.is_some() {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {}",
                g.failure.unwrap_or_else(|| "returned false".into())
            );
        }
    }
}

/// Re-run a single failing case by seed (debug helper).
pub fn replay<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> bool,
{
    let mut g = Gen {
        rng: Rng::new(seed),
        seed,
        case: 0,
        failure: None,
    };
    let ok = prop(&mut g);
    assert!(
        ok && g.failure.is_none(),
        "replay of '{name}' seed {seed:#x} failed: {:?}",
        g.failure
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivially true", 50, |_g| {
            count += 1;
            true
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_seed() {
        forall("always false", 10, |g| {
            prop_assert!(g, false, "nope");
            true
        });
    }

    #[test]
    fn deterministic_inputs_per_name() {
        let mut first: Vec<f64> = vec![];
        forall("det", 5, |g| {
            first.push(g.f64_in(0.0, 1.0));
            true
        });
        let mut second: Vec<f64> = vec![];
        forall("det", 5, |g| {
            second.push(g.f64_in(0.0, 1.0));
            true
        });
        assert_eq!(first, second);
    }
}
