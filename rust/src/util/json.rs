//! Minimal JSON substrate (serde is unavailable offline).
//!
//! A complete, strict JSON parser + writer adequate for the config system and
//! experiment result dumps. Supports objects, arrays, strings (with escapes,
//! `\uXXXX` incl. surrogate pairs), numbers, booleans and null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors -------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Lossless u64 decoding: accepts the hex-string form written by
    /// [`Json::from_u64`] as well as plain non-negative integral numbers
    /// (exact only below 2^53 — the reason the hex form exists).
    pub fn as_u64_lossless(&self) -> Option<u64> {
        match self {
            Json::Str(s) => {
                let hex = s.strip_prefix("0x")?;
                u64::from_str_radix(hex, 16).ok()
            }
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < 9.0e15 => Some(*x as u64),
            _ => None,
        }
    }

    // ---- constructors ----------------------------------------------------
    /// Lossless u64 encoding as a hex string. `Json::Num` stores f64, which
    /// silently corrupts integers above 2^53 — RNG state words need all 64
    /// bits to round-trip ([`Json::as_u64_lossless`] reads both forms).
    pub fn from_u64(x: u64) -> Json {
        Json::Str(format!("0x{x:016x}"))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            fmt::Write::write_fmt(out, format_args!("{}", x as i64)).unwrap();
        } else {
            fmt::Write::write_fmt(out, format_args!("{}", x)).unwrap();
        }
    } else {
        // JSON has no Inf/NaN; emit null (documented lossy behaviour).
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            at: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let txt = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(txt, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — 世界"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn u64_roundtrips_losslessly() {
        for x in [0u64, 1, u64::MAX, 1u64 << 53, 0xDEADBEEF_CAFEBABE] {
            let v = Json::from_u64(x);
            assert_eq!(v.as_u64_lossless(), Some(x), "{x}");
            // survives a serialize/parse cycle too
            let re = Json::parse(&v.to_string()).unwrap();
            assert_eq!(re.as_u64_lossless(), Some(x), "{x}");
        }
        // plain small integral numbers are accepted as a convenience
        assert_eq!(Json::Num(42.0).as_u64_lossless(), Some(42));
        assert_eq!(Json::Num(-1.0).as_u64_lossless(), None);
        assert_eq!(Json::Num(0.5).as_u64_lossless(), None);
        assert_eq!(Json::Str("xyz".into()).as_u64_lossless(), None);
    }

    #[test]
    fn as_usize_rejects_fractions() {
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(3.0).as_usize(), Some(3));
    }
}
