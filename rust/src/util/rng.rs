//! Deterministic PRNG substrate (the `rand` crate is unavailable offline).
//!
//! Implements xoshiro256++ seeded via SplitMix64 — the same generator family
//! used by `rand_xoshiro`. All randomness in the library flows through
//! [`Rng`] so experiments are reproducible from a single `u64` seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::usize(0)");
        // Lemire-style rejection-free for our purposes (bias < 2^-53 for small n)
        (self.f64() * n as f64) as usize % n
    }

    /// Bernoulli trial with probability p.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed sample with given rate (mean 1/rate).
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0,1]
        -u.ln() / rate
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Returns `None` if all weights are ~0.
    pub fn weighted(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if total <= 1e-300 {
            return None;
        }
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose k distinct indices from 0..n (k <= n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Fork a statistically independent child generator (for parallel actors).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Raw generator state, for checkpointing ([`Rng::from_state`] restores
    /// it exactly — the resumed stream is bit-identical).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from [`Rng::state`] output.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(9);
        let rate = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(11);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w).unwrap()] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn weighted_all_zero_is_none() {
        let mut r = Rng::new(13);
        assert!(r.weighted(&[0.0, 0.0]).is_none());
    }

    #[test]
    fn choose_distinct_unique() {
        let mut r = Rng::new(17);
        let picks = r.choose_distinct(10, 6);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 6);
        assert!(picks.iter().all(|&i| i < 10));
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(23);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn usize_bounds() {
        let mut r = Rng::new(19);
        for _ in 0..1000 {
            assert!(r.usize(7) < 7);
        }
    }
}
