//! Tiny std-only stderr logger with level filtering via `SCFO_LOG`
//! (error|warn|info|debug|trace; default info). The `log`/`once_cell` crates
//! are unavailable offline, so this module provides the whole facade: call
//! [`init`] once, then use the [`crate::log_info!`]-family macros (or
//! [`log`] directly).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Current max level; 0 = not yet initialized (treated as Info).
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);

/// Install the logger (idempotent): reads `SCFO_LOG` once and stores the
/// filter level. Safe to call repeatedly (tests do).
pub fn init() {
    let level = match std::env::var("SCFO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is a record at `level` currently enabled?
pub fn enabled(level: Level) -> bool {
    let max = MAX_LEVEL.load(Ordering::Relaxed);
    let max = if max == 0 { Level::Info as u8 } else { max };
    (level as u8) <= max
}

/// Emit one record to stderr if enabled.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{:<5} {}] {}", level.name(), target, args);
    }
}

/// Log at info level: `log_info!("solved in {} slots", n)`.
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($t)*),
        )
    };
}

/// Log at warn level.
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($t)*),
        )
    };
}

/// Log at debug level.
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($t)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        crate::log_info!("logging smoke test");
    }

    #[test]
    fn severity_ordering() {
        init();
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info) || !enabled(Level::Info)); // never panics
        assert!(Level::Error < Level::Trace);
    }
}
