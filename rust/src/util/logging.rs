//! Tiny std-only stderr logger with level filtering via `SCFO_LOG`
//! (error|warn|info|debug|trace; default info) and an optional structured
//! line format via `SCFO_LOG_JSON=1` (one JSON object per line: ts, level,
//! target, msg) for log pipelines. The `log`/`once_cell` crates are
//! unavailable offline, so this module provides the whole facade: call
//! [`init`] once, then use the [`crate::log_info!`]-family macros (or
//! [`log`] directly).

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};

/// Log severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Current max level; 0 = not yet initialized (treated as Info).
static MAX_LEVEL: AtomicU8 = AtomicU8::new(0);
/// Emit JSON lines instead of the human format (`SCFO_LOG_JSON=1`).
static JSON_FORMAT: AtomicBool = AtomicBool::new(false);
/// An unrecognized `SCFO_LOG` value is reported once, not per [`init`].
static WARNED_BAD_LEVEL: AtomicBool = AtomicBool::new(false);

/// Parse one `SCFO_LOG` value; `None` for unrecognized input.
fn parse_level(s: &str) -> Option<Level> {
    match s {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Install the logger (idempotent): reads `SCFO_LOG` once and stores the
/// filter level; an unrecognized value falls back to `info` with a
/// once-only warning instead of a silent default. `SCFO_LOG_JSON=1`
/// switches the line format to structured JSON.
pub fn init() {
    let level = match std::env::var("SCFO_LOG") {
        Ok(raw) => parse_level(&raw).unwrap_or_else(|| {
            if !WARNED_BAD_LEVEL.swap(true, Ordering::Relaxed) {
                eprintln!(
                    "[WARN  scfo::util::logging] unrecognized SCFO_LOG={raw:?} \
                     (expected error|warn|info|debug|trace); using info"
                );
            }
            Level::Info
        }),
        Err(_) => Level::Info,
    };
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
    let json = matches!(std::env::var("SCFO_LOG_JSON").as_deref(), Ok("1"));
    JSON_FORMAT.store(json, Ordering::Relaxed);
}

/// Is a record at `level` currently enabled?
pub fn enabled(level: Level) -> bool {
    let max = MAX_LEVEL.load(Ordering::Relaxed);
    let max = if max == 0 { Level::Info as u8 } else { max };
    (level as u8) <= max
}

/// Render one structured record: `{"ts":…,"level":"…","target":"…","msg":"…"}`.
/// `ts` is seconds since the Unix epoch (fractional).
fn json_line(level: Level, target: &str, msg: &str) -> String {
    use crate::util::json::Json;
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    Json::obj(vec![
        ("ts", Json::Num(ts)),
        ("level", Json::Str(level.name().to_string())),
        ("target", Json::Str(target.to_string())),
        ("msg", Json::Str(msg.to_string())),
    ])
    .to_string()
}

/// Emit one record to stderr if enabled.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        if JSON_FORMAT.load(Ordering::Relaxed) {
            eprintln!("{}", json_line(level, target, &args.to_string()));
        } else {
            eprintln!("[{:<5} {}] {}", level.name(), target, args);
        }
    }
}

/// Log at info level: `log_info!("solved in {} slots", n)`.
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($t)*),
        )
    };
}

/// Log at warn level.
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($t)*),
        )
    };
}

/// Log at debug level.
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($t)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        crate::log_info!("logging smoke test");
    }

    #[test]
    fn severity_ordering() {
        init();
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info) || !enabled(Level::Info)); // never panics
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn parse_level_accepts_all_names_and_rejects_junk() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level("INFO"), None); // levels are lowercase
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn json_line_is_parseable_and_escaped() {
        let line = json_line(Level::Warn, "scfo::test", "msg with \"quotes\"\nand newline");
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert!(v.get("ts").and_then(|t| t.as_f64()).unwrap() > 0.0);
        assert_eq!(v.get("level").and_then(|l| l.as_str()), Some("WARN"));
        assert_eq!(v.get("target").and_then(|t| t.as_str()), Some("scfo::test"));
        assert_eq!(
            v.get("msg").and_then(|m| m.as_str()),
            Some("msg with \"quotes\"\nand newline")
        );
    }
}
