//! Service-chain applications, stages and the assembled [`Network`].
//!
//! An application `a` is a chain of |𝒯_a| tasks. Its flows are partitioned
//! into stages (a,k), k = 0..|𝒯_a|: stage 0 is raw input data, stage k the
//! output of task k, stage |𝒯_a| the final results delivered to `dest`.

use crate::cost::CostFn;
use crate::graph::Graph;

/// One service-chain application.
#[derive(Clone, Debug)]
pub struct Application {
    /// Result destination d_a.
    pub dest: usize,
    /// |𝒯_a| — number of chained tasks.
    pub num_tasks: usize,
    /// L_(a,k), packet size (bits) per stage; len = num_tasks + 1.
    pub packet_sizes: Vec<f64>,
    /// r_i(a), exogenous input packet rate per node; len = |𝒱|.
    pub input_rates: Vec<f64>,
}

impl Application {
    /// Number of stages (|𝒯_a| + 1).
    pub fn num_stages(&self) -> usize {
        self.num_tasks + 1
    }
    /// Total exogenous input rate.
    pub fn total_input(&self) -> f64 {
        self.input_rates.iter().sum()
    }
}

/// Flat indexing of the stage set 𝒮 = {(a,k)}.
#[derive(Clone, Debug)]
pub struct StageRegistry {
    /// stage id -> (app, k)
    stages: Vec<(usize, usize)>,
    /// app -> first stage id
    offsets: Vec<usize>,
}

impl StageRegistry {
    pub fn new(apps: &[Application]) -> Self {
        let mut stages = Vec::new();
        let mut offsets = Vec::with_capacity(apps.len());
        for (a, app) in apps.iter().enumerate() {
            offsets.push(stages.len());
            for k in 0..app.num_stages() {
                stages.push((a, k));
            }
        }
        StageRegistry { stages, offsets }
    }
    /// |𝒮|
    pub fn len(&self) -> usize {
        self.stages.len()
    }
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }
    /// stage id -> (app, k)
    pub fn app_k(&self, s: usize) -> (usize, usize) {
        self.stages[s]
    }
    /// (app, k) -> stage id
    pub fn id(&self, a: usize, k: usize) -> usize {
        self.offsets[a] + k
    }
    /// Iterate stage ids of one app in chain order.
    pub fn of_app(&self, a: usize, num_stages: usize) -> std::ops::Range<usize> {
        self.offsets[a]..self.offsets[a] + num_stages
    }
    pub fn iter(&self) -> impl Iterator<Item = (usize, (usize, usize))> + '_ {
        self.stages.iter().copied().enumerate()
    }
}

/// The assembled CEC network: topology, applications, cost functions and
/// per-node computation weights.
#[derive(Clone, Debug)]
pub struct Network {
    pub graph: Graph,
    pub apps: Vec<Application>,
    pub stages: StageRegistry,
    /// D_ij(·) per directed link (edge id).
    pub link_cost: Vec<CostFn>,
    /// C_i(·) per node.
    pub comp_cost: Vec<CostFn>,
    /// w_i(a,k): computation workload for node i to perform task k+1 of app a
    /// on one packet; indexed [stage id][node]. Rows for final stages are
    /// unused (no further task) and kept zero.
    pub comp_weight: Vec<Vec<f64>>,
}

impl Network {
    /// Assemble and validate a network.
    pub fn new(
        graph: Graph,
        apps: Vec<Application>,
        link_cost: Vec<CostFn>,
        comp_cost: Vec<CostFn>,
        comp_weight: Vec<Vec<f64>>,
    ) -> anyhow::Result<Self> {
        let n = graph.n();
        anyhow::ensure!(link_cost.len() == graph.m(), "link_cost len != |E|");
        anyhow::ensure!(comp_cost.len() == n, "comp_cost len != |V|");
        let stages = StageRegistry::new(&apps);
        anyhow::ensure!(
            comp_weight.len() == stages.len(),
            "comp_weight stage rows {} != |S| {}",
            comp_weight.len(),
            stages.len()
        );
        for (a, app) in apps.iter().enumerate() {
            anyhow::ensure!(app.dest < n, "app {a} dest out of range");
            anyhow::ensure!(
                app.packet_sizes.len() == app.num_stages(),
                "app {a} packet_sizes len"
            );
            anyhow::ensure!(app.input_rates.len() == n, "app {a} input_rates len");
            anyhow::ensure!(
                app.packet_sizes.iter().all(|&l| l > 0.0),
                "app {a} packet sizes must be positive"
            );
            anyhow::ensure!(
                app.input_rates.iter().all(|&r| r >= 0.0),
                "app {a} negative input rate"
            );
            anyhow::ensure!(
                graph.all_reach(app.dest),
                "app {a}: not every node can reach dest {}",
                app.dest
            );
        }
        for row in &comp_weight {
            anyhow::ensure!(row.len() == n, "comp_weight row len != |V|");
            anyhow::ensure!(row.iter().all(|&w| w >= 0.0), "negative comp weight");
        }
        Ok(Network {
            graph,
            apps,
            stages,
            link_cost,
            comp_cost,
            comp_weight,
        })
    }

    pub fn n(&self) -> usize {
        self.graph.n()
    }
    pub fn m(&self) -> usize {
        self.graph.m()
    }
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Is `s` the final stage of its application?
    pub fn is_final_stage(&self, s: usize) -> bool {
        let (a, k) = self.stages.app_k(s);
        k == self.apps[a].num_tasks
    }

    /// Packet size L_(a,k) for stage id `s`.
    pub fn packet_size(&self, s: usize) -> f64 {
        let (a, k) = self.stages.app_k(s);
        self.apps[a].packet_sizes[k]
    }

    /// Destination of the app that stage `s` belongs to.
    pub fn dest_of_stage(&self, s: usize) -> usize {
        let (a, _) = self.stages.app_k(s);
        self.apps[a].dest
    }

    /// Exogenous injection rate of stage `s` at node `i` (only stage 0 has
    /// exogenous input).
    pub fn exo_rate(&self, s: usize, i: usize) -> f64 {
        let (a, k) = self.stages.app_k(s);
        if k == 0 {
            self.apps[a].input_rates[i]
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;

    pub fn tiny_app(n: usize, dest: usize, rate_at: usize) -> Application {
        let mut r = vec![0.0; n];
        r[rate_at] = 1.0;
        Application {
            dest,
            num_tasks: 2,
            packet_sizes: vec![10.0, 5.0, 1.0],
            input_rates: r,
        }
    }

    fn tiny_network() -> Network {
        let g = topologies::abilene();
        let n = g.n();
        let m = g.m();
        let apps = vec![tiny_app(n, 10, 0), tiny_app(n, 0, 9)];
        let stages = StageRegistry::new(&apps);
        let cw = vec![vec![1.0; n]; stages.len()];
        Network::new(
            g,
            apps,
            vec![CostFn::Linear { d: 1.0 }; m],
            vec![CostFn::Linear { d: 1.0 }; n],
            cw,
        )
        .unwrap()
    }

    #[test]
    fn registry_roundtrip() {
        let net = tiny_network();
        assert_eq!(net.num_stages(), 6);
        for (s, (a, k)) in net.stages.iter() {
            assert_eq!(net.stages.id(a, k), s);
        }
        assert!(net.is_final_stage(net.stages.id(0, 2)));
        assert!(!net.is_final_stage(net.stages.id(0, 1)));
    }

    #[test]
    fn packet_sizes_and_exo() {
        let net = tiny_network();
        let s00 = net.stages.id(0, 0);
        assert_eq!(net.packet_size(s00), 10.0);
        assert_eq!(net.exo_rate(s00, 0), 1.0);
        assert_eq!(net.exo_rate(net.stages.id(0, 1), 0), 0.0);
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let g = topologies::abilene();
        let n = g.n();
        let m = g.m();
        let mut app = tiny_app(n, 10, 0);
        app.packet_sizes.pop();
        let stages = StageRegistry::new(std::slice::from_ref(&app));
        let cw = vec![vec![1.0; n]; stages.len()];
        assert!(Network::new(
            g,
            vec![app],
            vec![CostFn::Linear { d: 1.0 }; m],
            vec![CostFn::Linear { d: 1.0 }; n],
            cw,
        )
        .is_err());
    }
}
