//! Service-chain applications, stages and the assembled [`Network`].
//!
//! An application `a` is a chain of |𝒯_a| tasks. Its flows are partitioned
//! into stages (a,k), k = 0..|𝒯_a|: stage 0 is raw input data, stage k the
//! output of task k, stage |𝒯_a| the final results delivered to `dest`.

use crate::chain::ChainProfile;
use crate::cost::CostFn;
use crate::graph::Graph;

/// One service-chain application.
#[derive(Clone, Debug)]
pub struct Application {
    /// Result destination d_a.
    pub dest: usize,
    /// |𝒯_a| — number of chained tasks.
    pub num_tasks: usize,
    /// L_(a,k), packet size (bits) per stage; len = num_tasks + 1.
    pub packet_sizes: Vec<f64>,
    /// r_i(a), exogenous input packet rate per node; len = |𝒱|.
    pub input_rates: Vec<f64>,
}

impl Application {
    /// Number of stages (|𝒯_a| + 1).
    pub fn num_stages(&self) -> usize {
        self.num_tasks + 1
    }
    /// Total exogenous input rate.
    pub fn total_input(&self) -> f64 {
        self.input_rates.iter().sum()
    }
}

/// Flat indexing of the stage set 𝒮 = {(a,k)}.
#[derive(Clone, Debug)]
pub struct StageRegistry {
    /// stage id -> (app, k)
    stages: Vec<(usize, usize)>,
    /// app -> first stage id
    offsets: Vec<usize>,
}

impl StageRegistry {
    pub fn new(apps: &[Application]) -> Self {
        let mut stages = Vec::new();
        let mut offsets = Vec::with_capacity(apps.len());
        for (a, app) in apps.iter().enumerate() {
            offsets.push(stages.len());
            for k in 0..app.num_stages() {
                stages.push((a, k));
            }
        }
        StageRegistry { stages, offsets }
    }
    /// |𝒮|
    pub fn len(&self) -> usize {
        self.stages.len()
    }
    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }
    /// stage id -> (app, k)
    pub fn app_k(&self, s: usize) -> (usize, usize) {
        self.stages[s]
    }
    /// (app, k) -> stage id
    pub fn id(&self, a: usize, k: usize) -> usize {
        self.offsets[a] + k
    }
    /// Iterate stage ids of one app in chain order.
    pub fn of_app(&self, a: usize, num_stages: usize) -> std::ops::Range<usize> {
        self.offsets[a]..self.offsets[a] + num_stages
    }
    pub fn iter(&self) -> impl Iterator<Item = (usize, (usize, usize))> + '_ {
        self.stages.iter().copied().enumerate()
    }
}

/// The assembled CEC network: topology, applications, cost functions and
/// per-node computation weights.
#[derive(Clone, Debug)]
pub struct Network {
    pub graph: Graph,
    pub apps: Vec<Application>,
    pub stages: StageRegistry,
    /// D_ij(·) per directed link (edge id).
    pub link_cost: Vec<CostFn>,
    /// C_i(·) per node.
    pub comp_cost: Vec<CostFn>,
    /// w_i(a,k): computation workload for node i to perform task k+1 of app a
    /// on one packet; indexed [stage id][node]. Rows for final stages are
    /// unused (no further task) and kept zero.
    pub comp_weight: Vec<Vec<f64>>,
    /// Generalized chain profile per application (identity for networks
    /// built via [`Network::new`] — the base paper model). See
    /// [`crate::chain`].
    pub chains: Vec<ChainProfile>,
    /// conv(a,k) per stage id: stage-`k+1` packets produced per stage-`k`
    /// packet processed (1.0 at final stages, which convert nothing).
    pub stage_conv: Vec<f64>,
    /// Return-flow weight per stage id: data volume crossing the *mirror*
    /// link per forward packet of this stage
    /// (`result_size · Π_{j≥k} conv[j]`; 0 when the chain has no return
    /// flow).
    pub stage_ret: Vec<f64>,
    /// Mirror edge id per edge: `rev_edge[e]` is the id of `(j,i)` for
    /// `e = (i,j)`, if present. All shipped topologies are bidirected, so it
    /// is `Some` everywhere in practice; chains with a return flow require
    /// it on every link ([`Network::with_chains`] validates this).
    pub rev_edge: Vec<Option<usize>>,
}

impl Network {
    /// Assemble and validate a network with identity chain profiles (the
    /// base paper model: no data scaling, no result-return flows).
    pub fn new(
        graph: Graph,
        apps: Vec<Application>,
        link_cost: Vec<CostFn>,
        comp_cost: Vec<CostFn>,
        comp_weight: Vec<Vec<f64>>,
    ) -> anyhow::Result<Self> {
        let chains = apps
            .iter()
            .map(|a| ChainProfile::identity(a.num_tasks))
            .collect();
        Self::with_chains(graph, apps, link_cost, comp_cost, comp_weight, chains)
    }

    /// Assemble and validate a network with explicit per-app chain profiles
    /// (generalized model: per-stage data scaling + result-return flows).
    pub fn with_chains(
        graph: Graph,
        apps: Vec<Application>,
        link_cost: Vec<CostFn>,
        comp_cost: Vec<CostFn>,
        comp_weight: Vec<Vec<f64>>,
        chains: Vec<ChainProfile>,
    ) -> anyhow::Result<Self> {
        let n = graph.n();
        anyhow::ensure!(link_cost.len() == graph.m(), "link_cost len != |E|");
        anyhow::ensure!(comp_cost.len() == n, "comp_cost len != |V|");
        let stages = StageRegistry::new(&apps);
        anyhow::ensure!(
            comp_weight.len() == stages.len(),
            "comp_weight stage rows {} != |S| {}",
            comp_weight.len(),
            stages.len()
        );
        for (a, app) in apps.iter().enumerate() {
            anyhow::ensure!(app.dest < n, "app {a} dest out of range");
            anyhow::ensure!(
                app.packet_sizes.len() == app.num_stages(),
                "app {a} packet_sizes len"
            );
            anyhow::ensure!(app.input_rates.len() == n, "app {a} input_rates len");
            anyhow::ensure!(
                app.packet_sizes.iter().all(|&l| l > 0.0),
                "app {a} packet sizes must be positive"
            );
            anyhow::ensure!(
                app.input_rates.iter().all(|&r| r >= 0.0),
                "app {a} negative input rate"
            );
            anyhow::ensure!(
                graph.all_reach(app.dest),
                "app {a}: not every node can reach dest {}",
                app.dest
            );
        }
        for row in &comp_weight {
            anyhow::ensure!(row.len() == n, "comp_weight row len != |V|");
            anyhow::ensure!(row.iter().all(|&w| w >= 0.0), "negative comp weight");
        }
        anyhow::ensure!(
            chains.len() == apps.len(),
            "chains len {} != |A| {}",
            chains.len(),
            apps.len()
        );
        let mut stage_conv = vec![1.0; stages.len()];
        let mut stage_ret = vec![0.0; stages.len()];
        for (a, (app, chain)) in apps.iter().zip(&chains).enumerate() {
            anyhow::ensure!(
                chain.conv.len() == app.num_tasks && chain.local_frac.len() == app.num_tasks,
                "app {a} chain profile is ragged ({} conv / {} local_frac entries for {} tasks)",
                chain.conv.len(),
                chain.local_frac.len(),
                app.num_tasks
            );
            let rho = chain.suffix_products();
            for k in 0..app.num_stages() {
                let s = stages.id(a, k);
                if k < app.num_tasks {
                    stage_conv[s] = chain.conv[k];
                }
                stage_ret[s] = chain.result_size * rho[k];
            }
        }
        let rev_edge: Vec<Option<usize>> = (0..graph.m())
            .map(|e| {
                let (i, j) = graph.edge(e);
                graph.edge_id(j, i)
            })
            .collect();
        if stage_ret.iter().any(|&u| u > 0.0) {
            for (e, rev) in rev_edge.iter().enumerate() {
                let (i, j) = graph.edge(e);
                anyhow::ensure!(
                    rev.is_some(),
                    "chain has a result-return flow but link ({i},{j}) has no mirror link"
                );
            }
        }
        Ok(Network {
            graph,
            apps,
            stages,
            link_cost,
            comp_cost,
            comp_weight,
            chains,
            stage_conv,
            stage_ret,
            rev_edge,
        })
    }

    pub fn n(&self) -> usize {
        self.graph.n()
    }
    pub fn m(&self) -> usize {
        self.graph.m()
    }
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Is `s` the final stage of its application?
    pub fn is_final_stage(&self, s: usize) -> bool {
        let (a, k) = self.stages.app_k(s);
        k == self.apps[a].num_tasks
    }

    /// Packet size L_(a,k) for stage id `s`.
    pub fn packet_size(&self, s: usize) -> f64 {
        let (a, k) = self.stages.app_k(s);
        self.apps[a].packet_sizes[k]
    }

    /// Destination of the app that stage `s` belongs to.
    pub fn dest_of_stage(&self, s: usize) -> usize {
        let (a, _) = self.stages.app_k(s);
        self.apps[a].dest
    }

    /// Exogenous injection rate of stage `s` at node `i` (only stage 0 has
    /// exogenous input).
    pub fn exo_rate(&self, s: usize, i: usize) -> f64 {
        let (a, k) = self.stages.app_k(s);
        if k == 0 {
            self.apps[a].input_rates[i]
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topologies;

    pub fn tiny_app(n: usize, dest: usize, rate_at: usize) -> Application {
        let mut r = vec![0.0; n];
        r[rate_at] = 1.0;
        Application {
            dest,
            num_tasks: 2,
            packet_sizes: vec![10.0, 5.0, 1.0],
            input_rates: r,
        }
    }

    fn tiny_network() -> Network {
        let g = topologies::abilene();
        let n = g.n();
        let m = g.m();
        let apps = vec![tiny_app(n, 10, 0), tiny_app(n, 0, 9)];
        let stages = StageRegistry::new(&apps);
        let cw = vec![vec![1.0; n]; stages.len()];
        Network::new(
            g,
            apps,
            vec![CostFn::Linear { d: 1.0 }; m],
            vec![CostFn::Linear { d: 1.0 }; n],
            cw,
        )
        .unwrap()
    }

    #[test]
    fn registry_roundtrip() {
        let net = tiny_network();
        assert_eq!(net.num_stages(), 6);
        for (s, (a, k)) in net.stages.iter() {
            assert_eq!(net.stages.id(a, k), s);
        }
        assert!(net.is_final_stage(net.stages.id(0, 2)));
        assert!(!net.is_final_stage(net.stages.id(0, 1)));
    }

    #[test]
    fn packet_sizes_and_exo() {
        let net = tiny_network();
        let s00 = net.stages.id(0, 0);
        assert_eq!(net.packet_size(s00), 10.0);
        assert_eq!(net.exo_rate(s00, 0), 1.0);
        assert_eq!(net.exo_rate(net.stages.id(0, 1), 0), 0.0);
    }

    #[test]
    fn new_defaults_to_identity_chains() {
        let net = tiny_network();
        assert_eq!(net.chains.len(), 2);
        assert!(net.chains.iter().all(|c| c.is_identity()));
        assert!(net.stage_conv.iter().all(|&c| c == 1.0));
        assert!(net.stage_ret.iter().all(|&u| u == 0.0));
        // abilene is bidirected: every link has a mirror
        assert!(net.rev_edge.iter().all(|r| r.is_some()));
        for (e, r) in net.rev_edge.iter().enumerate() {
            let (i, j) = net.graph.edge(e);
            assert_eq!(net.graph.edge(r.unwrap()), (j, i));
        }
    }

    #[test]
    fn with_chains_derives_stage_tables() {
        let g = topologies::abilene();
        let n = g.n();
        let m = g.m();
        let apps = vec![tiny_app(n, 10, 0)];
        let stages = StageRegistry::new(&apps);
        let cw = vec![vec![1.0; n]; stages.len()];
        let chain = crate::chain::ChainProfile {
            conv: vec![2.0, 0.5],
            result_size: 0.4,
            local_frac: vec![0.0, 0.0],
        };
        let net = Network::with_chains(
            g,
            apps,
            vec![CostFn::Linear { d: 1.0 }; m],
            vec![CostFn::Linear { d: 1.0 }; n],
            cw,
            vec![chain],
        )
        .unwrap();
        assert_eq!(net.stage_conv, vec![2.0, 0.5, 1.0]);
        // rho = [1.0, 0.5, 1.0] suffix products -> ret = 0.4 * rho
        assert_eq!(net.stage_ret, vec![0.4, 0.2, 0.4]);
    }

    #[test]
    fn with_chains_rejects_ragged_profiles() {
        let g = topologies::abilene();
        let n = g.n();
        let m = g.m();
        let apps = vec![tiny_app(n, 10, 0)];
        let stages = StageRegistry::new(&apps);
        let cw = vec![vec![1.0; n]; stages.len()];
        let chain = crate::chain::ChainProfile {
            conv: vec![2.0], // app has 2 tasks
            result_size: 0.0,
            local_frac: vec![0.0],
        };
        assert!(Network::with_chains(
            g,
            apps,
            vec![CostFn::Linear { d: 1.0 }; m],
            vec![CostFn::Linear { d: 1.0 }; n],
            cw,
            vec![chain],
        )
        .is_err());
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let g = topologies::abilene();
        let n = g.n();
        let m = g.m();
        let mut app = tiny_app(n, 10, 0);
        app.packet_sizes.pop();
        let stages = StageRegistry::new(std::slice::from_ref(&app));
        let cw = vec![vec![1.0; n]; stages.len()];
        assert!(Network::new(
            g,
            vec![app],
            vec![CostFn::Linear { d: 1.0 }; m],
            vec![CostFn::Linear { d: 1.0 }; n],
            cw,
        )
        .is_err());
    }
}
