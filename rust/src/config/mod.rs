//! Scenario configuration — the experiment config system.
//!
//! A [`Scenario`] captures one row of Table II (or a custom setup): topology,
//! application count, sources per app, cost-function families and their
//! parameters (d̄_ij, s̄_i), packet-size schedule and input-rate range. It
//! builds a concrete [`Network`] deterministically from a seed, and
//! round-trips through JSON for config files (`scfo run --config x.json`).

use crate::app::{Application, Network, StageRegistry};
use crate::chain::ChainSpec;
use crate::cost::CostKind;
use crate::graph::topologies;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One experiment scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    /// Topology name understood by [`topologies::by_name`].
    pub topology: String,
    /// |𝒜| — number of applications.
    pub num_apps: usize,
    /// R — number of random data sources per application.
    pub num_sources: usize,
    /// |𝒯_a| — tasks per application (the paper fixes 2).
    pub num_tasks: usize,
    pub link_kind: CostKind,
    /// d̄_ij: linear "speed" or queue capacity for links.
    pub link_param: f64,
    pub comp_kind: CostKind,
    /// s̄_i: linear speed or queue capacity for CPUs.
    pub comp_param: f64,
    /// Input rate range (paper: [0.5, 1.5]).
    pub rate_lo: f64,
    pub rate_hi: f64,
    /// Multiplier on all input rates (Fig. 6 sweeps this).
    pub rate_scale: f64,
    /// L_(a,0); stage k gets max(packet_base − packet_decay·k, 1).
    pub packet_base: f64,
    pub packet_decay: f64,
    /// Workload per input *bit*: w_i(a,k) = comp_weight · L_(a,k).
    /// Processing cost scaling with input size makes computation genuinely
    /// congestible (a data source running every task locally saturates its
    /// CPU), which is the regime the paper's Fig. 5/6 gaps live in.
    pub comp_weight: f64,
    /// Generalized chain profile applied to every application (None = the
    /// paper's identity chain: no data inflation, no result-return flow).
    pub chain: Option<ChainSpec>,
    pub seed: u64,
}

impl Scenario {
    /// The Table-II row for a named topology (`sw` gets Queue costs; use
    /// [`Scenario::sw_linear`] for the SW-linear variant of Fig. 5).
    pub fn table2(topology: &str) -> anyhow::Result<Scenario> {
        let (num_apps, num_sources, link_param, comp_param) = match topology {
            "connected-er" => (5, 3, 10.0, 12.0),
            "balanced-tree" => (5, 3, 20.0, 15.0),
            "fog" => (5, 3, 20.0, 17.0),
            "abilene" => (3, 3, 15.0, 10.0),
            "lhc" => (8, 3, 15.0, 15.0),
            "geant" => (10, 5, 20.0, 20.0),
            "sw" => (30, 8, 20.0, 20.0),
            other => anyhow::bail!("not a Table-II topology: '{other}'"),
        };
        Ok(Scenario {
            name: topology.to_string(),
            topology: topology.to_string(),
            num_apps,
            num_sources,
            num_tasks: 2,
            link_kind: CostKind::Queue,
            link_param,
            comp_kind: CostKind::Queue,
            comp_param,
            rate_lo: 0.5,
            rate_hi: 1.5,
            rate_scale: 1.0,
            packet_base: 10.0,
            packet_decay: 5.0,
            comp_weight: 0.25,
            chain: None,
            seed: 2023,
        })
    }

    /// The SW-linear variant of Fig. 5.
    pub fn sw_linear() -> Scenario {
        let mut s = Scenario::table2("sw").unwrap();
        s.name = "sw-linear".into();
        s.link_kind = CostKind::Linear;
        s.comp_kind = CostKind::Linear;
        s
    }

    /// Packet size of stage k.
    pub fn packet_size(&self, k: usize) -> f64 {
        (self.packet_base - self.packet_decay * k as f64).max(1.0)
    }

    /// Build the concrete network (topology + apps + costs) from the seed.
    pub fn build(&self, rng: &mut Rng) -> anyhow::Result<Network> {
        let graph = topologies::by_name(&self.topology, rng)?;
        self.build_on(graph, rng)
    }

    /// Build the network on an already-constructed topology. The scenario
    /// engine uses this to share cached graphs across related runs: `rng`
    /// then only drives application placement, so a cached graph plus a
    /// fresh rng reproduces exactly the same network as an uncached build
    /// with a separate topology rng.
    pub fn build_on(&self, graph: crate::graph::Graph, rng: &mut Rng) -> anyhow::Result<Network> {
        let n = graph.n();
        let mut apps = Vec::with_capacity(self.num_apps);
        for _ in 0..self.num_apps {
            let dest = rng.usize(n);
            let sources = rng.choose_distinct(n, self.num_sources.min(n));
            let mut input_rates = vec![0.0; n];
            for s in sources {
                input_rates[s] = rng.range(self.rate_lo, self.rate_hi) * self.rate_scale;
            }
            let packet_sizes = (0..=self.num_tasks).map(|k| self.packet_size(k)).collect();
            apps.push(Application {
                dest,
                num_tasks: self.num_tasks,
                packet_sizes,
                input_rates,
            });
        }
        let stages = StageRegistry::new(&apps);
        // w_i(a,k) = comp_weight · L_(a,k): task workload scales with the
        // size of its input packets (final stages get w = 0; no next task).
        let comp_weight = stages
            .iter()
            .map(|(_s, (_a, k))| {
                let w = if k < self.num_tasks {
                    self.comp_weight * self.packet_size(k)
                } else {
                    0.0
                };
                vec![w; n]
            })
            .collect();
        let link_cost = (0..graph.m())
            .map(|_| self.link_kind.instantiate(self.link_param))
            .collect();
        let comp_cost = (0..n)
            .map(|_| self.comp_kind.instantiate(self.comp_param))
            .collect();
        match &self.chain {
            None => Network::new(graph, apps, link_cost, comp_cost, comp_weight),
            Some(spec) => {
                let profile = spec.resolve(self.num_tasks)?;
                let chains = vec![profile; apps.len()];
                Network::with_chains(graph, apps, link_cost, comp_cost, comp_weight, chains)
            }
        }
    }

    // ---- JSON round trip ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::Str(self.name.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("num_apps", Json::Num(self.num_apps as f64)),
            ("num_sources", Json::Num(self.num_sources as f64)),
            ("num_tasks", Json::Num(self.num_tasks as f64)),
            (
                "link_kind",
                Json::Str(
                    match self.link_kind {
                        CostKind::Linear => "linear",
                        CostKind::Queue => "queue",
                    }
                    .into(),
                ),
            ),
            ("link_param", Json::Num(self.link_param)),
            (
                "comp_kind",
                Json::Str(
                    match self.comp_kind {
                        CostKind::Linear => "linear",
                        CostKind::Queue => "queue",
                    }
                    .into(),
                ),
            ),
            ("comp_param", Json::Num(self.comp_param)),
            ("rate_lo", Json::Num(self.rate_lo)),
            ("rate_hi", Json::Num(self.rate_hi)),
            ("rate_scale", Json::Num(self.rate_scale)),
            ("packet_base", Json::Num(self.packet_base)),
            ("packet_decay", Json::Num(self.packet_decay)),
            ("comp_weight", Json::Num(self.comp_weight)),
            // seeds below 2^53 stay human-readable numbers; larger ones use
            // the lossless hex form (f64 would silently round them, and the
            // control plane's checkpoint restore rebuilds the topology from
            // this seed — see Json::from_u64)
            (
                "seed",
                if self.seed < (1u64 << 53) {
                    Json::Num(self.seed as f64)
                } else {
                    Json::from_u64(self.seed)
                },
            ),
        ];
        // identity chains are omitted entirely for config readability
        if let Some(spec) = &self.chain {
            fields.push(("chain", spec.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Scenario> {
        let gets = |k: &str| -> anyhow::Result<String> {
            Ok(v
                .get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("config: missing string '{k}'"))?
                .to_string())
        };
        let getf = |k: &str, d: f64| v.get(k).and_then(Json::as_f64).unwrap_or(d);
        let getu = |k: &str, d: usize| v.get(k).and_then(Json::as_usize).unwrap_or(d);
        Ok(Scenario {
            name: gets("name").unwrap_or_else(|_| "custom".into()),
            topology: gets("topology")?,
            num_apps: getu("num_apps", 1),
            num_sources: getu("num_sources", 1),
            num_tasks: getu("num_tasks", 2),
            link_kind: CostKind::parse(&gets("link_kind").unwrap_or_else(|_| "queue".into()))?,
            link_param: getf("link_param", 10.0),
            comp_kind: CostKind::parse(&gets("comp_kind").unwrap_or_else(|_| "queue".into()))?,
            comp_param: getf("comp_param", 10.0),
            rate_lo: getf("rate_lo", 0.5),
            rate_hi: getf("rate_hi", 1.5),
            rate_scale: getf("rate_scale", 1.0),
            packet_base: getf("packet_base", 10.0),
            packet_decay: getf("packet_decay", 5.0),
            comp_weight: getf("comp_weight", 1.0),
            chain: match v.get("chain") {
                None | Some(Json::Null) => None,
                Some(c) => Some(ChainSpec::from_json(c)?),
            },
            seed: v
                .get("seed")
                .and_then(Json::as_u64_lossless)
                .unwrap_or(2023),
        })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Load a scenario config from a `.json` or `.toml` file (detected by
    /// extension; anything except `.toml` is parsed as JSON).
    pub fn load(path: &std::path::Path) -> anyhow::Result<Scenario> {
        let text = std::fs::read_to_string(path)?;
        let v = parse_config_text(&text, path)?;
        Scenario::from_json(&v)
    }
}

/// Parse config text as TOML (for `.toml` paths) or JSON (everything else)
/// into the shared [`Json`] value model.
pub fn parse_config_text(text: &str, path: &std::path::Path) -> anyhow::Result<Json> {
    let is_toml = path
        .extension()
        .and_then(|e| e.to_str())
        .map(|e| e.eq_ignore_ascii_case("toml"))
        .unwrap_or(false);
    if is_toml {
        crate::util::toml::parse(text)
    } else {
        Ok(Json::parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_rows_build_valid_networks() {
        for name in topologies::SCENARIO_NAMES {
            let sc = Scenario::table2(name).unwrap();
            let mut rng = Rng::new(sc.seed);
            let net = sc.build(&mut rng).unwrap();
            assert_eq!(net.num_stages(), sc.num_apps * 3, "{name}");
            // every app has exactly R sources
            for app in &net.apps {
                let sources = app.input_rates.iter().filter(|&&r| r > 0.0).count();
                assert_eq!(sources, sc.num_sources, "{name}");
            }
        }
    }

    #[test]
    fn packet_schedule_matches_paper() {
        let sc = Scenario::table2("abilene").unwrap();
        assert_eq!(sc.packet_size(0), 10.0);
        assert_eq!(sc.packet_size(1), 5.0);
        assert_eq!(sc.packet_size(2), 1.0); // floor(10-10, 1)
    }

    #[test]
    fn json_roundtrip() {
        let sc = Scenario::table2("geant").unwrap();
        let re = Scenario::from_json(&sc.to_json()).unwrap();
        assert_eq!(format!("{sc:?}"), format!("{re:?}"));
    }

    #[test]
    fn chain_field_roundtrips_and_defaults_to_identity() {
        // identity (None) stays absent from the emitted config
        let sc = Scenario::table2("abilene").unwrap();
        assert!(sc.to_json().get("chain").is_none());
        // named profile round-trips exactly
        let mut sc = Scenario::table2("abilene").unwrap();
        sc.chain = Some(ChainSpec::named("vgg16").unwrap());
        let re = Scenario::from_json(&sc.to_json()).unwrap();
        assert_eq!(format!("{sc:?}"), format!("{re:?}"));
        // explicit profile round-trips exactly
        sc.chain = Some(ChainSpec::Explicit {
            scale: vec![2.0, 0.5],
            result_size: 0.25,
            local_frac: vec![0.5, 0.25],
        });
        let re = Scenario::from_json(&sc.to_json()).unwrap();
        assert_eq!(format!("{sc:?}"), format!("{re:?}"));
    }

    #[test]
    fn chained_scenario_builds_generalized_network() {
        let mut sc = Scenario::table2("abilene").unwrap();
        sc.chain = Some(ChainSpec::named("resnet50").unwrap());
        let net = sc.build(&mut Rng::new(sc.seed)).unwrap();
        // every stage table is populated and at least one stage inflates or
        // returns data
        assert_eq!(net.stage_conv.len(), net.num_stages());
        assert!(net.stage_ret.iter().any(|&u| u > 0.0));
        assert!(net.chains.iter().all(|c| !c.is_identity()));
        // a ragged explicit spec is rejected at build time
        sc.chain = Some(ChainSpec::Explicit {
            scale: vec![2.0],
            result_size: 0.0,
            local_frac: vec![],
        });
        assert!(sc.build(&mut Rng::new(sc.seed)).is_err());
    }

    #[test]
    fn huge_seeds_roundtrip_losslessly() {
        // seeds past 2^53 would corrupt through f64; the hex form keeps the
        // deterministic topology rebuild (checkpoint restore) exact
        let mut sc = Scenario::table2("abilene").unwrap();
        sc.seed = (1u64 << 53) + 1;
        let re = Scenario::from_json(&sc.to_json()).unwrap();
        assert_eq!(re.seed, sc.seed);
        // small seeds stay plain numbers for config readability
        sc.seed = 2023;
        let v = sc.to_json();
        assert_eq!(v.get("seed").unwrap().as_usize(), Some(2023));
        assert_eq!(Scenario::from_json(&v).unwrap().seed, 2023);
    }

    #[test]
    fn build_is_deterministic_per_seed() {
        let sc = Scenario::table2("connected-er").unwrap();
        let n1 = sc.build(&mut Rng::new(sc.seed)).unwrap();
        let n2 = sc.build(&mut Rng::new(sc.seed)).unwrap();
        assert_eq!(n1.graph.edges(), n2.graph.edges());
        for (a1, a2) in n1.apps.iter().zip(&n2.apps) {
            assert_eq!(a1.dest, a2.dest);
            assert_eq!(a1.input_rates, a2.input_rates);
        }
    }

    #[test]
    fn sw_linear_variant() {
        let sc = Scenario::sw_linear();
        assert_eq!(sc.link_kind, CostKind::Linear);
        assert_eq!(sc.name, "sw-linear");
    }

    #[test]
    fn toml_config_parses_like_json() {
        let toml_text = r#"
            name = "custom"
            topology = "grid-3x3"
            num_apps = 2
            link_kind = "queue"
            link_param = 12.0
        "#;
        let v = parse_config_text(toml_text, std::path::Path::new("x.toml")).unwrap();
        let sc = Scenario::from_json(&v).unwrap();
        assert_eq!(sc.topology, "grid-3x3");
        assert_eq!(sc.num_apps, 2);
        assert_eq!(sc.link_param, 12.0);
        // unknown extension falls back to JSON
        let v2 = parse_config_text(
            r#"{"topology": "abilene"}"#,
            std::path::Path::new("x.json"),
        )
        .unwrap();
        assert_eq!(
            Scenario::from_json(&v2).unwrap().topology,
            "abilene"
        );
    }

    #[test]
    fn build_on_matches_build_with_split_rngs() {
        // a cached-graph build (build_on) must reproduce the uncached build
        // exactly when the same app rng is used
        let sc = Scenario::table2("connected-er").unwrap();
        let mut topo_rng = Rng::new(sc.seed);
        let graph = topologies::by_name(&sc.topology, &mut topo_rng).unwrap();
        let mut full_rng = Rng::new(sc.seed);
        let reference = sc.build(&mut full_rng).unwrap();
        // replay: same graph, rng positioned after topology draws
        let mut topo_rng2 = Rng::new(sc.seed);
        let graph2 = topologies::by_name(&sc.topology, &mut topo_rng2).unwrap();
        assert_eq!(graph.edges(), graph2.edges());
        let cached = sc.build_on(graph2, &mut topo_rng2).unwrap();
        for (a, b) in reference.apps.iter().zip(&cached.apps) {
            assert_eq!(a.dest, b.dest);
            assert_eq!(a.input_rates, b.input_rates);
        }
    }
}
