//! Directed network graph 𝒢 = (𝒱, ℰ).
//!
//! Nodes are dense indices `0..n`. Links are directed; every topology builder
//! in [`topologies`] produces bidirected graphs (both (i,j) and (j,i)) as in
//! the paper's evaluation, but the core structures support arbitrary digraphs.

pub mod topologies;

use std::collections::BTreeMap;

/// A directed graph with O(1) edge-id lookup and adjacency lists.
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    edges: Vec<(usize, usize)>,
    /// (i,j) -> edge id
    index: BTreeMap<(usize, usize), usize>,
    /// dense n×n edge-id matrix (u32::MAX = no edge) — the hot-path lookup
    /// (marginals/blocked-sets do S·n² of these per iteration; a BTreeMap
    /// here was the top profile entry before this cache)
    dense: Vec<u32>,
    out: Vec<Vec<usize>>, // out-neighbors of i
    inn: Vec<Vec<usize>>, // in-neighbors of i
}

const NO_EDGE: u32 = u32::MAX;

impl Graph {
    /// Build from a node count and a directed edge list. Duplicate edges and
    /// self-loops are rejected.
    pub fn new(n: usize, edge_list: &[(usize, usize)]) -> anyhow::Result<Self> {
        let mut g = Graph {
            n,
            edges: Vec::with_capacity(edge_list.len()),
            index: BTreeMap::new(),
            dense: vec![NO_EDGE; n * n],
            out: vec![Vec::new(); n],
            inn: vec![Vec::new(); n],
        };
        for &(i, j) in edge_list {
            anyhow::ensure!(i < n && j < n, "edge ({i},{j}) out of range (n={n})");
            anyhow::ensure!(i != j, "self-loop ({i},{i})");
            anyhow::ensure!(
                !g.index.contains_key(&(i, j)),
                "duplicate edge ({i},{j})"
            );
            let id = g.edges.len();
            g.edges.push((i, j));
            g.index.insert((i, j), id);
            g.dense[i * n + j] = id as u32;
            g.out[i].push(j);
            g.inn[j].push(i);
        }
        Ok(g)
    }

    /// Bidirect an undirected edge list: {i,j} -> (i,j) and (j,i).
    pub fn bidirected(n: usize, undirected: &[(usize, usize)]) -> anyhow::Result<Self> {
        let mut es = Vec::with_capacity(undirected.len() * 2);
        for &(i, j) in undirected {
            es.push((i, j));
            es.push((j, i));
        }
        Graph::new(n, &es)
    }

    pub fn n(&self) -> usize {
        self.n
    }
    pub fn m(&self) -> usize {
        self.edges.len()
    }
    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }
    pub fn edge(&self, id: usize) -> (usize, usize) {
        self.edges[id]
    }
    #[inline]
    pub fn edge_id(&self, i: usize, j: usize) -> Option<usize> {
        let id = self.dense[i * self.n + j];
        (id != NO_EDGE).then_some(id as usize)
    }
    #[inline]
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.dense[i * self.n + j] != NO_EDGE
    }
    pub fn out_neighbors(&self, i: usize) -> &[usize] {
        &self.out[i]
    }
    pub fn in_neighbors(&self, i: usize) -> &[usize] {
        &self.inn[i]
    }
    pub fn max_out_degree(&self) -> usize {
        self.out.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Is the graph strongly connected? (Kosaraju-lite: forward+backward BFS.)
    pub fn strongly_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        self.bfs_count(0, false) == self.n && self.bfs_count(0, true) == self.n
    }

    /// Is every node able to reach `dst`?
    pub fn all_reach(&self, dst: usize) -> bool {
        self.bfs_count(dst, true) == self.n
    }

    fn bfs_count(&self, src: usize, reverse: bool) -> usize {
        let mut seen = vec![false; self.n];
        let mut queue = vec![src];
        seen[src] = true;
        let mut count = 1;
        while let Some(u) = queue.pop() {
            let nbrs = if reverse { &self.inn[u] } else { &self.out[u] };
            for &v in nbrs {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push(v);
                }
            }
        }
        count
    }

    /// Single-source shortest path tree by edge weights (Dijkstra).
    /// Returns (dist, parent) where parent[src] = src.
    pub fn dijkstra(&self, src: usize, weight: impl Fn(usize) -> f64) -> (Vec<f64>, Vec<usize>) {
        use std::cmp::Ordering;
        use std::collections::BinaryHeap;

        #[derive(PartialEq)]
        struct Item(f64, usize);
        impl Eq for Item {}
        impl PartialOrd for Item {
            fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for Item {
            fn cmp(&self, o: &Self) -> Ordering {
                // min-heap via reversed comparison on the f64 key
                o.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
            }
        }

        let mut dist = vec![f64::INFINITY; self.n];
        let mut parent: Vec<usize> = (0..self.n).collect();
        let mut heap = BinaryHeap::new();
        dist[src] = 0.0;
        heap.push(Item(0.0, src));
        while let Some(Item(d, u)) = heap.pop() {
            if d > dist[u] {
                continue;
            }
            for &v in &self.out[u] {
                let e = self.edge_id(u, v).unwrap();
                let w = weight(e);
                debug_assert!(w >= 0.0, "negative weight on edge {e}");
                let nd = d + w;
                if nd < dist[v] {
                    dist[v] = nd;
                    parent[v] = u;
                    heap.push(Item(nd, v));
                }
            }
        }
        (dist, parent)
    }

    /// Shortest path distances *to* `dst` from every node (Dijkstra on the
    /// reversed graph). Returns (dist, next_hop) where next_hop[dst] = dst.
    pub fn dijkstra_to(&self, dst: usize, weight: impl Fn(usize) -> f64) -> (Vec<f64>, Vec<usize>) {
        use std::cmp::Ordering;
        use std::collections::BinaryHeap;

        #[derive(PartialEq)]
        struct Item(f64, usize);
        impl Eq for Item {}
        impl PartialOrd for Item {
            fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for Item {
            fn cmp(&self, o: &Self) -> Ordering {
                o.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
            }
        }

        let mut dist = vec![f64::INFINITY; self.n];
        let mut next: Vec<usize> = (0..self.n).collect();
        let mut heap = BinaryHeap::new();
        dist[dst] = 0.0;
        heap.push(Item(0.0, dst));
        while let Some(Item(d, u)) = heap.pop() {
            if d > dist[u] {
                continue;
            }
            // traverse reversed: edges (v, u)
            for &v in &self.inn[u] {
                let e = self.edge_id(v, u).unwrap();
                let w = weight(e);
                let nd = d + w;
                if nd < dist[v] {
                    dist[v] = nd;
                    next[v] = u;
                    heap.push(Item(nd, v));
                }
            }
        }
        (dist, next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0 -> 1 -> 3, 0 -> 2 -> 3
        Graph::new(4, &[(0, 1), (1, 3), (0, 2), (2, 3)]).unwrap()
    }

    #[test]
    fn adjacency_and_ids() {
        let g = diamond();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.edge_id(0, 1), Some(0));
        assert_eq!(g.edge_id(1, 0), None);
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.in_neighbors(3), &[1, 2]);
    }

    #[test]
    fn rejects_bad_edges() {
        assert!(Graph::new(2, &[(0, 0)]).is_err());
        assert!(Graph::new(2, &[(0, 1), (0, 1)]).is_err());
        assert!(Graph::new(2, &[(0, 2)]).is_err());
    }

    #[test]
    fn bidirected_doubles_edges() {
        let g = Graph::bidirected(3, &[(0, 1), (1, 2)]).unwrap();
        assert_eq!(g.m(), 4);
        assert!(g.has_edge(1, 0));
        assert!(g.strongly_connected());
    }

    #[test]
    fn reachability() {
        let g = diamond();
        assert!(!g.strongly_connected());
        assert!(g.all_reach(3));
        assert!(!g.all_reach(0));
    }

    #[test]
    fn dijkstra_shortest() {
        let g = diamond();
        // weights: edge ids 0:(0,1)=1, 1:(1,3)=5, 2:(0,2)=2, 3:(2,3)=1
        let w = [1.0, 5.0, 2.0, 1.0];
        let (dist, parent) = g.dijkstra(0, |e| w[e]);
        assert_eq!(dist[3], 3.0);
        assert_eq!(parent[3], 2);
    }

    #[test]
    fn dijkstra_to_gives_next_hops() {
        let g = diamond();
        let w = [1.0, 5.0, 2.0, 1.0];
        let (dist, next) = g.dijkstra_to(3, |e| w[e]);
        assert_eq!(dist[0], 3.0);
        assert_eq!(next[0], 2);
        assert_eq!(next[2], 3);
        assert_eq!(next[3], 3);
    }
}
