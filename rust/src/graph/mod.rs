//! Directed network graph 𝒢 = (𝒱, ℰ) with a CSR (compressed sparse row)
//! slot layout shared by every per-(stage, node) structure in the optimizer.
//!
//! Nodes are dense indices `0..n`. Links are directed; every topology builder
//! in [`topologies`] produces bidirected graphs (both (i,j) and (j,i)) as in
//! the paper's evaluation, but the core structures support arbitrary digraphs.
//!
//! ## The CSR slot layout
//!
//! Each node `i` owns `out_degree(i) + 1` consecutive *slots* in a single
//! flat arena of `Σ_i (deg(i)+1) = m + n` entries:
//!
//! ```text
//! arena:    [ node 0 slots | node 1 slots | ... | node n-1 slots ]
//! node i:   [ link slot, link slot, ..., link slot, CPU slot ]
//!             targets sorted ascending by node id       ^ always last
//! slot_ptr: slot_ptr[i]..slot_ptr[i+1] delimits node i's slots
//! ```
//!
//! [`Strategy`](crate::strategy::Strategy) (φ),
//! [`Marginals`](crate::marginals::Marginals) (δ), blocked flags and
//! support masks all store one `f64`/`bool` per slot, so a GP iteration touches
//! O(|𝒮|·(m+n)) memory instead of the former dense O(|𝒮|·n²) — see
//! `docs/PERFORMANCE.md`. The shared [`CsrLayout`] is reference-counted;
//! cloning a graph or strategy does not copy the offset tables.

pub mod topologies;

use std::sync::Arc;

/// Shared CSR offset tables: per-node slot ranges, per-slot targets and edge
/// ids. Immutable once built; shared via `Arc` by [`Graph`], strategies,
/// marginals, blocked sets and support masks.
#[derive(Debug, PartialEq, Eq)]
pub struct CsrLayout {
    n: usize,
    /// len n+1; node i's slots are `slot_ptr[i]..slot_ptr[i+1]`.
    slot_ptr: Vec<usize>,
    /// len m+n; link slots hold the target node id (ascending within a
    /// node's segment), the trailing CPU slot holds the sentinel `n`.
    slot_target: Vec<usize>,
    /// len m+n; link slots hold the edge id, CPU slots hold `usize::MAX`.
    slot_edge: Vec<usize>,
}

impl CsrLayout {
    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total arena length: `m + n` (one CPU slot per node).
    pub fn num_slots(&self) -> usize {
        self.slot_target.len()
    }

    /// Arena range of node `i`'s slots (links first, CPU last).
    #[inline]
    pub fn slot_range(&self, i: usize) -> std::ops::Range<usize> {
        self.slot_ptr[i]..self.slot_ptr[i + 1]
    }

    /// Arena range of node `i`'s *link* slots (excludes the CPU slot).
    #[inline]
    pub fn link_slot_range(&self, i: usize) -> std::ops::Range<usize> {
        self.slot_ptr[i]..self.slot_ptr[i + 1] - 1
    }

    /// Row width of node `i`: `out_degree(i) + 1`.
    #[inline]
    pub fn width(&self, i: usize) -> usize {
        self.slot_ptr[i + 1] - self.slot_ptr[i]
    }

    /// Arena index of node `i`'s CPU slot (always the last of its segment).
    #[inline]
    pub fn cpu_slot(&self, i: usize) -> usize {
        self.slot_ptr[i + 1] - 1
    }

    /// Target node of an arena slot (`n` for CPU slots).
    #[inline]
    pub fn slot_target(&self, t: usize) -> usize {
        self.slot_target[t]
    }

    /// Edge id of an arena *link* slot (`usize::MAX` for CPU slots).
    #[inline]
    pub fn slot_edge(&self, t: usize) -> usize {
        self.slot_edge[t]
    }

    /// Node `i`'s out-neighbor ids, ascending (the link-slot targets).
    #[inline]
    pub fn link_targets(&self, i: usize) -> &[usize] {
        &self.slot_target[self.link_slot_range(i)]
    }

    /// Arena slot of direction `j` from node `i`: `j == n` resolves to the
    /// CPU slot, a neighbor id to its link slot (binary search), anything
    /// else to `None`.
    #[inline]
    pub fn slot_of(&self, i: usize, j: usize) -> Option<usize> {
        let r = self.slot_range(i);
        if j == self.n {
            return Some(r.end - 1);
        }
        let links = &self.slot_target[r.start..r.end - 1];
        links.binary_search(&j).ok().map(|p| r.start + p)
    }
}

/// A directed graph with CSR adjacency and O(log deg) edge-id lookup.
#[derive(Clone, Debug)]
pub struct Graph {
    edges: Vec<(usize, usize)>,
    layout: Arc<CsrLayout>,
    inn: Vec<Vec<usize>>, // in-neighbors of i, ascending
}

impl Graph {
    /// Build from a node count and a directed edge list. Duplicate edges and
    /// self-loops are rejected.
    pub fn new(n: usize, edge_list: &[(usize, usize)]) -> anyhow::Result<Self> {
        let mut seen = std::collections::BTreeSet::new();
        let mut out: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n]; // (target, edge id)
        let mut inn: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut edges = Vec::with_capacity(edge_list.len());
        for &(i, j) in edge_list {
            anyhow::ensure!(i < n && j < n, "edge ({i},{j}) out of range (n={n})");
            anyhow::ensure!(i != j, "self-loop ({i},{i})");
            anyhow::ensure!(seen.insert((i, j)), "duplicate edge ({i},{j})");
            let id = edges.len();
            edges.push((i, j));
            out[i].push((j, id));
            inn[j].push(i);
        }
        for l in &mut out {
            l.sort_unstable(); // by target; targets are unique per node
        }
        for l in &mut inn {
            l.sort_unstable();
        }
        let mut slot_ptr = Vec::with_capacity(n + 1);
        let mut slot_target = Vec::with_capacity(edges.len() + n);
        let mut slot_edge = Vec::with_capacity(edges.len() + n);
        slot_ptr.push(0);
        for adj in &out {
            for &(j, e) in adj {
                slot_target.push(j);
                slot_edge.push(e);
            }
            slot_target.push(n); // CPU sentinel
            slot_edge.push(usize::MAX);
            slot_ptr.push(slot_target.len());
        }
        Ok(Graph {
            edges,
            layout: Arc::new(CsrLayout {
                n,
                slot_ptr,
                slot_target,
                slot_edge,
            }),
            inn,
        })
    }

    /// Bidirect an undirected edge list: {i,j} -> (i,j) and (j,i).
    pub fn bidirected(n: usize, undirected: &[(usize, usize)]) -> anyhow::Result<Self> {
        let mut es = Vec::with_capacity(undirected.len() * 2);
        for &(i, j) in undirected {
            es.push((i, j));
            es.push((j, i));
        }
        Graph::new(n, &es)
    }

    pub fn n(&self) -> usize {
        self.layout.n
    }
    pub fn m(&self) -> usize {
        self.edges.len()
    }
    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }
    pub fn edge(&self, id: usize) -> (usize, usize) {
        self.edges[id]
    }

    /// The shared CSR slot layout (see the module docs).
    #[inline]
    pub fn layout(&self) -> &Arc<CsrLayout> {
        &self.layout
    }

    #[inline]
    pub fn edge_id(&self, i: usize, j: usize) -> Option<usize> {
        if j >= self.layout.n {
            return None;
        }
        self.layout.slot_of(i, j).map(|t| self.layout.slot_edge[t])
    }
    #[inline]
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.edge_id(i, j).is_some()
    }
    /// Out-neighbors of `i`, ascending by node id.
    pub fn out_neighbors(&self, i: usize) -> &[usize] {
        self.layout.link_targets(i)
    }
    /// In-neighbors of `i`, ascending by node id.
    pub fn in_neighbors(&self, i: usize) -> &[usize] {
        &self.inn[i]
    }
    pub fn out_degree(&self, i: usize) -> usize {
        self.layout.width(i) - 1
    }
    pub fn max_out_degree(&self) -> usize {
        (0..self.n()).map(|i| self.out_degree(i)).max().unwrap_or(0)
    }

    /// Iterate `(target, edge id)` over `i`'s out-links, ascending by target
    /// — index-aligned with the first `out_degree(i)` entries of any CSR row
    /// for node `i` (φ rows, δ rows, blocked/support flags).
    pub fn out_links(&self, i: usize) -> impl Iterator<Item = (usize, usize)> + '_ {
        let r = self.layout.link_slot_range(i);
        self.layout.slot_target[r.clone()]
            .iter()
            .copied()
            .zip(self.layout.slot_edge[r].iter().copied())
    }

    /// `(target, edge id)` of node `i`'s `idx`-th out-link slot.
    #[inline]
    pub fn link_slot(&self, i: usize, idx: usize) -> (usize, usize) {
        let t = self.layout.slot_ptr[i] + idx;
        debug_assert!(t < self.layout.cpu_slot(i), "slot {idx} of node {i} is not a link");
        (self.layout.slot_target[t], self.layout.slot_edge[t])
    }

    /// Is the graph strongly connected? (Kosaraju-lite: forward+backward BFS.)
    pub fn strongly_connected(&self) -> bool {
        if self.n() == 0 {
            return true;
        }
        self.bfs_count(0, false) == self.n() && self.bfs_count(0, true) == self.n()
    }

    /// Is every node able to reach `dst`?
    pub fn all_reach(&self, dst: usize) -> bool {
        self.bfs_count(dst, true) == self.n()
    }

    fn bfs_count(&self, src: usize, reverse: bool) -> usize {
        let mut seen = vec![false; self.n()];
        let mut queue = vec![src];
        seen[src] = true;
        let mut count = 1;
        while let Some(u) = queue.pop() {
            let nbrs: &[usize] = if reverse {
                &self.inn[u]
            } else {
                self.layout.link_targets(u)
            };
            for &v in nbrs {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push(v);
                }
            }
        }
        count
    }

    /// Single-source shortest path tree by edge weights (Dijkstra).
    /// Returns (dist, parent) where parent[src] = src.
    pub fn dijkstra(&self, src: usize, weight: impl Fn(usize) -> f64) -> (Vec<f64>, Vec<usize>) {
        use std::cmp::Ordering;
        use std::collections::BinaryHeap;

        #[derive(PartialEq)]
        struct Item(f64, usize);
        impl Eq for Item {}
        impl PartialOrd for Item {
            fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for Item {
            fn cmp(&self, o: &Self) -> Ordering {
                // min-heap via reversed comparison on the f64 key
                o.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
            }
        }

        let n = self.n();
        let mut dist = vec![f64::INFINITY; n];
        let mut parent: Vec<usize> = (0..n).collect();
        let mut heap = BinaryHeap::new();
        dist[src] = 0.0;
        heap.push(Item(0.0, src));
        while let Some(Item(d, u)) = heap.pop() {
            if d > dist[u] {
                continue;
            }
            for (v, e) in self.out_links(u) {
                let w = weight(e);
                debug_assert!(w >= 0.0, "negative weight on edge {e}");
                let nd = d + w;
                if nd < dist[v] {
                    dist[v] = nd;
                    parent[v] = u;
                    heap.push(Item(nd, v));
                }
            }
        }
        (dist, parent)
    }

    /// Shortest path distances *to* `dst` from every node (Dijkstra on the
    /// reversed graph). Returns (dist, next_hop) where next_hop[dst] = dst.
    pub fn dijkstra_to(&self, dst: usize, weight: impl Fn(usize) -> f64) -> (Vec<f64>, Vec<usize>) {
        use std::cmp::Ordering;
        use std::collections::BinaryHeap;

        #[derive(PartialEq)]
        struct Item(f64, usize);
        impl Eq for Item {}
        impl PartialOrd for Item {
            fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
                Some(self.cmp(o))
            }
        }
        impl Ord for Item {
            fn cmp(&self, o: &Self) -> Ordering {
                o.0.partial_cmp(&self.0).unwrap_or(Ordering::Equal)
            }
        }

        let n = self.n();
        let mut dist = vec![f64::INFINITY; n];
        let mut next: Vec<usize> = (0..n).collect();
        let mut heap = BinaryHeap::new();
        dist[dst] = 0.0;
        heap.push(Item(0.0, dst));
        while let Some(Item(d, u)) = heap.pop() {
            if d > dist[u] {
                continue;
            }
            // traverse reversed: edges (v, u)
            for &v in &self.inn[u] {
                let e = self.edge_id(v, u).unwrap();
                let w = weight(e);
                let nd = d + w;
                if nd < dist[v] {
                    dist[v] = nd;
                    next[v] = u;
                    heap.push(Item(nd, v));
                }
            }
        }
        (dist, next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // 0 -> 1 -> 3, 0 -> 2 -> 3
        Graph::new(4, &[(0, 1), (1, 3), (0, 2), (2, 3)]).unwrap()
    }

    #[test]
    fn adjacency_and_ids() {
        let g = diamond();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.edge_id(0, 1), Some(0));
        assert_eq!(g.edge_id(1, 0), None);
        assert_eq!(g.out_neighbors(0), &[1, 2]);
        assert_eq!(g.in_neighbors(3), &[1, 2]);
    }

    #[test]
    fn csr_layout_shapes() {
        let g = diamond();
        let l = g.layout();
        // arena: m + n slots, one CPU slot per node
        assert_eq!(l.num_slots(), g.m() + g.n());
        assert_eq!(l.width(0), 3); // two links + CPU
        assert_eq!(l.width(3), 1); // no out-links + CPU
        // CPU slot is last and tagged with the sentinel n
        assert_eq!(l.slot_target(l.cpu_slot(0)), g.n());
        assert_eq!(l.slot_of(0, g.n()), Some(l.cpu_slot(0)));
        // link slots resolve to the right edges, non-links to None
        let t01 = l.slot_of(0, 1).unwrap();
        assert_eq!(l.slot_edge(t01), 0);
        assert_eq!(l.slot_of(0, 3), None);
        assert_eq!(l.slot_of(3, 0), None);
    }

    #[test]
    fn out_links_aligned_with_slots() {
        let g = diamond();
        let l = g.layout();
        for i in 0..g.n() {
            for (idx, (j, e)) in g.out_links(i).enumerate() {
                assert_eq!(g.link_slot(i, idx), (j, e));
                let r = l.slot_range(i);
                assert_eq!(l.slot_target(r.start + idx), j);
                assert_eq!(l.slot_edge(r.start + idx), e);
                assert_eq!(g.edge(e), (i, j));
            }
        }
    }

    #[test]
    fn rejects_bad_edges() {
        assert!(Graph::new(2, &[(0, 0)]).is_err());
        assert!(Graph::new(2, &[(0, 1), (0, 1)]).is_err());
        assert!(Graph::new(2, &[(0, 2)]).is_err());
    }

    #[test]
    fn bidirected_doubles_edges() {
        let g = Graph::bidirected(3, &[(0, 1), (1, 2)]).unwrap();
        assert_eq!(g.m(), 4);
        assert!(g.has_edge(1, 0));
        assert!(g.strongly_connected());
    }

    #[test]
    fn reachability() {
        let g = diamond();
        assert!(!g.strongly_connected());
        assert!(g.all_reach(3));
        assert!(!g.all_reach(0));
    }

    #[test]
    fn dijkstra_shortest() {
        let g = diamond();
        // weights: edge ids 0:(0,1)=1, 1:(1,3)=5, 2:(0,2)=2, 3:(2,3)=1
        let w = [1.0, 5.0, 2.0, 1.0];
        let (dist, parent) = g.dijkstra(0, |e| w[e]);
        assert_eq!(dist[3], 3.0);
        assert_eq!(parent[3], 2);
    }

    #[test]
    fn dijkstra_to_gives_next_hops() {
        let g = diamond();
        let w = [1.0, 5.0, 2.0, 1.0];
        let (dist, next) = g.dijkstra_to(3, |e| w[e]);
        assert_eq!(dist[0], 3.0);
        assert_eq!(next[0], 2);
        assert_eq!(next[2], 3);
        assert_eq!(next[3], 3);
    }
}
